"""Serving driver: chunked batched prefill + continuous-batching decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 6 --max-new 24 --prefill-chunk 32

Sharded SPMD serving: ``--tp``/``--fsdp`` declare the (data, model) host
mesh — every model GEMM then plans on its post-partition shape and runs
per-shard under jax.shard_map (see docs/substrate.md).  On CPU,
``--host-devices N`` fans the host out to N devices (the XLA_FLAGS
device-count override) so a TP=4 mesh is testable on a laptop:

  PYTHONPATH=src python -m repro.launch.serve --tp 4 --host-devices 8

Prints per-request outputs plus per-phase timing: prefill and decode
throughput (tokens/s), dispatch counts, and mean time-to-first-token.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax

from repro.configs import get_config, reduced
from repro.kernels import substrate
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def phase_report(engine: ServingEngine, reqs) -> str:
    st = engine.stats
    pf_tps = st["prefill_tokens"] / max(st["prefill_time_s"], 1e-9)
    de_tps = st["decode_tokens"] / max(st["decode_time_s"], 1e-9)
    ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
    ttft_ms = 1e3 * sum(ttfts) / max(len(ttfts), 1)
    out = (f"prefill[{engine.prefill_mode}]: {st['prefill_tokens']} tok "
           f"in {st['prefill_time_s']:.3f}s ({pf_tps:.1f} tok/s, "
           f"{st['prefill_dispatches']} dispatches, "
           f"chunk={engine.prefill_chunk})\n"
           f"decode: {st['decode_tokens']} tok in "
           f"{st['decode_time_s']:.3f}s ({de_tps:.1f} tok/s, "
           f"{st['decode_dispatches']} dispatches)\n"
           f"mean TTFT: {ttft_ms:.1f} ms")
    if engine.paged:
        out += (f"\npaged: peak {st['pages_used_peak']} pages, "
                f"peak concurrency {st['concurrency_peak']}, "
                f"prefix hits {st['prefix_hit_tokens']} tok, "
                f"{st['prefill_gemm_dispatches']} prefill GEMM launches")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk size (0 -> planner-chosen)")
    ap.add_argument("--prefill-mode", default="auto",
                    choices=("auto", "batched", "token"))
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged K/V: physical pages in the global pool "
                         "(incl. the scratch page); 0 keeps the dense "
                         "(max_batch, max_seq) slot cache.  Admission then "
                         "reserves pages, so concurrency is memory-bounded "
                         "rather than capped at --max-batch")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per K/V page (must divide max_seq); "
                         "0 -> planner.page_plan picks it with the Eq.(6) "
                         "cost model")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix shared-prefix reuse: requests sharing a "
                         "prompt prefix map their leading block-table "
                         "entries to the same physical pages (paged mode "
                         "only)")
    ap.add_argument("--gemm-backend", default="xla",
                    help="GEMM substrate backend (kernels.substrate): "
                         + " | ".join(substrate.backends()))
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (mesh 'model' axis); "
                         "GEMMs plan per-shard and run under shard_map")
    ap.add_argument("--fsdp", type=int, default=1,
                    help="FSDP/data-parallel degree (mesh 'data' axis)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fan the host out to N devices before the backend "
                         "initializes (XLA_FLAGS "
                         "--xla_force_host_platform_device_count; CPU only)")
    ap.add_argument("--strict-audit", action="store_true",
                    help="routing violations (unknown/missing site= labels) "
                         "raise [AF007] RuntimeErrors at dispatch time, and "
                         "run_to_completion cross-checks every recorded "
                         "site against planner.model_gemms (see "
                         "docs/analysis.md)")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    if args.strict_audit:
        os.environ["REPRO_STRICT_AUDIT"] = "1"

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    # validate at config-resolve time: a typo'd backend should die here
    # with the registered list, not deep inside the first traced dispatch
    substrate.check_backend(args.gemm_backend)
    cfg = dataclasses.replace(cfg, gemm_backend=args.gemm_backend)
    if args.tp > 1 or args.fsdp > 1:
        cfg = dataclasses.replace(cfg, mesh_shape=(args.fsdp, args.tp))
        print(f"mesh: data={args.fsdp} x model={args.tp} over "
              f"{len(jax.devices())} host devices")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.prefix_cache and not args.kv_pages:
        ap.error("--prefix-cache requires --kv-pages (paged mode)")
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=args.max_batch,
                                       max_seq=128,
                                       prefill_mode=args.prefill_mode,
                                       prefill_chunk=args.prefill_chunk,
                                       kv_pages=args.kv_pages,
                                       page_size=args.page_size,
                                       prefix_cache=args.prefix_cache))
    if args.kv_pages:
        print(f"paged KV: {args.kv_pages} pages x {engine.page_size} tok "
              f"({engine.kv_cache_bytes()/1024:.0f} KiB resident K/V), "
              f"prefix_cache={'on' if args.prefix_cache else 'off'}")
    prompts = [[2 + (i * 7 + j) % 97 for j in range(5 + i % 3)]
               for i in range(args.requests)]
    reqs = [Request(prompt=p, max_new_tokens=args.max_new,
                    temperature=args.temperature, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    ticks = engine.run_to_completion()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out_tokens}")
    print(f"{total} tokens in {dt:.2f}s ({total/max(dt,1e-9):.1f} tok/s, "
          f"{ticks} ticks)")
    print(phase_report(engine, reqs))
    return reqs


if __name__ == "__main__":
    main()
