"""Serving driver: batched decode with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 6 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=args.max_batch,
                                       max_seq=128))
    prompts = [[2 + (i * 7 + j) % 97 for j in range(5 + i % 3)]
               for i in range(args.requests)]
    reqs = [Request(prompt=p, max_new_tokens=args.max_new,
                    temperature=args.temperature, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    ticks = engine.run_to_completion()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out_tokens}")
    print(f"{total} tokens in {dt:.2f}s ({total/max(dt,1e-9):.1f} tok/s, "
          f"{ticks} ticks)")
    return reqs


if __name__ == "__main__":
    main()
