"""Serving driver: chunked batched prefill + continuous-batching decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 6 --max-new 24 --prefill-chunk 32

Sharded SPMD serving: ``--tp``/``--fsdp`` declare the (data, model) host
mesh — every model GEMM then plans on its post-partition shape and runs
per-shard under jax.shard_map (see docs/substrate.md).  On CPU,
``--host-devices N`` fans the host out to N devices (the XLA_FLAGS
device-count override) so a TP=4 mesh is testable on a laptop:

  PYTHONPATH=src python -m repro.launch.serve --tp 4 --host-devices 8

Prints per-request outputs plus per-phase timing: prefill and decode
throughput (tokens/s), dispatch counts, and mean time-to-first-token.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax

from repro.configs import get_config, reduced
from repro.kernels import substrate
from repro.models import lm
from repro.runtime import chaos
from repro.serving import (AdmissionError, DisaggServeConfig,
                           DisaggServingEngine, EngineCrash, ServeConfig,
                           ServingEngine)
from repro.serving.engine import Request


def phase_report(engine: ServingEngine, reqs) -> str:
    st = engine.stats
    pf_tps = st["prefill_tokens"] / max(st["prefill_time_s"], 1e-9)
    de_tps = st["decode_tokens"] / max(st["decode_time_s"], 1e-9)
    ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
    ttft_ms = 1e3 * sum(ttfts) / max(len(ttfts), 1)
    out = (f"prefill[{engine.prefill_mode}]: {st['prefill_tokens']} tok "
           f"in {st['prefill_time_s']:.3f}s ({pf_tps:.1f} tok/s, "
           f"{st['prefill_dispatches']} dispatches, "
           f"chunk={engine.prefill_chunk})\n"
           f"decode: {st['decode_tokens']} tok in "
           f"{st['decode_time_s']:.3f}s ({de_tps:.1f} tok/s, "
           f"{st['decode_dispatches']} dispatches)\n"
           f"mean TTFT: {ttft_ms:.1f} ms")
    if engine.paged:
        out += (f"\npaged: peak {st['pages_used_peak']} pages, "
                f"peak concurrency {st['concurrency_peak']}, "
                f"prefix hits {st['prefix_hit_tokens']} tok, "
                f"{st['prefill_gemm_dispatches']} prefill GEMM launches")
    be = engine.cfg.gemm_backend
    if substrate.backend_quantizes(be):
        out += (f"\nquantized: {be} serves int8 weights from the "
                f"pre-quantized tree"
                + (", per-tile int8 activations in-kernel (W8A8 MAC path)"
                   if substrate.backend_act_quantizes(be)
                   else " against fp32 activations"))
    counts = {r.outcome or "pending": 0 for r in reqs}
    for r in reqs:
        counts[r.outcome or "pending"] += 1
    out += ("\noutcomes: "
            + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())))
    resil = (f"\nresilience: {st['sample_retries']} sample retries, "
             f"{st['kernel_fault_retries']} kernel-fault retries, "
             f"{st['preemptions']} preemptions, "
             f"{st['watchdog_fired']} watchdog fires")
    if st["snapshots_taken"]:
        resil += f", {st['snapshots_taken']} snapshots"
    out += resil
    if isinstance(engine, DisaggServingEngine):
        sc = engine.sc
        vt = [engine.ttft_virtual[r.rid] for r in reqs
              if r.rid in engine.ttft_virtual]
        vt_ms = 1e3 * sum(vt) / max(len(vt), 1)
        makespan = max(st["prefill_time_s"], st["decode_time_s"])
        out += (f"\ndisagg: {sc.prefill_pods} prefill + {sc.decode_pods} "
                f"decode pod(s), pp={engine.pp}; "
                f"mean virtual TTFT {vt_ms:.1f} ms "
                f"(per-role clocks; wall TTFT above pays the colocated "
                f"interleave)\n"
                f"disagg: role makespan {makespan:.3f}s "
                f"(colocated sum {st['prefill_time_s'] + st['decode_time_s']:.3f}s), "
                f"K/V handoff {st['kv_transfer_bytes'] / 1024:.0f} KiB"
                + (f" in {st['kv_transfer_pages']} pages"
                   if engine.paged else "")
                + (f", {st['transfer_retries']} transfer retries"
                   if st["transfer_retries"] else "")
                + (f", {st['pod_losses']} pod losses"
                   if st["pod_losses"] else ""))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk size (0 -> planner-chosen)")
    ap.add_argument("--prefill-mode", default="auto",
                    choices=("auto", "batched", "token"))
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged K/V: physical pages in the global pool "
                         "(incl. the scratch page); 0 keeps the dense "
                         "(max_batch, max_seq) slot cache.  Admission then "
                         "reserves pages, so concurrency is memory-bounded "
                         "rather than capped at --max-batch")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per K/V page (must divide max_seq); "
                         "0 -> planner.page_plan picks it with the Eq.(6) "
                         "cost model")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix shared-prefix reuse: requests sharing a "
                         "prompt prefix map their leading block-table "
                         "entries to the same physical pages (paged mode "
                         "only)")
    ap.add_argument("--gemm-backend", default="xla",
                    help="GEMM substrate backend (kernels.substrate): "
                         + " | ".join(substrate.backends()))
    ap.add_argument("--prefill-pods", type=int, default=0,
                    help="disaggregated serving: pods in the prefill role "
                         "submesh (device window [0, prefill_pods)); "
                         "setting either pod flag switches to "
                         "DisaggServingEngine (see docs/serving.md)")
    ap.add_argument("--decode-pods", type=int, default=0,
                    help="disaggregated serving: pods in the decode role "
                         "submesh (devices after the prefill window)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages over the 'pod' axis within each "
                         "role (GPipe collective_permute); requires "
                         "--prefill-pods == --decode-pods == PP and dense "
                         "K/V")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (mesh 'model' axis); "
                         "GEMMs plan per-shard and run under shard_map")
    ap.add_argument("--fsdp", type=int, default=1,
                    help="FSDP/data-parallel degree (mesh 'data' axis)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fan the host out to N devices before the backend "
                         "initializes (XLA_FLAGS "
                         "--xla_force_host_platform_device_count; CPU only)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request total deadline in ms (0 = none); "
                         "expired requests terminate with outcome "
                         "deadline_expired")
    ap.add_argument("--ttft-deadline-ms", type=float, default=0.0,
                    help="per-request time-to-first-token deadline in ms "
                         "(0 = none)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue (0 = unbounded); overflow "
                         "is rejected typed with outcome rejected_overload")
    ap.add_argument("--chaos", default="",
                    help="seeded fault injection spec, e.g. "
                         "'seed=3,gemm=0.05,nan_at=2,crash_at=10' "
                         "(keys: seed, gemm, nan, pages, crash, + _at "
                         "variants; see docs/resilience.md)")
    ap.add_argument("--preempt-policy", default="none",
                    choices=("none", "youngest"),
                    help="on page-pool exhaustion mid-decode: 'youngest' "
                         "preempts the youngest resident sequence (release "
                         "pages, re-queue, recompute via the prefix cache) "
                         "instead of failing; also switches paged admission "
                         "to lazy page reservation")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="engine snapshot cadence in ticks for crash "
                         "recovery (0 = off; forced to 1 when --chaos "
                         "configures a crash)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="restore-from-snapshot attempts after injected "
                         "engine crashes before giving up")
    ap.add_argument("--strict-audit", action="store_true",
                    help="routing violations (unknown/missing site= labels) "
                         "raise [AF007] RuntimeErrors at dispatch time, and "
                         "run_to_completion cross-checks every recorded "
                         "site against planner.model_gemms (see "
                         "docs/analysis.md)")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    if args.strict_audit:
        os.environ["REPRO_STRICT_AUDIT"] = "1"

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    # validate at config-resolve time: a typo'd backend should die here
    # with the registered list, not deep inside the first traced dispatch
    substrate.check_backend(args.gemm_backend)
    cfg = dataclasses.replace(cfg, gemm_backend=args.gemm_backend)
    if args.tp > 1 or args.fsdp > 1:
        cfg = dataclasses.replace(cfg, mesh_shape=(args.fsdp, args.tp))
        print(f"mesh: data={args.fsdp} x model={args.tp} over "
              f"{len(jax.devices())} host devices")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.prefix_cache and not args.kv_pages:
        ap.error("--prefix-cache requires --kv-pages (paged mode)")
    chaos_cfg = chaos.parse_spec(args.chaos) if args.chaos else None
    snapshot_every = args.snapshot_every
    if (chaos_cfg is not None and not snapshot_every
            and (chaos_cfg.crash > 0.0 or chaos_cfg.crash_at >= 0)):
        snapshot_every = 1      # crash chaos without snapshots cannot recover
    disagg = args.prefill_pods > 0 or args.decode_pods > 0
    sc_kwargs = dict(max_batch=args.max_batch,
                     max_seq=128,
                     prefill_mode=args.prefill_mode,
                     prefill_chunk=args.prefill_chunk,
                     kv_pages=args.kv_pages,
                     page_size=args.page_size,
                     prefix_cache=args.prefix_cache,
                     max_queue=args.max_queue,
                     deadline_ms=args.deadline_ms,
                     ttft_deadline_ms=args.ttft_deadline_ms,
                     preempt_policy=args.preempt_policy,
                     snapshot_every_ticks=snapshot_every,
                     chaos=chaos_cfg)
    if disagg:
        sc = DisaggServeConfig(prefill_pods=max(1, args.prefill_pods),
                               decode_pods=max(1, args.decode_pods),
                               pp_stages=max(1, args.pp),
                               **sc_kwargs)
        engine = DisaggServingEngine(cfg, params, sc)
        print(f"disagg: {sc.prefill_pods} prefill + {sc.decode_pods} decode "
              f"pod(s), pp={sc.pp_stages}, prefill_chunk="
              f"{engine.prefill_chunk}")
    else:
        if args.pp > 1:
            ap.error("--pp requires disaggregated serving "
                     "(--prefill-pods/--decode-pods)")
        sc = ServeConfig(**sc_kwargs)
        engine = ServingEngine(cfg, params, sc)
    if chaos_cfg is not None:
        print(f"chaos: {args.chaos} (snapshot every "
              f"{snapshot_every or 'never'} ticks)")
    if args.kv_pages:
        print(f"paged KV: {args.kv_pages} pages x {engine.page_size} tok "
              f"({engine.kv_cache_bytes()/1024:.0f} KiB resident K/V), "
              f"prefix_cache={'on' if args.prefix_cache else 'off'}")
    prompts = [[2 + (i * 7 + j) % 97 for j in range(5 + i % 3)]
               for i in range(args.requests)]
    reqs = [Request(prompt=p, max_new_tokens=args.max_new,
                    temperature=args.temperature, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        try:
            engine.submit(r)
        except AdmissionError as e:
            print(f"req {r.rid}: rejected ({e})")
    t0 = time.time()
    ticks, restarts = 0, 0
    while True:
        try:
            ticks += engine.run_to_completion()
            break
        except EngineCrash as e:
            restarts += 1
            snap = engine.latest_snapshot()
            if snap is None or restarts > args.max_restarts:
                raise
            print(f"engine crashed ({e}); restoring from snapshot "
                  f"[restart {restarts}/{args.max_restarts}]")
            engine = type(engine).restore(cfg, params, sc, snap)
    dt = time.time() - t0
    # a restored engine rebuilt its Request objects from the snapshot:
    # merge by rid so reporting reflects the final state of every stream
    final = {r.rid: r for r in reqs}
    for r in engine.restored_requests:
        final[r.rid] = r
    reqs = [final[r.rid] for r in reqs]
    total = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out_tokens} "
              f"[{r.outcome or 'pending'}]")
    print(f"{total} tokens in {dt:.2f}s ({total/max(dt,1e-9):.1f} tok/s, "
          f"{ticks} ticks)")
    if restarts:
        print(f"recovered from {restarts} injected crash(es)")
    print(phase_report(engine, reqs))
    return reqs


if __name__ == "__main__":
    main()
