import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks device count on first init.

import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config, get_shape, cell_is_runnable
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import OptConfig
from repro.parallel import sharding
from repro.roofline import hlo as hlo_lib
from repro.roofline import model as roof


def opt_config_for(cfg) -> OptConfig:
    # >=50B params: bf16 moments + bf16 stored params with fp32 master
    # (DESIGN.md §Memory budget)
    big = cfg.param_count() > 5e10
    return OptConfig(moment_dtype="bfloat16" if big else "float32",
                     master_weights=big)


def model_config_for(arch: str):
    import dataclasses
    cfg = get_config(arch)
    if cfg.param_count() > 5e10:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    # perf-iteration knobs (EXPERIMENTS.md §Perf): override via env
    if os.environ.get("REPRO_REMAT"):
        cfg = dataclasses.replace(cfg, remat=os.environ["REPRO_REMAT"])
    if os.environ.get("REPRO_KV_CHUNK"):
        cfg = dataclasses.replace(
            cfg, attn_kv_chunk=int(os.environ["REPRO_KV_CHUNK"]))
    return cfg


# Gradient-accumulation depth per arch for the train_4k cell: chosen so the
# activation working set fits 16GB v5e HBM (EXPERIMENTS.md §Dry-run).
MICROBATCHES = {
    "jamba-1.5-large-398b": 16,
    "llama-3.2-vision-90b": 8,
    "mixtral-8x22b": 8,
    "qwen3-moe-30b-a3b": 4,
    "qwen2.5-14b": 2,
    "stablelm-12b": 2,
    "llama3-8b": 2,
}


def build_lowerable(cfg, shape, mesh):
    """Returns (fn, arg_specs, arg_shardings, donate_argnums)."""
    ns = lambda tree: sharding.named(tree, mesh)
    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        fn = api.make_train_step(cfg, opt_cfg,
                                 MICROBATCHES.get(cfg.name, 1))
        specs = (api.abstract_params(cfg),
                 api.abstract_opt_state(cfg, opt_cfg),
                 api.batch_specs(cfg, shape))
        shardings = (ns(api.param_pspecs(cfg, mesh)),
                     ns(api.opt_pspecs(cfg, opt_cfg, mesh)),
                     ns(api.batch_pspecs(cfg, shape, mesh)))
        return fn, specs, shardings, (0, 1)
    if shape.kind == "prefill":
        fn = api.make_prefill_step(cfg)
        specs = (api.abstract_params(cfg),
                 api.batch_specs(cfg, shape, with_labels=False))
        shardings = (ns(api.param_pspecs(cfg, mesh)),
                     ns(api.batch_pspecs(cfg, shape, mesh,
                                         with_labels=False)))
        return fn, specs, shardings, ()
    # decode
    fn = api.make_serve_step(cfg)
    cache, tok, pos = api.decode_specs(cfg, shape)
    cache_ps, tok_ps, pos_ps = api.decode_pspecs(cfg, shape, mesh)
    specs = (api.abstract_params(cfg), cache, tok, pos)
    shardings = (ns(api.param_pspecs(cfg, mesh)), ns(cache_ps),
                 ns(tok_ps), ns(pos_ps))
    return fn, specs, shardings, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str = "") -> dict:
    cfg = model_config_for(arch)
    shape = get_shape(shape_name)
    ok, why = cell_is_runnable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    fn, specs, shardings, donate = build_lowerable(cfg, shape, mesh)
    act_rules = sharding.activation_rules(mesh, shape.global_batch, cfg,
                                          kind=shape.kind)
    with mesh, sharding.use_activation_rules(act_rules):
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # jax >= 0.4.3x: one dict per program
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(txt)
    hl = hlo_lib.analyze(txt)
    mf = roof.model_flops(cfg, shape)
    terms = roof.terms_from_analysis(hl)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "n_devices": n_dev,
        "mesh": list(mesh.shape.values()), "axis_names": list(mesh.axis_names),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "per_device_gib": round(per_dev_bytes / 2**30, 3),
            "fits_16g_hbm": bool(per_dev_bytes < 16 * 2**30),
        },
        "cost_analysis": {"flops": ca.get("flops", 0.0),
                          "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "hlo": hl,
        "model_flops": mf,
        "roofline": {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "dominant": terms.dominant,
            "bound_s": terms.bound_s,
            "roofline_fraction": terms.roofline_fraction,
            "useful_flops_ratio": (
                mf["model_flops"] /
                max(hl["flops_per_device"] * n_dev, 1.0)),
            "useful_flops_ratio_with_attn": (
                mf["model_flops_with_attn"] /
                max(hl["flops_per_device"] * n_dev, 1.0)),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, args.save_hlo)
    except Exception as e:  # noqa: BLE001 — report failures as data
        res = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multi_pod, "status": "error",
               "error": repr(e), "traceback": traceback.format_exc()}
    js = json.dumps(res, indent=1)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
    if res["status"] == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
