"""End-to-end training driver.

CPU-runnable with --reduced (smoke/examples); on real fleets the same driver
runs under the production mesh (launch.mesh) with per-host data sharding,
async checkpointing, fault-tolerant restart, and optional int8 error-feedback
gradient compression on the data axis.

Example (the ~100M-param end-to-end run used by examples/train_lm.py):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --d-model 512 --n-layers 8 --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data import DataConfig, make_pipeline
from repro.models import api, lm
from repro.optim import OptConfig, adamw_init
from repro.launch.mesh import make_host_mesh
from repro.parallel import sharding


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-path", default="")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrayflex-report", action="store_true",
                    help="print the ArrayFlex GEMM plan for this model")
    return ap


def build_config(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
        overrides["head_dim"] = max(16, args.d_model // cfg.n_heads)
    if args.n_layers:
        overrides["n_layers"] = args.n_layers
    if args.d_ff:
        overrides["d_ff"] = args.d_ff
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = build_config(args)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    if args.arrayflex_report:
        from repro.core import planner
        from repro.configs.base import ShapeConfig
        shp = ShapeConfig("train", args.seq, args.batch, "train")
        rep = planner.plan_model(cfg, shp)
        print(f"ArrayFlex plan: latency saving "
              f"{rep['latency_saving']*100:.1f}% "
              f"power saving {rep['power_saving']*100:.1f}% "
              f"EDP gain {rep['edp_gain']:.2f}x")
        for p in rep["plans"][:8]:
            print(f"  {p.gemm.name:14s} M={p.gemm.M:6d} N={p.gemm.N:6d} "
                  f"T={p.gemm.T:8d} k={p.k} (khat={p.k_hat:.2f})")

    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    opt_cfg = OptConfig(lr=args.lr, total_steps=max(args.steps, 10))
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, key)
    opt_state = adamw_init(params, opt_cfg)
    train_step = jax.jit(api.make_train_step(cfg, opt_cfg),
                         donate_argnums=(0, 1))

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab_size=cfg.vocab_size, seed=args.seed,
                    path=args.data_path)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        restored, rstep = ckpt.restore({"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = rstep
            print(f"resumed from step {start_step}")
    pipe = make_pipeline(dc, start_step=start_step)

    act_rules = sharding.activation_rules(mesh, args.batch, cfg)
    losses = []
    t0 = time.time()
    with mesh, sharding.use_activation_rules(act_rules):
        for step in range(start_step, args.steps):
            _, batch = next(pipe)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = train_step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                toks = args.batch * args.seq * (step - start_step + 1)
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"tok/s {toks/max(dt,1e-9):,.0f}")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1,
                                {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.wait()
    pipe.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
