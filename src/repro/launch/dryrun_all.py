"""Sweep orchestrator: run every (arch x shape x mesh) dry-run cell.

Each cell runs in its own subprocess (jax locks the fake-device count at
first init, and failures must not kill the sweep).  Results land in
results/dryrun/<arch>_<shape>_<mesh>.json and are summarized to stdout.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_all [--multi-pod-only]
      [--single-pod-only] [--arch A] [--shape S] [--force] [--jobs N]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCHS, SHAPES

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def cell_path(arch, shape, multi_pod):
    mesh = "2pod" if multi_pod else "1pod"
    return os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh}.json")


def run_cell(arch, shape, multi_pod, force=False, timeout=2400):
    out = cell_path(arch, shape, multi_pod)
    if not force and os.path.exists(out):
        try:
            r = json.load(open(out))
            if r.get("status") in ("ok", "skipped"):
                return r, True
        except Exception:
            pass
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        if os.path.exists(out):
            return json.load(open(out)), False
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "error",
                "error": (proc.stderr or proc.stdout)[-2000:]}, False
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "timeout", "elapsed_s": time.time() - t0}, False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]
    cells = []
    for mp in meshes:
        for a in ARCHS:
            if args.arch and a != args.arch:
                continue
            for s in SHAPES:
                if args.shape and s != args.shape:
                    continue
                cells.append((a, s, mp))
    n_ok = n_skip = n_err = 0
    t_start = time.time()
    for i, (a, s, mp) in enumerate(cells):
        r, cached = run_cell(a, s, mp, force=args.force)
        st = r.get("status")
        tag = "cached" if cached else f"{r.get('compile_s', 0):.0f}s"
        if st == "ok":
            n_ok += 1
            roof = r.get("roofline", {})
            print(f"[{i+1}/{len(cells)}] OK   {a:26s} {s:12s} "
                  f"{'2pod' if mp else '1pod'} {tag:7s} "
                  f"mem={r['memory']['per_device_gib']:8.2f}GiB "
                  f"dom={roof.get('dominant', '?'):10s} "
                  f"useful={roof.get('useful_flops_ratio', 0):.3f}")
        elif st == "skipped":
            n_skip += 1
            print(f"[{i+1}/{len(cells)}] SKIP {a:26s} {s:12s} "
                  f"{'2pod' if mp else '1pod'} — {r.get('reason')}")
        else:
            n_err += 1
            print(f"[{i+1}/{len(cells)}] ERR  {a:26s} {s:12s} "
                  f"{'2pod' if mp else '1pod'} — "
                  f"{str(r.get('error', st))[:200]}")
        sys.stdout.flush()
    print(f"\nDone in {time.time()-t_start:.0f}s: "
          f"{n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
