"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod = 16x16 v5e (256 chips); multi-pod
adds a leading 'pod' axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the jax version has it (>= 0.5 explicit
    sharding); older versions take no such argument."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1, *, strict: bool = False):
    """(data, model) mesh over whatever devices exist (tests / smoke runs).

    Degenerate requests clamp to a valid mesh: each axis size is at least 1
    (``data=0`` or ``data > n`` no longer yields a zero/invalid axis) and
    the product never exceeds the device count — the mesh simply uses the
    first ``data * model`` devices.  ``strict=True`` raises instead when
    the requested shape does not fit, with the CPU fan-out hint (the
    sharded-dispatch path wants the exact mesh it planned for, not a
    silently clamped one).
    """
    devs = jax.devices()
    n = len(devs)
    if strict:
        if data < 1 or model < 1:
            raise ValueError(
                f"mesh axis sizes must be >= 1, got (data={data}, "
                f"model={model})")
        if data * model > n:
            raise ValueError(
                f"mesh (data={data}, model={model}) needs {data * model} "
                f"devices but only {n} exist; on CPU set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "(or pass --host-devices to repro.launch.serve)")
    data = max(1, min(data, n))
    model = max(1, min(model, n // data))
    use = np.asarray(devs[:data * model]).reshape(data, model)
    return Mesh(use, ("data", "model"))
