"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod = 16x16 v5e (256 chips); multi-pod
adds a leading 'pod' axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
