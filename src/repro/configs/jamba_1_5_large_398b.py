"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536  [arXiv:2403.19887; hf]

Jamba blocks have period 8: one attention layer (index 4 within the period)
and seven Mamba layers; every other layer carries a 16-expert top-2 MoE MLP.
NOTE (DESIGN.md §Arch-applicability): the SSM layers use the Mamba-2 SSD
formulation rather than Jamba's original Mamba-1 selective scan — the SSD
dual form is the TPU-native (matmul-friendly) expression of the same SSM.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    hybrid_period=8,
    hybrid_attn_index=4,
    moe=MoEConfig(num_experts=16, top_k=2, moe_every=2, moe_offset=1,
                  expert_d_ff=24576),
    ssm=SSMConfig(d_state=128, head_dim=128, n_groups=1),
    rope_theta=1e6,
)
