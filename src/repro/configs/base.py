"""Configuration dataclasses for the ArrayFlex-JAX framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; every
input-shape cell as a :class:`ShapeConfig`.  Configs are plain frozen
dataclasses so they hash, print, and round-trip through the launcher CLI.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard/Mixtral-style token-choice)."""

    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # Which layers are MoE: every `moe_every`-th layer starting at `moe_offset`.
    moe_every: int = 1
    moe_offset: int = 0
    # d_ff of each expert (may differ from the dense d_ff).
    expert_d_ff: int = 0
    # Number of shared (always-on) experts, DeepSeek-style.  0 for the pool.
    num_shared_experts: int = 0
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) sub-config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per assigned arch."""

    name: str = "unnamed"
    # dense | moe | hybrid | ssm | vlm | audio
    family: str = "dense"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 32000
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # Sliding-window attention; 0 disables.
    sliding_window: int = 0
    # MoE / SSM sub-configs (None when not used by the family).
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Jamba): period of the attn/mamba interleave, and which index
    # within each period is the attention layer.
    hybrid_period: int = 8
    hybrid_attn_index: int = 4
    # vlm: cross-attention layers every `cross_attn_every` layers.
    cross_attn_every: int = 5
    n_image_tokens: int = 1600
    d_frontend: int = 1280       # raw vision/audio embedding width (pre-projection)
    # audio (enc-dec): number of encoder layers (decoder gets n_layers).
    n_encoder_layers: int = 0
    max_source_positions: int = 1500
    # --- numerics / execution policy -------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # remat: "none" | "dots" | "full"
    remat: str = "full"
    scan_layers: bool = True
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    # use dense (unchunked) attention below this seq_len
    attn_dense_below: int = 2048
    logit_softcap: float = 0.0
    # --- ArrayFlex integration -------------------------------------------
    # Execution backend every model GEMM dispatches through
    # (kernels.substrate registry): "xla" (plain x @ w, the default),
    # "arrayflex" (Pallas K-collapse kernel at the planner's Eq.(6) k),
    # "arrayflex_int8" (same kernel on memoized int8 weights +
    # per-output-channel fp32 scales, fp32 accumulation, k planned with
    # the int8 datapath timing), "arrayflex_w8a8" (int8 weights AND
    # dynamic per-tile int8 activations quantized in-kernel, int8 x int8
    # -> int32 accumulation, k planned with the w8a8 datapath timing
    # plus the Eq.(5') quantize boundary term), "ref" (fp32 oracle).
    # Validated against
    # substrate.backends() at the execution entry points (lm.forward /
    # decode_step / prefill_step, the serving engine, serve.py) so an
    # unknown name fails with the registered list, not deep in dispatch.
    gemm_backend: str = "xla"
    # Pallas interpret-mode override threaded to every kernel launch.
    # None resolves via the REPRO_PALLAS_INTERPRET env var, else the
    # default (compiled on real TPU backends, interpreted elsewhere) —
    # see kernels.runtime.resolve_interpret.  True/False force it.
    pallas_interpret: Optional[bool] = None
    # --- SPMD sharded dispatch -------------------------------------------
    # (data, model) host-mesh axis sizes for sharded GEMM dispatch; ()
    # runs unsharded.  The lm entry points activate the mesh from this
    # field (parallel.sharding.mesh_from_config), so the substrate plans
    # on post-partition per-shard shapes and runs each device's GEMM
    # under jax.shard_map (TP 'wo'-style contractions psum at the
    # collapsed-block boundary).
    mesh_shape: Tuple[int, ...] = ()
    # "auto" shards dispatch whenever mesh_shape declares a mesh; "none"
    # keeps replicated dispatch (the planner then sees logical shapes).
    gemm_sharding: str = "auto"
    # --- disaggregated pod roles / pipeline sharding ----------------------
    # A 3-axis mesh_shape (pod, data, model) pipelines layers over the
    # 'pod' axis with GPipe collective_permute stages
    # (parallel.pipeline).  pp_role tags which serving phase this config
    # plans for — "" (colocated), "prefill" (compute-bound: the
    # stage-boundary send prices as an Eq.(5') boundary op, pushing
    # best_k DEEPER), or "decode" (latency-bound: the stage ingress
    # serializes as Eq.(6'') transfer cycles, pushing best_k SHALLOWER).
    # The role is part of the plan-cache key via the shard signature, so
    # prefill pods and decode pods legitimately hold different plans for
    # the same GEMM shape.
    pp_role: str = ""
    # Pipeline stages over the 'pod' axis; 0/1 disables pipelining.
    pp_stages: int = 0
    # First device index of this role's pod window — a disaggregated
    # engine places prefill pods at [0, P) and decode pods at [P, P+D).
    pod_offset: int = 0

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over any mesh axis."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        ssm = self.ssm or SSMConfig()
        return ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        ssm = self.ssm or SSMConfig()
        return self.d_inner // ssm.head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return i % self.hybrid_period == self.hybrid_attn_index
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return i % self.moe.moe_every == self.moe.moe_offset

    def is_cross_attn_layer(self, i: int) -> bool:
        if self.family != "vlm":
            return False
        return i % self.cross_attn_every == (self.cross_attn_every - 1)

    # ---- parameter counting (used by roofline MODEL_FLOPS) --------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        q_dim = self.n_heads * hd
        kv_dim = self.n_kv_heads * hd
        attn = d * q_dim + 2 * d * kv_dim + q_dim * d
        dense_mlp = 3 * d * ff
        ssm = self.ssm or SSMConfig()
        d_in = self.d_inner
        bc = 2 * ssm.n_groups * ssm.d_state
        nh = self.ssm_heads
        mamba = d * (2 * d_in + bc + nh) + d_in * d + ssm.d_conv * (d_in + bc)
        total = 0
        n_layers = self.n_layers + self.n_encoder_layers
        for i in range(self.n_layers):
            if self.family == "ssm":
                total += mamba
            elif self.family == "hybrid":
                total += attn if self.is_attn_layer(i) else mamba
            else:
                total += attn
            if self.is_cross_attn_layer(i):
                total += attn  # cross-attention projections
            if self.is_moe_layer(i):
                m = self.moe
                eff = m.expert_d_ff or ff
                n_e = (m.top_k + m.num_shared_experts) if active_only else (
                    m.num_experts + m.num_shared_experts)
                total += 3 * d * eff * n_e + d * m.num_experts
            elif self.family != "ssm" or self.d_ff:
                if self.d_ff:
                    total += dense_mlp
        for _ in range(self.n_encoder_layers):
            total += attn + dense_mlp
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        total += n_layers * 2 * d + d  # norms
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    # train | prefill | decode
    kind: str = "train"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=max(2, cfg.hybrid_period) if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        n_image_tokens=16 if cfg.family == "vlm" else cfg.n_image_tokens,
        cross_attn_every=2 if cfg.family == "vlm" else cfg.cross_attn_every,
        d_frontend=32,
        attn_dense_below=4096,
        remat="none",
        max_source_positions=64,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, expert_d_ff=128)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
