"""Architecture & shape registry.

``get_config(name)`` returns the full published config; ``get_shape(name)``
one of the four assigned input-shape cells; ``reduced(cfg)`` a smoke-test
sized config of the same family.
"""
from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, reduced,
)

from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.llama_3_2_vision_90b import CONFIG as _llamavis
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.mamba2_370m import CONFIG as _mamba2

ARCHS = {c.name: c for c in (
    _jamba, _mixtral, _qwen3moe, _llamavis, _qwen2,
    _llama3, _qwen25, _stablelm, _whisper, _mamba2,
)}

# Sub-quadratic (or bounded-KV) archs that can run the 500k-token decode cell.
# Pure full-attention archs skip long_500k (see DESIGN.md §Arch-applicability);
# mixtral qualifies via its 4096-token sliding window (bounded KV).
LONG_CONTEXT_ARCHS = {"jamba-1.5-large-398b", "mamba2-370m", "mixtral-8x22b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_runnable(arch: str, shape: str) -> (bool, str):
    """Whether (arch x shape) is a live dry-run cell, and why not if not."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "long_500k needs sub-quadratic attention (full-attn arch)"
    return True, ""


def all_cells():
    """Every live (arch, shape) pair."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            ok, _ = cell_is_runnable(a, s)
            if ok:
                out.append((a, s))
    return out
