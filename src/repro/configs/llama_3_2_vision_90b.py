"""llama-3.2-vision-90b [vlm] — decoder with interleaved image cross-attention.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings of width ``d_frontend``; a learned projection
maps them to d_model and every 5th layer cross-attends to them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    cross_attn_every=5,
    n_image_tokens=1600,
    d_frontend=1280,
    rope_theta=5e5,
)
