"""whisper-base [audio] — encoder-decoder; conv frontend is a STUB.

6L(dec)+6L(enc) d_model=512 8H d_ff=2048 vocab=51865  [arXiv:2212.04356]

Per the assignment the conv frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (batch, frames, d_model).  ``seq_len`` of each
shape cell is interpreted as the number of encoder frames; the decoder length
is seq_len // 8 for train/prefill, and for decode shapes the decoder KV cache
is seq_len long while cross-attending to ``max_source_positions`` frames.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    max_source_positions=1500,
    rope_theta=1e4,
)
