"""mamba2-370m [ssm] — attention-free, SSD (state-space duality).

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,          # unused (attention-free); kept for head_dim bookkeeping
    n_kv_heads=16,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
)
