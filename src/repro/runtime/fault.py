"""Fault tolerance: heartbeats, straggler detection, checkpoint-restart.

At 1000+ nodes, MTBF is minutes-to-hours; the framework's contract is:

  * every host heartbeats every step (step index + step wall-time),
  * the monitor flags DEAD hosts (no heartbeat within `dead_after_s`) and
    STRAGGLERS (step time > `straggler_factor` x the fleet median —
    mitigation: the launcher excludes them at the next restart boundary and
    the elastic planner (runtime.elastic) re-shards),
  * the training driver checkpoints asynchronously every `ckpt_every` steps
    and restarts from the latest durable step on failure, replaying the
    deterministic data pipeline from that step (data.pipeline contract).

On a single-process CPU container the monitor runs in-process (hosts are
simulated), but the logic is the same one a GCS/etcd-backed deployment uses;
tests/test_runtime.py drives failure and straggler scenarios through it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import numpy as np


@dataclass
class Heartbeat:
    step: int
    step_time_s: float
    wall_time: float


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, dead_after_s: float = 60.0,
                 straggler_factor: float = 2.0, clock=time.monotonic):
        self.n_hosts = n_hosts
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.beats: Dict[int, Heartbeat] = {}

    def beat(self, host: int, step: int, step_time_s: float):
        self.beats[host] = Heartbeat(step, step_time_s, self.clock())

    def dead_hosts(self):
        now = self.clock()
        out = []
        for h in range(self.n_hosts):
            hb = self.beats.get(h)
            if hb is None or now - hb.wall_time > self.dead_after_s:
                out.append(h)
        return out

    def stragglers(self):
        times = [hb.step_time_s for hb in self.beats.values()]
        if len(times) < max(2, self.n_hosts // 2):
            return []
        med = float(np.median(times))
        return [h for h, hb in self.beats.items()
                if hb.step_time_s > self.straggler_factor * med]


# exceptions the restart loop treats as recoverable node failures by
# default: hardware/runtime crashes and I/O errors.  Programming errors
# (TypeError, ValueError, ...) propagate — restarting cannot fix them and
# retrying silently would loop max_restarts times before surfacing.
RECOVERABLE = (RuntimeError, OSError)


@dataclass
class FaultToleranceManager:
    """Drives the checkpoint-restart loop around a train step."""

    ckpt_manager: object                  # checkpoint.CheckpointManager
    monitor: HeartbeatMonitor
    ckpt_every: int = 100
    max_restarts: int = 100
    host_index: int = 0                   # this process's host id for beats
    restarts: int = field(default=0)
    cold_restarts: int = field(default=0)

    def should_checkpoint(self, step: int) -> bool:
        return step > 0 and step % self.ckpt_every == 0

    def health_action(self) -> str:
        """'ok' | 'restart' (dead host) | 'replan' (stragglers only)."""
        if self.monitor.dead_hosts():
            return "restart"
        if self.monitor.stragglers():
            return "replan"
        return "ok"

    def run(self, state, step_fn: Callable, data_source, n_steps: int,
            inject_failure: Optional[Callable] = None,
            recoverable: tuple = RECOVERABLE,
            cold_restart: str = "raise"):
        """Resumable loop: state must be a pytree the ckpt manager can save.

        `step_fn(state, batch) -> state`; `inject_failure(step)` raises to
        simulate a crash (tests).  Returns (state, steps_run, restarts).

        Only exceptions in `recoverable` trigger checkpoint-restart
        (default: :data:`RECOVERABLE` — runtime/hardware and I/O errors);
        everything else propagates immediately.  A failure with *no*
        durable checkpoint is a **cold restart**: `cold_restart="raise"`
        (default) re-raises the original exception — replaying from step 0
        silently is almost never what a production job wants — while
        `"restart"` opts in to the replay, counted in `cold_restarts`
        (training state must be rebuilt by the caller's step-0 semantics:
        the initial `state` object is reused as passed).
        """
        if cold_restart not in ("raise", "restart"):
            raise ValueError(f"cold_restart={cold_restart!r}: "
                             f"expected 'raise' or 'restart'")
        init_state = state
        start = self.ckpt_manager.latest_step()
        if start is not None:
            state, start = self.ckpt_manager.restore(state)
        step = 0 if start is None else start
        while step < n_steps:
            try:
                if inject_failure is not None:
                    inject_failure(step)
                t0 = time.monotonic()
                batch = data_source.batch_at(step)
                state = step_fn(state, batch)
                self.monitor.beat(self.host_index, step,
                                  time.monotonic() - t0)
                step += 1
                if self.should_checkpoint(step):
                    self.ckpt_manager.save_async(step, state)
            except recoverable:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.ckpt_manager.wait()
                restored, rstep = self.ckpt_manager.restore(state)
                if restored is not None:
                    state, step = restored, rstep
                elif cold_restart == "restart":
                    self.cold_restarts += 1
                    state, step = init_state, 0
                else:
                    raise
        self.ckpt_manager.wait()
        return state, step, self.restarts
