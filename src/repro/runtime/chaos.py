"""Deterministic, seeded fault injection for the serving stack.

Chaos engineering only pays off if a failure found once can be replayed
forever, so every injection decision here is a pure function of
``(seed, injection point, per-point draw index)`` — independent of wall
clock, interleaving, and of how many *other* points drew before it.  A
failing seed from CI reproduces bit-identically on a laptop.

Injection points (registered by the code under test, fired through the
ambient :func:`fire`):

=====================  ====================================================
``substrate.dispatch``  a GEMM launch fault (``KernelFault``) at the
                        substrate dispatch entry (``kernels/substrate.py``)
                        — fires at jit-trace time, i.e. at the
                        launch/trace boundary of a compiled step
``engine.sample``       corrupt a decode tick's logits to NaN/Inf before
                        sampling (``serving/engine.py``)
``pool.alloc``          report page-pool exhaustion even when pages are
                        free (``serving/paged.py``)
``engine.tick``         kill the engine mid-stream (``EngineCrash``) at a
                        tick boundary (``serving/engine.py``)
``transfer.kv``         drop a pod->pod K/V handoff in the disaggregated
                        engine (``TransferFault``; ``serving/disagg.py``)
``disagg.pod``          kill a decode pod mid-stream — resident sequences
                        preempt and re-admit through prefill recompute
                        (``serving/disagg.py``)
=====================  ====================================================

Probabilities are drawn per *draw index* ``n`` via
``random.Random(f"{seed}:{point}:{n}")``; the deterministic ``*_at``
triggers fire exactly at draw ``n == at`` (CI pins faults with these).
The draw counters and the fired-event log (``chaos_draws`` /
``chaos_log``) are chaos-owned mutable state: the AFL03 lint confines
their mutation to this module, and they ride along in engine snapshots so
a restored engine continues the *same* replayable draw sequence.

This module imports nothing from ``repro`` (stdlib only): the substrate
and the paged allocator reach it lazily without import cycles.
"""
from __future__ import annotations

import contextlib
import contextvars
import random
from dataclasses import dataclass, fields, replace
from typing import Dict, List, Optional, Tuple

# injection point -> (probability field, deterministic-trigger field)
POINT_FIELDS = {
    "substrate.dispatch": ("gemm_fault", "gemm_fault_at"),
    "engine.sample": ("nan_logits", "nan_logits_at"),
    "pool.alloc": ("page_exhaust", "page_exhaust_at"),
    "engine.tick": ("crash", "crash_at"),
    "transfer.kv": ("kv_transfer", "kv_transfer_at"),
    "disagg.pod": ("pod_lost", "pod_lost_at"),
}

# parse_spec key -> config field (short names for the --chaos flag)
_SPEC_KEYS = {
    "seed": "seed",
    "gemm": "gemm_fault", "gemm_at": "gemm_fault_at",
    "nan": "nan_logits", "nan_at": "nan_logits_at",
    "pages": "page_exhaust", "pages_at": "page_exhaust_at",
    "crash": "crash", "crash_at": "crash_at",
    "kv": "kv_transfer", "kv_at": "kv_transfer_at",
    "pod": "pod_lost", "pod_at": "pod_lost_at",
}


@dataclass(frozen=True)
class ChaosConfig:
    """Per-point fault probabilities (``0.0`` = off) and deterministic
    draw-index triggers (``-1`` = off; ``n`` fires exactly at draw n)."""

    seed: int = 0
    gemm_fault: float = 0.0
    nan_logits: float = 0.0
    page_exhaust: float = 0.0
    crash: float = 0.0
    kv_transfer: float = 0.0
    pod_lost: float = 0.0
    gemm_fault_at: int = -1
    nan_logits_at: int = -1
    page_exhaust_at: int = -1
    crash_at: int = -1
    kv_transfer_at: int = -1
    pod_lost_at: int = -1

    def without_crash(self) -> "ChaosConfig":
        """The same faults minus the mid-stream kill — what a restored
        engine inherits by default, so crash replay cannot livelock on
        re-raising the crash it just recovered from."""
        return replace(self, crash=0.0, crash_at=-1)


def parse_spec(spec: str) -> ChaosConfig:
    """``"seed=3,gemm=0.05,nan_at=2,crash=0.01"`` -> :class:`ChaosConfig`.
    Keys: seed, gemm, nan, pages, crash (+ ``_at`` variants)."""
    kw = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if "=" not in part:
            raise ValueError(f"chaos spec entry {part!r}: expected key=value")
        key, _, val = part.partition("=")
        field = _SPEC_KEYS.get(key.strip())
        if field is None:
            raise ValueError(f"unknown chaos spec key {key!r} "
                             f"(known: {', '.join(sorted(_SPEC_KEYS))})")
        typ = {f.name: f.type for f in fields(ChaosConfig)}[field]
        kw[field] = int(val) if typ == "int" else float(val)
    return ChaosConfig(**kw)


class ChaosEngine:
    """Draw state for one engine: per-point draw counters + fired log.

    ``fire(point)`` advances the point's counter and decides from
    ``Random(f"{seed}:{point}:{n}")`` (or the ``*_at`` trigger) — the
    decision for draw ``n`` never depends on other points' history, which
    is what makes a single seed replay under changed interleavings.
    """

    def __init__(self, config: ChaosConfig):
        self.config = config
        self.chaos_draws: Dict[str, int] = {p: 0 for p in POINT_FIELDS}
        self.chaos_log: List[Tuple[str, int, str]] = []   # (point, n, detail)

    def fire(self, point: str, detail: str = "") -> bool:
        prob_field, at_field = POINT_FIELDS[point]
        n = self.chaos_draws[point]
        self.chaos_draws[point] = n + 1
        prob = getattr(self.config, prob_field)
        at = getattr(self.config, at_field)
        hit = (n == at) or (
            prob > 0.0
            and random.Random(f"{self.config.seed}:{point}:{n}").random()
            < prob)
        if hit:
            self.chaos_log.append((point, n, detail))
        return hit

    # ----------------------------------------------------- snapshot state
    def state_snapshot(self) -> dict:
        """Draw counters + log, pure-python (rides in engine snapshots)."""
        return {"config": {f.name: getattr(self.config, f.name)
                           for f in fields(ChaosConfig)},
                "draws": dict(self.chaos_draws),
                "log": [list(e) for e in self.chaos_log]}

    def load_state(self, snap: dict) -> None:
        self.chaos_draws.update(snap["draws"])
        self.chaos_log[:] = [tuple(e) for e in snap["log"]]

    @staticmethod
    def config_from_snapshot(snap: dict) -> ChaosConfig:
        return ChaosConfig(**snap["config"])


# --------------------------------------------------------------------------
# ambient activation: the engine scopes its ChaosEngine around each tick so
# substrate dispatch / page allocation fire without threading a handle
# through every call signature.

_ACTIVE: contextvars.ContextVar[Optional[ChaosEngine]] = \
    contextvars.ContextVar("repro_chaos_active", default=None)


def active() -> Optional[ChaosEngine]:
    return _ACTIVE.get()


def fire(point: str, detail: str = "") -> bool:
    """Fire ``point`` on the ambient engine; False when chaos is off."""
    eng = _ACTIVE.get()
    return eng.fire(point, detail) if eng is not None else False


@contextlib.contextmanager
def scope(engine: Optional[ChaosEngine]):
    """Activate ``engine`` for the duration (None = explicit no-chaos)."""
    token = _ACTIVE.set(engine)
    try:
        yield engine
    finally:
        _ACTIVE.reset(token)
