"""Elastic scaling: re-plan the mesh and re-shard state on fleet changes.

When hosts die or join, the launcher rebuilds the largest valid mesh from
the survivors and *re-shards in place*: parameters keep their logical
PartitionSpecs, so moving to a new mesh is jax.device_put with the new
NamedSharding (XLA emits the minimal resharding collectives).  The data
pipeline re-partitions by (host_index, host_count) — deterministic step
indexing means no sample is lost or duplicated across the transition.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.parallel import sharding as shd


@dataclass(frozen=True)
class ElasticState:
    n_devices: int
    mesh_shape: tuple
    axis_names: tuple


def largest_mesh_shape(n_devices: int, model_parallel: int) -> tuple:
    """Largest (data, model) grid with fixed TP degree."""
    model = min(model_parallel, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model)


def replan_mesh(devices=None, model_parallel: int = 1):
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    data, model = largest_mesh_shape(n, model_parallel)
    dev_grid = np.asarray(devices[:data * model]).reshape(data, model)
    mesh = jax.sharding.Mesh(dev_grid, ("data", "model"))
    return mesh, ElasticState(n, (data, model), ("data", "model"))


def reshard(tree, pspecs, mesh):
    """Move a pytree onto `mesh` under its logical PartitionSpecs."""
    shardings = shd.named(pspecs, mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda x: hasattr(x, "shape"))
