from repro.runtime.fault import FaultToleranceManager, HeartbeatMonitor  # noqa: F401
from repro.runtime.elastic import ElasticState, replan_mesh  # noqa: F401
from repro.runtime.chaos import ChaosConfig, ChaosEngine, parse_spec  # noqa: F401
