from repro.runtime.fault import FaultToleranceManager, HeartbeatMonitor  # noqa: F401
from repro.runtime.elastic import ElasticState, replan_mesh  # noqa: F401
