"""AdamW with global-norm clipping and warmup-cosine schedule (pure JAX).

Moments can be stored in bfloat16 (``moment_dtype``) — at 398B parameters the
fp32 m/v pair alone is 3.2TB, so the giant configs run bf16 moments
(distributed-optimization trick #1; see DESIGN.md §Memory budget).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"   # "bfloat16" for the giant configs
    # Keep a sharded fp32 master copy and store the live params in bf16:
    # halves every FSDP weight all-gather and kills fp32 weight copies on
    # the compute path (used for >=50B models; see DESIGN.md §Memory).
    master_weights: bool = False


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params, cfg: OptConfig):
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, w, g, m, v):
        g = g.astype(jnp.float32) * scale
        w32 = w.astype(jnp.float32)
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        w_new = w32 - lr * (update + wd * w32)
        return (w_new.astype(p.dtype), w_new.astype(w.dtype),
                m_new.astype(m.dtype), v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, masters, grads, state["m"], state["v"])
    new_params, new_master, new_m, new_v = jax.tree_util.tree_transpose(
        jax.tree.structure(params), jax.tree.structure((0, 0, 0, 0)), out)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
