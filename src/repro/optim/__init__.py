from repro.optim.adamw import (  # noqa: F401
    OptConfig, adamw_init, adamw_update, lr_schedule,
)
