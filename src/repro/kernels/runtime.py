"""Kernel execution-policy helpers shared by every Pallas entry point."""
from __future__ import annotations

import os

import jax


def resolve_interpret(value=None) -> bool:
    """Pallas interpret-mode resolution chain.

    Explicit argument (e.g. threaded from ``ModelConfig.pallas_interpret``)
    > ``REPRO_PALLAS_INTERPRET`` env var ("0"/"false"/"no" disable, anything
    else enables) > default: compiled on real TPU backends, interpreted
    everywhere else.  Before this chain existed every kernel hard-coded
    ``interpret=True``, so TPU hardware runs executed the Mosaic emulator.
    """
    if value is not None:
        return bool(value)
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    if env:
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"
