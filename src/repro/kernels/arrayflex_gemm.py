"""ArrayFlex GEMM as a Pallas TPU kernel with configurable K-collapse.

TPU adaptation of the paper's configurable transparent pipelining (DESIGN.md
§Hardware adaptation): the MXU is itself a 128x128 systolic array whose
pipeline we cannot touch, but the *grid schedule* around it exposes the same
cycles-vs-per-step-cost tradeoff.  The collapse factor k fuses k consecutive
K-panels into ONE grid step:

  * fewer sequential grid steps  (the paper's R/k + C/k cycle reduction),
  * larger per-step VMEM working set and serial in-step adder chain
    (the paper's k*(d_CSA + 2 d_mux) clock-period increase),
  * the fp32 VMEM accumulator plays the carry-save register chain: partial
    sums stay in "redundant" form across the k sub-tiles and the final
    cast/store is the carry-propagate add at the collapsed-block boundary.

core.planner.best_k picks k per GEMM shape exactly as the paper picks the
pipeline depth per CNN layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, k_collapse: int, n_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                     # (bm, bk * k)
    w = w_ref[...]                     # (bk * k, bn)
    bk = x.shape[1] // k_collapse
    acc = acc_ref[...]
    # the k-deep "carry-save" chain: k MXU passes accumulate into the same
    # fp32 VMEM accumulator within one grid step
    for i in range(k_collapse):
        acc = acc + jnp.dot(x[:, i * bk:(i + 1) * bk],
                            w[i * bk:(i + 1) * bk, :],
                            preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == n_steps - 1)
    def _store():                      # carry-propagate: resolve + cast once
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def arrayflex_gemm(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128,
                   k_collapse: int = 1, out_dtype=None,
                   interpret: bool = True):
    """X[M,K] @ W[K,N] with K-collapse factor k_collapse.

    Divisibility contract:
      * ``bm`` (clamped to M) must divide M and ``bn`` (clamped to N) must
        divide N — otherwise a ``ValueError`` is raised;
      * empty M, N or K returns an all-zero (M, N) result directly;
      * K may be anything.  The K axis is tiled into
        ``n_steps = ceil(K / (bk * k_collapse))`` collapsed blocks of
        ``k_collapse`` equal sub-tiles each; when K does not fill that grid
        exactly, X and W are zero-padded along K (zeros contribute exactly
        0 to the fp32 accumulator, so the result is exact — previously the
        kernel silently *dropped* trailing K columns whenever the clamped
        block was not divisible by k_collapse, e.g. K=130, k_collapse=4).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: x {x.shape} @ w {w.shape}")
    if k_collapse < 1:
        raise ValueError(f"k_collapse must be >= 1, got {k_collapse}")
    if M == 0 or N == 0 or K == 0:      # empty operand: exact zero result
        return jnp.zeros((M, N), out_dtype or x.dtype)
    bm, bn = min(bm, M), min(bn, N)
    if M % bm or N % bn:
        raise ValueError(
            f"bm must divide M and bn must divide N: "
            f"M={M}, bm={bm}, N={N}, bn={bn}")
    # exact K tiling: choose the sub-tile width so the collapsed block grid
    # covers K with minimal zero padding (never drop columns).
    n_steps = -(-K // (bk * k_collapse))           # ceil
    bk_eff = -(-K // (n_steps * k_collapse))       # ceil
    kk = bk_eff * k_collapse
    K_pad = n_steps * kk
    if K_pad != K:
        x = jnp.pad(x, ((0, 0), (0, K_pad - K)))
        w = jnp.pad(w, ((0, K_pad - K), (0, 0)))
    grid = (M // bm, N // bn, n_steps)
    out_dtype = out_dtype or x.dtype
    kernel = functools.partial(_kernel, k_collapse=k_collapse,
                               n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i, j, s: (i, s)),
            pl.BlockSpec((kk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
