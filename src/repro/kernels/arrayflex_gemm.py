"""ArrayFlex GEMM as a Pallas TPU kernel: configurable K-collapse with
fused epilogues and an expert-batched variant.

TPU adaptation of the paper's configurable transparent pipelining (DESIGN.md
§Hardware adaptation): the MXU is itself a 128x128 systolic array whose
pipeline we cannot touch, but the *grid schedule* around it exposes the same
cycles-vs-per-step-cost tradeoff.  The collapse factor k fuses k consecutive
K-panels into ONE grid step:

  * fewer sequential grid steps  (the paper's R/k + C/k cycle reduction),
  * larger per-step VMEM working set and serial in-step adder chain
    (the paper's k*(d_CSA + 2 d_mux) clock-period increase),
  * the fp32 VMEM accumulator plays the carry-save register chain: partial
    sums stay in "redundant" form across the k sub-tiles and the final
    cast/store is the carry-propagate add at the collapsed-block boundary.

That carry-propagate boundary is exactly where an **epilogue** belongs:
bias add, activation, the gated multiply of a second fused contraction
(dual-GEMM swiglu: ``silu(x@w + b) * (x@w2 + b2)``), and the transformer
sublayer's residual join (``residual + f(x)``, applied after the
activation/gate) are applied to the resolved fp32 accumulator *before*
the single cast/store, so neither the activation nor the residual add
round-trips through HBM.  Eq.(5') in core.timing prices
the fused vector ops into the per-step period and ``best_k`` re-picks k.

The boundary also hosts **int8 dequantization** (``w_scale``/``w2_scale``):
the contraction streams raw int8 weight codes into the fp32 accumulator
and the per-output-channel scale multiply resolves with the
carry-propagate — per-column scales factor out of the K sum, so the
deferred dequant is exact and rides the same boundary ALU the epilogue
does (one extra Eq.(5') op per contraction, priced by
``timing.IntTimingParams``'s int8 datapath coefficients).

The **W8A8** path (``act_quant=True``) adds the other half: each grid
step's activation tile is quantized to int8 with a dynamic symmetric
per-tile fp32 scale in the step prologue — amax over the (bm, kk) tile,
reciprocal scale, round/clip — and the k-deep chain then runs real
int8 x int8 -> int32 MXU passes.  The two scales resolve at different
boundaries, both exact: the per-tile *activation* scale differs per
K-step, so it folds into the fp32 carry accumulator as each step's int32
partial resolves (sum_s x_scale_s * iacc_s); the per-output-channel
*weight* scale is constant across K, factors out of the whole sum, and
rides the carry-propagate ``store_phase`` dequant exactly as in the
weight-only path.  The quantizer stage is priced as the Eq.(5')
``d_actq_ps`` boundary term (``timing.W8A8TimingParams``).  The int32
accumulator cannot overflow: |code| <= 127, so one collapsed block of
kk <= bk * k_collapse = 512 MACs is bounded by 512 * 127^2 ~ 8.3e6,
far inside int32 range.

``arrayflex_expert_gemm`` runs a whole stack of per-expert GEMMs in ONE
``pallas_call`` whose *leading grid dimension is the expert axis* — the
MoE layer's 3E per-layer kernel launches become 3.

core.planner.best_k picks k per GEMM shape exactly as the paper picks the
pipeline depth per CNN layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

# Epilogue activations applicable at the carry-propagate boundary.
ACTIVATIONS = ("none", "silu", "gelu")


def _act(y, activation: str):
    if activation == "none":
        return y
    if activation == "silu":
        return jax.nn.silu(y)
    if activation == "gelu":
        return jax.nn.gelu(y)
    raise ValueError(f"unknown epilogue activation {activation!r}; "
                     f"supported: {ACTIVATIONS}")


def apply_epilogue(y, y2=None, bias=None, bias2=None, activation="none"):
    """The epilogue's reference semantics, shared by the fused kernel's
    store phase and every unfused backend:

        out = act(y [+ bias]) [* (y2 [+ bias2])]

    Operates in the dtype of ``y`` (fp32 inside the kernel; the operands'
    dtype on the unfused xla path, reproducing the pre-fusion op order
    bit for bit).
    """
    if bias is not None:
        y = y + bias.astype(y.dtype)
    out = _act(y, activation)
    if y2 is not None:
        if bias2 is not None:
            y2 = y2 + bias2.astype(y2.dtype)
        out = out * y2
    return out


def prologue_phase(x, norm_scale):
    """The grid step's *prologue* boundary math — the rmsnorm elementwise
    scale fused in front of the contraction: multiply the activation tile
    by the per-input-channel ``g`` in fp32 and cast back to the operand
    dtype.

    This is the SINGLE definition of the fused norm-scale (the kernels
    inline it on each x tile, the unfused backends apply it to the whole
    x, and ``analysis.kernel_check`` traces it to count the boundary op
    against ``Epilogue.ops``).  Because ``nn.layers.rmsnorm_normalize``
    hands the substrate an already-cast normalized x, every backend
    computes the identical ``(x_f32 * g) -> cast`` expression and fused
    vs unfused outputs agree bit for bit.

    Unlike the store-boundary ops, the scale is per-*input*-channel — it
    cannot commute past the K sum to the carry-propagate store, which is
    why it rides the step prologue (the same slot the W8A8 activation
    quantizer occupies) rather than ``store_phase``.
    """
    if norm_scale is None:
        return x
    return (x.astype(jnp.float32)
            * norm_scale.astype(jnp.float32)).astype(x.dtype)


def quantize_tile(x, eps: float = 1e-12):
    """Dynamic symmetric per-tile activation quantization: the W8A8 grid
    step's prologue stage, and the SINGLE definition of the quantizer
    (the kernels inline it; the property tests and the analysis passes
    trace this exact function).

    Returns ``(codes, scale)`` with ``codes`` int8 in [-127, 127] and
    ``scale`` a per-tile fp32 scalar such that ``codes * scale ~= x``
    with error bounded by ``scale / 2 = amax / 254`` per element.  An
    all-zero tile quantizes to all-zero codes (the eps floor keeps the
    reciprocal finite), so zero K-padding tails contribute exactly 0.
    """
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, eps) / 127.0
    codes = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def store_phase(y, y2=None, w_scale=None, w2_scale=None, bias=None,
                bias2=None, activation="none", residual=None):
    """The carry-propagate boundary math, in execution order: dequant the
    resolved fp32 accumulator(s), the fused epilogue, then the residual
    join (``residual + f(x)`` — the sublayer add applies to the finished
    activation/gate output, matching the unfused layers' op order).

    This is the SINGLE definition of what the kernel store applies —
    ``_kernel``/``_expert_kernel`` call it on their accumulator refs, and
    ``analysis.kernel_check`` traces it to count the boundary ops actually
    executed against the ``Epilogue.ops`` pricing (Eq. 5' ``e``), so the
    timing model and the datapath cannot drift apart silently.
    """
    if w_scale is not None:
        y = y * w_scale.astype(jnp.float32)
    if y2 is not None and w2_scale is not None:
        y2 = y2 * w2_scale.astype(jnp.float32)
    out = apply_epilogue(
        y, y2,
        None if bias is None else bias.astype(jnp.float32),
        None if bias2 is None else bias2.astype(jnp.float32),
        activation)
    if residual is not None:
        out = residual.astype(jnp.float32) + out
    return out


# ---------------------------------------------------------------------------
# single-GEMM kernel (optionally dual-contraction) with fused epilogue

def _kernel(*refs, k_collapse: int, n_steps: int, activation: str,
            dual: bool, quant: bool, act_quant: bool, has_b: bool,
            has_b2: bool, has_r: bool, has_g: bool):
    """refs = x, w, [w2], [scale], [scale2], [b], [b2], [r], [g], o, acc,
    [acc2] (inputs, outputs, scratch — in pallas_call order).  ``has_r``:
    an (M, N) residual stream tiled like the output joins at the store,
    after the activation/gate.  ``has_g``: a (K,) rmsnorm scale, tiled
    with x's K axis, multiplies this step's x tile in the prologue
    (:func:`prologue_phase`) before the contraction — and before the
    W8A8 quantizer, so the quantizer sees the same values the unfused
    path would hand it.

    ``quant``: w (and w2) hold int8 codes with per-output-channel fp32
    scales; the contraction accumulates the raw codes and the dequant
    multiply resolves at the carry-propagate ``_store`` — the per-column
    scale factors out of the K sum, so deferring it is exact and the
    scale rides the same boundary ALU the epilogue does.

    ``act_quant`` (W8A8, requires ``quant``): the step's x-tile is
    quantized to int8 with one dynamic per-tile fp32 scale in the
    prologue, the k-chain runs int8 x int8 -> int32 dots, and the int32
    partial folds into the fp32 carry accumulator scaled by this step's
    tile scale (per-step fold: the scale differs across K-steps, so only
    the K-constant weight scale defers to the store)."""
    i = 2
    x_ref, w_ref = refs[0], refs[1]
    w2_ref = refs[i] if dual else None
    i += dual
    s_ref = refs[i] if quant else None
    i += quant
    s2_ref = refs[i] if (quant and dual) else None
    i += quant and dual
    b_ref = refs[i] if has_b else None
    i += has_b
    b2_ref = refs[i] if has_b2 else None
    i += has_b2
    r_ref = refs[i] if has_r else None
    i += has_r
    g_ref = refs[i] if has_g else None
    i += has_g
    o_ref = refs[i]
    acc_ref = refs[i + 1]
    acc2_ref = refs[i + 2] if dual else None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if dual:
            acc2_ref[...] = jnp.zeros_like(acc2_ref)

    x = x_ref[...]                     # (bm, bk * k)
    if has_g:                          # prologue: fused rmsnorm scale on
        x = prologue_phase(x, g_ref[...])   # this step's K slice
    w = w_ref[...]                     # (bk * k, bn)
    w2 = w2_ref[...] if dual else None
    if quant and not act_quant:        # int8 codes ride the MXU in x's dtype
        w = w.astype(x.dtype)          # (exact: |code| <= 127)
        if dual:
            w2 = w2.astype(x.dtype)
    bk = x.shape[1] // k_collapse
    acc = acc_ref[...]
    acc2 = acc2_ref[...] if dual else None
    if act_quant:
        # W8A8: quantize this step's x-tile once (the Eq.(5') d_actq
        # boundary stage), run the k-chain as int8 x int8 -> int32, and
        # fold the per-tile scale as the int32 partial resolves.  Bound:
        # kk <= 512 codes of |.| <= 127 -> |iacc| <= 512 * 127^2, no
        # int32 overflow.
        qx, x_scale = quantize_tile(x)
        iacc = jnp.zeros(acc_ref.shape, jnp.int32)
        iacc2 = jnp.zeros(acc_ref.shape, jnp.int32) if dual else None
        for i in range(k_collapse):
            qs = qx[:, i * bk:(i + 1) * bk]
            ws = slice(i * bk, (i + 1) * bk)
            iacc = iacc + jnp.dot(qs, w[ws, :],
                                  preferred_element_type=jnp.int32)
            if dual:
                iacc2 = iacc2 + jnp.dot(qs, w2[ws, :],
                                        preferred_element_type=jnp.int32)
        acc = acc + iacc.astype(jnp.float32) * x_scale
        if dual:
            acc2 = acc2 + iacc2.astype(jnp.float32) * x_scale
    else:
        # the k-deep "carry-save" chain: k MXU passes accumulate into the
        # same fp32 VMEM accumulator within one grid step (both
        # contractions stream through the same collapsed schedule when
        # dual)
        for i in range(k_collapse):
            xs = x[:, i * bk:(i + 1) * bk]
            ws = slice(i * bk, (i + 1) * bk)
            acc = acc + jnp.dot(xs, w[ws, :],
                                preferred_element_type=jnp.float32)
            if dual:
                acc2 = acc2 + jnp.dot(xs, w2[ws, :],
                                      preferred_element_type=jnp.float32)
    acc_ref[...] = acc
    if dual:
        acc2_ref[...] = acc2

    @pl.when(pl.program_id(2) == n_steps - 1)
    def _store():                      # carry-propagate: resolve the fp32
        out = store_phase(             # accumulator(s), dequant, fuse the
            acc_ref[...],              # epilogue, cast/store ONCE
            acc2_ref[...] if dual else None,
            s_ref[...] if quant else None,
            s2_ref[...] if (quant and dual) else None,
            b_ref[...] if has_b else None,
            b2_ref[...] if has_b2 else None,
            activation,
            r_ref[...] if has_r else None)
        o_ref[...] = out.astype(o_ref.dtype)


def arrayflex_gemm(x, w, *, w2=None, bias=None, bias2=None,
                   w_scale=None, w2_scale=None, act_quant: bool = False,
                   residual=None, norm_scale=None,
                   activation: str = "none", bm: int = 128, bn: int = 128,
                   bk: int = 128, k_collapse: int = 1, out_dtype=None,
                   interpret=None):
    """X[M,K] @ W[K,N] with K-collapse factor k_collapse and an optional
    fused epilogue at the carry-propagate boundary:

        out = [residual +] act(X@W [+ bias]) [* (X@W2 [+ bias2])]

    ``residual`` (an (M, N) array, any float dtype) fuses the sublayer
    residual join into the store: it is tiled exactly like the output,
    cast to fp32, and added after the activation/gate — one more Eq.(5')
    boundary op, no separate HBM round-trip for the add.

    ``norm_scale`` (a (K,) vector) fuses the rmsnorm elementwise scale
    into each grid step's *prologue* (:func:`prologue_phase`): the step's
    x tile is multiplied by its K-slice of ``g`` in fp32 and cast back
    before the contraction (and before the W8A8 quantizer) — the
    pre-attention norm's scale pass stops being a separate elementwise
    kernel on the decode hot path.  One more priced Eq.(5') boundary op.

    ``w2`` (same shape as ``w``) enables the dual-contraction gated form —
    with ``activation="silu"`` this is the one-kernel swiglu.  ``bias`` /
    ``bias2`` are (N,) vectors added to the fp32 accumulator(s) before the
    activation/gate.  All epilogue math happens on the resolved fp32
    accumulator; the output is cast exactly once.

    ``w_scale`` (an (N,) fp32 vector) enables the **int8-weight** path:
    ``w`` then holds int8 codes and the effective weight is
    ``w * w_scale`` per output channel.  The contraction accumulates raw
    codes in fp32 and the dequant multiply resolves at the carry-propagate
    store, *before* bias/activation — per-column scales factor out of the
    K sum, so deferring the dequant to the boundary is exact.  A dual
    contraction takes its own ``w2_scale``.

    ``act_quant`` (requires ``w_scale``) enables the **W8A8** path: each
    grid step quantizes its activation tile to int8 with a dynamic
    per-tile fp32 scale and the MAC chain runs int8 x int8 -> int32; the
    tile scale folds per step, the weight scale at the store (see the
    module docstring).  Unlike the weight path this is *lossy* on the
    activations (per-tile round-off bounded by amax/254 per element
    pre-contraction), so it is opt-in per site.

    Divisibility contract:
      * ``bm`` (clamped to M) must divide M and ``bn`` (clamped to N) must
        divide N — otherwise a ``ValueError`` is raised;
      * empty M, N or K short-circuits: the epilogue is applied to the
        exact zero accumulator(s) (NOT necessarily a zero result — a bias
        epilogue with K=0 returns ``act(bias)``);
      * K may be anything.  The K axis is tiled into
        ``n_steps = ceil(K / (bk * k_collapse))`` collapsed blocks of
        ``k_collapse`` equal sub-tiles each; when K does not fill that grid
        exactly, X and W are zero-padded along K (zeros contribute exactly
        0 to the fp32 accumulator, so the result is exact — previously the
        kernel silently *dropped* trailing K columns whenever the clamped
        block was not divisible by k_collapse, e.g. K=130, k_collapse=4).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: x {x.shape} @ w {w.shape}")
    if k_collapse < 1:
        raise ValueError(f"k_collapse must be >= 1, got {k_collapse}")
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown epilogue activation {activation!r}; "
                         f"supported: {ACTIVATIONS}")
    dual = w2 is not None
    if dual and w2.shape != w.shape:
        raise ValueError(f"w2 {w2.shape} must match w {w.shape}")
    if bias2 is not None and not dual:
        raise ValueError("bias2 requires w2 (the dual contraction)")
    quant = w_scale is not None
    if w2_scale is not None and not (quant and dual):
        raise ValueError("w2_scale requires both w_scale and w2")
    if quant and dual and w2_scale is None:
        raise ValueError("int8 dual contraction needs w2_scale for w2")
    if act_quant and not quant:
        raise ValueError("act_quant (W8A8) requires int8 weights (w_scale)")
    for name, b in (("bias", bias), ("bias2", bias2),
                    ("w_scale", w_scale), ("w2_scale", w2_scale)):
        if b is not None and b.shape != (N,):
            raise ValueError(f"{name} must be ({N},), got {b.shape}")
    if residual is not None and residual.shape != (M, N):
        raise ValueError(
            f"residual must be ({M}, {N}), got {residual.shape}")
    if norm_scale is not None and norm_scale.shape != (K,):
        raise ValueError(
            f"norm_scale must be ({K},), got {norm_scale.shape}")
    out_dtype = out_dtype or x.dtype
    if M == 0 or N == 0 or K == 0:      # empty operand: epilogue of zeros
        zero = jnp.zeros((M, N), jnp.float32)
        out = apply_epilogue(zero, zero if dual else None,
                             None if bias is None else bias.astype(jnp.float32),
                             None if bias2 is None else bias2.astype(jnp.float32),
                             activation)
        if residual is not None:
            out = residual.astype(jnp.float32) + out
        return out.astype(out_dtype)
    bm, bn = min(bm, M), min(bn, N)
    if M % bm or N % bn:
        raise ValueError(
            f"bm must divide M and bn must divide N: "
            f"M={M}, bm={bm}, N={N}, bn={bn}")
    # exact K tiling: choose the sub-tile width so the collapsed block grid
    # covers K with minimal zero padding (never drop columns).
    n_steps = -(-K // (bk * k_collapse))           # ceil
    bk_eff = -(-K // (n_steps * k_collapse))       # ceil
    kk = bk_eff * k_collapse
    K_pad = n_steps * kk
    if K_pad != K:
        x = jnp.pad(x, ((0, 0), (0, K_pad - K)))
        w = jnp.pad(w, ((0, K_pad - K), (0, 0)))
        if dual:
            w2 = jnp.pad(w2, ((0, K_pad - K), (0, 0)))
        if norm_scale is not None:      # padded x columns are zero, so the
            norm_scale = jnp.pad(norm_scale, (0, K_pad - K))   # pad value
    grid = (M // bm, N // bn, n_steps)  # is inert (0 * 0 == 0)
    interpret = resolve_interpret(interpret)
    kernel = functools.partial(_kernel, k_collapse=k_collapse,
                               n_steps=n_steps, activation=activation,
                               dual=dual, quant=quant, act_quant=act_quant,
                               has_b=bias is not None,
                               has_b2=bias2 is not None,
                               has_r=residual is not None,
                               has_g=norm_scale is not None)
    operands = [x, w]
    in_specs = [
        pl.BlockSpec((bm, kk), lambda i, j, s: (i, s)),
        pl.BlockSpec((kk, bn), lambda i, j, s: (s, j)),
    ]
    if dual:
        operands.append(w2)
        in_specs.append(pl.BlockSpec((kk, bn), lambda i, j, s: (s, j)))
    for b in (w_scale, w2_scale, bias, bias2):
        if b is not None:
            operands.append(b.reshape(1, N))
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
    if residual is not None:            # output-tiled: one (bm, bn) block
        operands.append(residual)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)))
    if norm_scale is not None:          # K-tiled: this step's (kk,) slice
        operands.append(norm_scale.reshape(1, K_pad))
        in_specs.append(pl.BlockSpec((1, kk), lambda i, j, s: (0, s)))
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    if dual:
        scratch.append(pltpu.VMEM((bm, bn), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# expert-batched kernel: the expert axis is the leading grid dimension

def _expert_kernel(*refs, k_collapse: int, n_steps: int, quant: bool,
                   act_quant: bool):
    """refs = x, w, [scale], o, acc.  ``quant``: int8 per-expert codes
    with per-(expert, output-channel) scales dequantized at the store.
    ``act_quant``: W8A8 — this expert's x-tile quantizes with one dynamic
    per-tile scale and the chain runs int8 x int8 -> int32, exactly as in
    :func:`_kernel`."""
    x_ref, w_ref = refs[0], refs[1]
    s_ref = refs[2] if quant else None
    o_ref = refs[2 + quant]
    acc_ref = refs[3 + quant]

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # (bm, bk * k)  — this expert's rows
    w = w_ref[0]                       # (bk * k, bn)  — this expert's weights
    if quant and not act_quant:
        w = w.astype(x.dtype)          # exact: |code| <= 127
    bk = x.shape[1] // k_collapse
    acc = acc_ref[...]
    if act_quant:
        qx, x_scale = quantize_tile(x)
        iacc = jnp.zeros(acc_ref.shape, jnp.int32)
        for i in range(k_collapse):
            iacc = iacc + jnp.dot(qx[:, i * bk:(i + 1) * bk],
                                  w[i * bk:(i + 1) * bk, :],
                                  preferred_element_type=jnp.int32)
        acc = acc + iacc.astype(jnp.float32) * x_scale
    else:
        for i in range(k_collapse):
            acc = acc + jnp.dot(x[:, i * bk:(i + 1) * bk],
                                w[i * bk:(i + 1) * bk, :],
                                preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(pl.program_id(3) == n_steps - 1)
    def _store():                      # carry-propagate: resolve, dequant,
        y = store_phase(acc_ref[...],  # cast once
                        w_scale=s_ref[0] if quant else None)
        o_ref[0] = y.astype(o_ref.dtype)


def arrayflex_expert_gemm(x, w, *, w_scale=None, act_quant: bool = False,
                          bm: int = 128,
                          bn: int = 128, bk: int = 128, k_collapse: int = 1,
                          out_dtype=None, interpret=None):
    """Batched per-expert GEMM in ONE launch: X[E,T,K] @ W[E,K,N] -> [E,T,N].

    ``w_scale`` (an (E, N) fp32 array) enables the int8-weight path: ``w``
    holds int8 codes and each expert's per-output-channel dequant multiply
    resolves at its carry-propagate store, exactly as in
    :func:`arrayflex_gemm`.  ``act_quant`` (requires ``w_scale``) adds the
    W8A8 per-tile activation quantize + int8 x int8 -> int32 chain.

    Grid = (E, T/bm, N/bn, n_steps) — the *leading* grid dimension walks
    the expert axis, so every expert's K-collapsed schedule runs inside a
    single ``pallas_call`` (the MoE layer's per-site dispatch count drops
    from E to 1).  Each (e, i, j) output tile owns the same fp32
    carry-save accumulator walk as :func:`arrayflex_gemm`; experts share
    the collapse depth k, planned once for the common (T, K, N) shape.

    Same divisibility contract as :func:`arrayflex_gemm` on T (rows) and
    N; K is zero-padded to the collapsed-block grid; empty E/T/N/K
    returns exact zeros.
    """
    E, T, K = x.shape
    E2, K2, N = w.shape
    if E != E2 or K != K2:
        raise ValueError(f"expert gemm mismatch: x {x.shape} @ w {w.shape}")
    if k_collapse < 1:
        raise ValueError(f"k_collapse must be >= 1, got {k_collapse}")
    quant = w_scale is not None
    if quant and w_scale.shape != (E, N):
        raise ValueError(f"w_scale must be ({E}, {N}), got {w_scale.shape}")
    if act_quant and not quant:
        raise ValueError("act_quant (W8A8) requires int8 weights (w_scale)")
    out_dtype = out_dtype or x.dtype
    if E == 0 or T == 0 or N == 0 or K == 0:
        return jnp.zeros((E, T, N), out_dtype)
    bm, bn = min(bm, T), min(bn, N)
    if T % bm or N % bn:
        raise ValueError(
            f"bm must divide T and bn must divide N: "
            f"T={T}, bm={bm}, N={N}, bn={bn}")
    n_steps = -(-K // (bk * k_collapse))
    bk_eff = -(-K // (n_steps * k_collapse))
    kk = bk_eff * k_collapse
    K_pad = n_steps * kk
    if K_pad != K:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, K_pad - K)))
        w = jnp.pad(w, ((0, 0), (0, K_pad - K), (0, 0)))
    grid = (E, T // bm, N // bn, n_steps)
    interpret = resolve_interpret(interpret)
    kernel = functools.partial(_expert_kernel, k_collapse=k_collapse,
                               n_steps=n_steps, quant=quant,
                               act_quant=act_quant)
    operands = [x, w]
    in_specs = [
        pl.BlockSpec((1, bm, kk), lambda e, i, j, s: (e, i, s)),
        pl.BlockSpec((1, kk, bn), lambda e, i, j, s: (e, s, j)),
    ]
    if quant:
        operands.append(w_scale)
        in_specs.append(pl.BlockSpec((1, bn), lambda e, i, j, s: (e, j)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, s: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, T, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
