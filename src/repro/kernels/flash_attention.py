"""Fused flash-attention forward kernel (Pallas, TPU BlockSpec tiling).

This is the fusion that removes the dominant HBM-traffic term of the jnp
chunked attention (see EXPERIMENTS.md §Perf): scores/probabilities live in
VMEM only; HBM sees Q, K, V once and O once.

Layout: q (BH, S, D), k/v (BH, T, D) — callers fold batch x heads (GQA
callers repeat or fold kv heads).  Grid = (BH, S/bq); each step loads one q
row-block, loops the full KV in VMEM-resident chunks with an online softmax,
and writes one O block.  Causal + sliding-window masks are applied from
global row/col ids so the schedule skips nothing it shouldn't.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_chunk: int, causal: bool,
            window: int, scale: float, kv_len: int):
    q = q_ref[0]                                  # (bq, D)
    bq, D = q.shape
    T = k_ref.shape[1]
    n_k = T // kv_chunk
    row0 = pl.program_id(1) * bq
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, kv_chunk), 0)

    def body(j, carry):
        o, m, l = carry
        ks = k_ref[0, pl.ds(j * kv_chunk, kv_chunk), :]
        vs = v_ref[0, pl.ds(j * kv_chunk, kv_chunk), :]
        s = jax.lax.dot_general(
            q, ks, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, kc)
        cols = (j * kv_chunk
                + jax.lax.broadcasted_iota(jnp.int32, (bq, kv_chunk), 1))
        ok = jnp.ones((bq, kv_chunk), jnp.bool_)
        if causal:
            ok = ok & (cols <= rows)
        if window:
            ok = ok & (cols > rows - window)
        if kv_len != T:                          # zero-padded ragged tail
            ok = ok & (cols < kv_len)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(vs.dtype), vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_new = o * corr[:, None] + pv
        return o_new, m_new, l_new

    o0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    o, m, l = jax.lax.fori_loop(0, n_k, body, (o0, m0, l0))
    o_ref[0] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, kv_chunk: int = 128, interpret=None):
    """q: (BH, S, D); k/v: (BH, T, D).  Returns (BH, S, D).

    T need not divide ``kv_chunk``: K/V are zero-padded to the chunk grid
    and the kernel masks columns past the true length (so the planner's
    chunk pick runs as-is instead of degenerating via a divisor search).

    ``interpret=None`` resolves via :func:`repro.kernels.runtime
    .resolve_interpret` (env override, compiled on TPU).
    """
    interpret = resolve_interpret(interpret)
    BH, S, D = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    kv_chunk = min(kv_chunk, T)
    assert S % bq == 0
    Tp = -(-T // kv_chunk) * kv_chunk
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0)))
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_kernel, kv_chunk=kv_chunk, causal=causal,
                               window=window, scale=scale, kv_len=T)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Tp, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Tp, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
