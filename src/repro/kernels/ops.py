"""jit'd public wrappers: planner-driven kernel configuration.

``arrayflex_matmul`` is the framework's ArrayFlex-scheduled GEMM: the
collapse factor k comes from core.planner (Eq. 6/7) for the GEMM's (M,N,T)
shape, mirroring the paper's per-CNN-layer pipeline-depth selection.
``attention`` picks the flash kernel's KV-chunk with the same machinery.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import planner, timing
from repro.kernels.arrayflex_gemm import arrayflex_gemm
from repro.kernels.flash_attention import flash_attention
from repro.kernels import ref

# MXU geometry: the TPU systolic tile the collapse factor schedules around.
SA_R = 128
SA_C = 128


def plan_collapse(M: int, K: int, T_rows: int, *, max_k: int = 4) -> int:
    """ArrayFlex pipeline depth for GEMM X[T,K] @ W[K,M] (Eq. 7 -> discrete).

    K is the contraction (the SA's R-tiled dim), M the output columns.
    """
    k = timing.best_k(M, K, T_rows, SA_R, SA_C)
    return max(1, min(max_k, k))


@partial(jax.jit, static_argnames=("k_collapse", "bk", "interpret"))
def _gemm(x, w, k_collapse: int, bk: int, interpret: bool):
    return arrayflex_gemm(x, w, bk=bk, k_collapse=k_collapse,
                          interpret=interpret)


def arrayflex_matmul(x, w, *, k_collapse: int = 0, bk: int = 128,
                     interpret: bool = True):
    """Planner-configured GEMM.  x: (..., K), w: (K, N)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    if x.size == 0 or N == 0:           # empty operand: exact zero result
        return jnp.zeros((*lead, N), x.dtype)
    x2 = x.reshape(-1, K)
    if not k_collapse:
        k_collapse = plan_collapse(N, K, x2.shape[0])
    M_rows = x2.shape[0]
    # the kernel zero-pads ragged K exactly; only ragged M/N tilings need
    # the reference fallback (the output grid cannot be padded
    # transparently).  Tile sizes mirror the kernel's bm/bn clamp.
    if M_rows % min(SA_R, M_rows) or N % min(SA_C, N):
        return ref.gemm_ref(x2, w).reshape(*lead, N)   # shape fallback
    out = _gemm(x2, w, k_collapse, bk, interpret)
    return out.reshape(*lead, N)


def attention(q, k, v, *, causal=True, window=0, kv_chunk: int = 0,
              interpret: bool = True):
    """Flash attention with planner-chosen KV chunk.  (BH,S,D) layout."""
    from repro.nn.attention import fit_chunk
    if not kv_chunk:
        kv_chunk = planner.attention_plan(q.shape[1], k.shape[1])
    return flash_attention(q, k, v, causal=causal, window=window,
                           kv_chunk=fit_chunk(k.shape[1], kv_chunk),
                           interpret=interpret)
