"""jit'd public wrappers: planner-driven kernel configuration.

``arrayflex_matmul`` is the framework's ArrayFlex-scheduled GEMM: the
collapse factor k comes from core.planner (Eq. 6/7) for the GEMM's (M,N,T)
shape, mirroring the paper's per-CNN-layer pipeline-depth selection, and an
optional fused epilogue (bias / activation / dual-GEMM gate) rides the
carry-propagate store.  ``arrayflex_expert_matmul`` runs a stack of
same-shape per-expert GEMMs in one launch.  ``attention`` picks the flash
kernel's KV-chunk with the same machinery.

``plan_collapse`` is memoized: it is a pure function of small int tuples,
and model tracing + per-request serving hit it with the same handful of
shapes thousands of times.

Pallas ``interpret`` resolution (the TPU-hardware switch): an explicit
argument wins, else the ``REPRO_PALLAS_INTERPRET`` env var, else interpret
mode everywhere but on real TPU backends.  ``ModelConfig.pallas_interpret``
threads the explicit argument from model configs down to every kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import planner, timing
from repro.kernels.arrayflex_gemm import (apply_epilogue, arrayflex_gemm,
                                          arrayflex_expert_gemm)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.runtime import resolve_interpret

# MXU geometry: the TPU systolic tile the collapse factor schedules around.
SA_R = 128
SA_C = 128


@functools.lru_cache(maxsize=None)
def plan_collapse(M: int, K: int, T_rows: int, *, max_k: int = 4,
                  epilogue_ops: int = 0, precision: str = "fp32",
                  actq_ops: int = 0, transfer_cycles: int = 0) -> int:
    """ArrayFlex pipeline depth for GEMM X[T,K] @ W[K,M] (Eq. 7 -> discrete).

    K is the contraction (the SA's R-tiled dim), M the output columns.
    ``epilogue_ops`` prices fused post-GEMM vector ops into the per-step
    period (Eq. 5'), which can shift the argmin toward deeper collapse.
    ``precision`` selects the datapath's Eq.(5) coefficients
    (``timing.timing_for``): the int8 datapath's cheap collapse stages
    move the argmin deeper than fp32 picks at the same shape.
    ``actq_ops`` prices the W8A8 dynamic activation-quantize boundary
    stage (Eq. 5' ``d_actq_ps``); on the w8a8 datapath this term alone
    can deepen the argmin — e.g. (896, 4864, 512) picks k=2 unpriced and
    k=4 with the quantizer priced.  ``transfer_cycles`` serializes a
    pipeline-stage activation transfer (ICI ingress at C lanes/cycle) in
    front of the schedule — paid at the k-collapsed period (Eq. 6''), it
    pushes the argmin SHALLOWER, which is how a latency-bound decode
    stage legitimately plans a shallower k than a compute-bound prefill
    stage at the same (M, K, T).
    """
    k = timing.best_k(M, K, T_rows, SA_R, SA_C,
                      timing.timing_for(precision),
                      epilogue_ops=epilogue_ops, actq_ops=actq_ops,
                      extra_cycles=transfer_cycles)
    return max(1, min(max_k, k))


@functools.partial(jax.jit,
                   static_argnames=("activation", "has_w2", "has_b",
                                    "has_b2", "has_s", "has_s2", "has_r",
                                    "has_g", "act_quant", "k_collapse",
                                    "bk", "out_dtype", "interpret"))
def _gemm(x, w, w2, bias, bias2, w_scale, w2_scale, residual, norm_scale,
          activation, has_w2, has_b, has_b2, has_s, has_s2, has_r,
          has_g, act_quant: bool,
          k_collapse: int, bk: int, out_dtype, interpret: bool):
    return arrayflex_gemm(x, w,
                          w2=w2 if has_w2 else None,
                          bias=bias if has_b else None,
                          bias2=bias2 if has_b2 else None,
                          w_scale=w_scale if has_s else None,
                          w2_scale=w2_scale if has_s2 else None,
                          residual=residual if has_r else None,
                          norm_scale=norm_scale if has_g else None,
                          act_quant=act_quant,
                          activation=activation, bk=bk,
                          k_collapse=k_collapse, out_dtype=out_dtype,
                          interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("has_s", "act_quant", "k_collapse",
                                    "bk", "out_dtype", "interpret"))
def _expert_gemm(x, w, w_scale, has_s, act_quant: bool, k_collapse: int,
                 bk: int, out_dtype, interpret: bool):
    return arrayflex_expert_gemm(x, w,
                                 w_scale=w_scale if has_s else None,
                                 act_quant=act_quant,
                                 bk=bk, k_collapse=k_collapse,
                                 out_dtype=out_dtype, interpret=interpret)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def arrayflex_matmul(x, w, *, w2=None, bias=None, bias2=None,
                     w_scale=None, w2_scale=None, act_quant: bool = False,
                     residual=None, norm_scale=None,
                     activation: str = "none", k_collapse: int = 0,
                     bk: int = 128, out_dtype=None, interpret=None):
    """Planner-configured GEMM with fused epilogue.  x: (..., K), w: (K, N).

        out = [residual +] act((g*x)@w [+ bias]) [* ((g*x)@w2 [+ bias2])]

    ``norm_scale`` (``g``, a (K,) vector) fuses the rmsnorm elementwise
    scale into the kernel's step prologue — one more priced boundary op,
    no separate scale pass before the GEMM.

    ``residual`` is an output-shaped ``(..., N)`` stream joined after the
    activation/gate at the carry-propagate store (one more priced
    boundary op; padded rows/columns join zero residual and slice off).

    ``w_scale`` enables the int8-weight path (``w`` holds int8 codes,
    effective weight ``w * w_scale`` per output channel; dequant at the
    carry-propagate store) — the unplanned ``k_collapse=0`` then picks k
    with the int8 datapath's Eq.(5) coefficients, which favor deeper
    collapse than fp32.  ``act_quant`` (requires ``w_scale``) enables the
    W8A8 per-tile activation quantize + int8 x int8 -> int32 chain; the
    unplanned path then prices the w8a8 datapath with one Eq.(5')
    activation-quantize boundary op.

    Covers *every* nonempty shape exactly: the kernel zero-pads ragged K
    itself, and ragged M rows / N columns (tilings the output grid cannot
    absorb) are zero-padded here to the systolic tile and sliced off the
    result — zeros contribute exactly 0 to the fp32 accumulator, so
    padding is exact and no reference fallback is ever taken.  Padded N
    columns extend ``bias``/``bias2`` (and the dequant scales) with zeros
    (sliced off with the output); padded M rows run the epilogue on zero
    accumulators and are sliced off.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    out_dtype = out_dtype or x.dtype
    interpret = resolve_interpret(interpret)
    if x.size == 0 or N == 0 or K == 0:   # empty operand: epilogue of zeros
        zero = jnp.zeros((*lead, N), jnp.float32)
        out = apply_epilogue(
            zero, zero if w2 is not None else None,
            None if bias is None else bias.astype(jnp.float32),
            None if bias2 is None else bias2.astype(jnp.float32),
            activation)
        if residual is not None:
            out = residual.astype(jnp.float32) + out
        return out.astype(out_dtype)
    x2 = x.reshape(-1, K)
    M_rows = x2.shape[0]
    quant = w_scale is not None
    if not k_collapse:
        # dequant multiplies (one per contraction) and the residual join
        # are boundary ops too
        n_ops = ((activation != "none") + (bias is not None)
                 + (bias2 is not None) + (w2 is not None)
                 + (residual is not None) + (norm_scale is not None)
                 + quant * (1 + (w2 is not None)))
        precision = ("w8a8" if act_quant else "int8") if quant else "fp32"
        k_collapse = plan_collapse(N, K, M_rows, epilogue_ops=n_ops,
                                   precision=precision,
                                   actq_ops=int(act_quant))
    # tile sizes mirror the kernel's bm/bn clamp: a dim smaller than the SA
    # is its own (exactly dividing) tile; larger dims pad up to a multiple.
    Mp = M_rows if M_rows <= SA_R else _round_up(M_rows, SA_R)
    Np = N if N <= SA_C else _round_up(N, SA_C)
    if residual is not None:
        residual = residual.reshape(M_rows, N)
        if (Mp, Np) != (M_rows, N):
            residual = jnp.pad(residual, ((0, Mp - M_rows), (0, Np - N)))
    if Mp != M_rows:
        x2 = jnp.pad(x2, ((0, Mp - M_rows), (0, 0)))
    if Np != N:
        w = jnp.pad(w, ((0, 0), (0, Np - N)))
        if w2 is not None:
            w2 = jnp.pad(w2, ((0, 0), (0, Np - N)))
        if bias is not None:
            bias = jnp.pad(bias, (0, Np - N))
        if bias2 is not None:
            bias2 = jnp.pad(bias2, (0, Np - N))
        if w_scale is not None:
            w_scale = jnp.pad(w_scale, (0, Np - N))
        if w2_scale is not None:
            w2_scale = jnp.pad(w2_scale, (0, Np - N))
    dummy = jnp.zeros((), x2.dtype)
    out = _gemm(x2, w,
                w2 if w2 is not None else dummy,
                bias if bias is not None else dummy,
                bias2 if bias2 is not None else dummy,
                w_scale if w_scale is not None else dummy,
                w2_scale if w2_scale is not None else dummy,
                residual if residual is not None else dummy,
                norm_scale if norm_scale is not None else dummy,
                activation, w2 is not None, bias is not None,
                bias2 is not None, w_scale is not None,
                w2_scale is not None, residual is not None,
                norm_scale is not None,
                act_quant, k_collapse, bk,
                out_dtype, interpret)
    if (Mp, Np) != (M_rows, N):
        out = out[:M_rows, :N]
    return out.reshape(*lead, N)


def arrayflex_expert_matmul(x, w, *, w_scale=None, act_quant: bool = False,
                            k_collapse: int = 0,
                            bk: int = 128, out_dtype=None, interpret=None):
    """Planner-configured batched expert GEMM in ONE kernel launch.

    x: (E, T, K), w: (E, K, N) -> (E, T, N).  All experts share one
    collapse depth k, planned for the common (N, K, T) shape (every expert
    GEMM in a capacity-buffered MoE layer has identical shape).
    ``w_scale`` (E, N) enables the int8-weight path; ``act_quant`` adds
    the W8A8 per-tile activation quantize.  Ragged T / N are zero-padded
    to the systolic tile and sliced off, exactly as in
    :func:`arrayflex_matmul`.
    """
    E, T, K = x.shape
    N = w.shape[-1]
    out_dtype = out_dtype or x.dtype
    interpret = resolve_interpret(interpret)
    if E == 0 or T == 0 or N == 0 or K == 0:
        return jnp.zeros((E, T, N), out_dtype)
    quant = w_scale is not None
    if not k_collapse:
        precision = ("w8a8" if act_quant else "int8") if quant else "fp32"
        k_collapse = plan_collapse(N, K, T, epilogue_ops=int(quant),
                                   precision=precision,
                                   actq_ops=int(act_quant))
    Tp = T if T <= SA_R else _round_up(T, SA_R)
    Np = N if N <= SA_C else _round_up(N, SA_C)
    if Tp != T:
        x = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))
    if Np != N:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, Np - N)))
        if w_scale is not None:
            w_scale = jnp.pad(w_scale, ((0, 0), (0, Np - N)))
    dummy = jnp.zeros((), x.dtype)
    out = _expert_gemm(x, w, w_scale if quant else dummy, quant, act_quant,
                       k_collapse, bk, out_dtype, interpret)
    if (Tp, Np) != (T, N):
        out = out[:, :T, :N]
    return out


def attention(q, k, v, *, causal=True, window=0, kv_chunk: int = 0,
              interpret=None):
    """Flash attention with planner-chosen KV chunk.  (BH,S,D) layout.

    The KV length need not divide the chunk: the kernel pads K/V to the
    chunk grid and masks the tail, so the planner's pick is used as-is
    (a prime KV length no longer degenerates to chunk=1).
    """
    if not kv_chunk:
        kv_chunk = planner.attention_plan(q.shape[1], k.shape[1])
    return flash_attention(q, k, v, causal=causal, window=window,
                           kv_chunk=kv_chunk,
                           interpret=resolve_interpret(interpret))
