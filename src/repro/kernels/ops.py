"""jit'd public wrappers: planner-driven kernel configuration.

``arrayflex_matmul`` is the framework's ArrayFlex-scheduled GEMM: the
collapse factor k comes from core.planner (Eq. 6/7) for the GEMM's (M,N,T)
shape, mirroring the paper's per-CNN-layer pipeline-depth selection.
``attention`` picks the flash kernel's KV-chunk with the same machinery.

``plan_collapse`` is memoized: it is a pure function of small int tuples,
and model tracing + per-request serving hit it with the same handful of
shapes thousands of times.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import planner, timing
from repro.kernels.arrayflex_gemm import arrayflex_gemm
from repro.kernels.flash_attention import flash_attention

# MXU geometry: the TPU systolic tile the collapse factor schedules around.
SA_R = 128
SA_C = 128


@functools.lru_cache(maxsize=None)
def plan_collapse(M: int, K: int, T_rows: int, *, max_k: int = 4) -> int:
    """ArrayFlex pipeline depth for GEMM X[T,K] @ W[K,M] (Eq. 7 -> discrete).

    K is the contraction (the SA's R-tiled dim), M the output columns.
    """
    k = timing.best_k(M, K, T_rows, SA_R, SA_C)
    return max(1, min(max_k, k))


@functools.partial(jax.jit,
                   static_argnames=("k_collapse", "bk", "out_dtype",
                                    "interpret"))
def _gemm(x, w, k_collapse: int, bk: int, out_dtype, interpret: bool):
    return arrayflex_gemm(x, w, bk=bk, k_collapse=k_collapse,
                          out_dtype=out_dtype, interpret=interpret)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def arrayflex_matmul(x, w, *, k_collapse: int = 0, bk: int = 128,
                     out_dtype=None, interpret: bool = True):
    """Planner-configured GEMM.  x: (..., K), w: (K, N).

    Covers *every* nonempty shape exactly: the kernel zero-pads ragged K
    itself, and ragged M rows / N columns (tilings the output grid cannot
    absorb) are zero-padded here to the systolic tile and sliced off the
    result — zeros contribute exactly 0 to the fp32 accumulator, so
    padding is exact and no reference fallback is ever taken.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    out_dtype = out_dtype or x.dtype
    if x.size == 0 or N == 0 or K == 0:   # empty operand: exact zero result
        return jnp.zeros((*lead, N), out_dtype)
    x2 = x.reshape(-1, K)
    M_rows = x2.shape[0]
    if not k_collapse:
        k_collapse = plan_collapse(N, K, M_rows)
    # tile sizes mirror the kernel's bm/bn clamp: a dim smaller than the SA
    # is its own (exactly dividing) tile; larger dims pad up to a multiple.
    Mp = M_rows if M_rows <= SA_R else _round_up(M_rows, SA_R)
    Np = N if N <= SA_C else _round_up(N, SA_C)
    if Mp != M_rows:
        x2 = jnp.pad(x2, ((0, Mp - M_rows), (0, 0)))
    if Np != N:
        w = jnp.pad(w, ((0, 0), (0, Np - N)))
    out = _gemm(x2, w, k_collapse, bk, out_dtype, interpret)
    if (Mp, Np) != (M_rows, N):
        out = out[:M_rows, :N]
    return out.reshape(*lead, N)


def attention(q, k, v, *, causal=True, window=0, kv_chunk: int = 0,
              interpret: bool = True):
    """Flash attention with planner-chosen KV chunk.  (BH,S,D) layout.

    The KV length need not divide the chunk: the kernel pads K/V to the
    chunk grid and masks the tail, so the planner's pick is used as-is
    (a prime KV length no longer degenerates to chunk=1).
    """
    if not kv_chunk:
        kv_chunk = planner.attention_plan(q.shape[1], k.shape[1])
    return flash_attention(q, k, v, causal=causal, window=window,
                           kv_chunk=kv_chunk, interpret=interpret)
