"""GEMM execution substrate: one dispatch layer for every model GEMM.

The paper's selection loop (core.planner / core.timing, Eqs. 6-7) picks a
pipeline-collapse depth k *per GEMM shape*; this module is the pipe that
makes those picks configure actual execution.  Every dense contraction in
nn/ and models/ routes through :func:`gemm` (or :func:`expert_gemm` for the
MoE batched form, :func:`batched_gemm` for attention QK/PV products), which

  * resolves the GEMM's :class:`GemmPlan` from a process-wide **plan
    cache** keyed on ``(M, N, T, backend, epilogue, shard)`` — the Eq.(6')
    argmin runs once per *post-partition* shape, not once per jit trace or
    serving request;
  * records the plan under the caller's **site label** (``attn.wq``,
    ``mlp.wo``, ``attn.qk``, ...), the same names
    ``core.planner.model_gemms`` emits, so analytic plans and executed
    kernels are the same objects (the substrate benchmark joins the two
    tables on these labels), and counts the dispatch in
    :data:`DISPATCH_COUNTS`;
  * dispatches to a **backend** from a pluggable registry:

      ``xla``            today's ``x @ w`` (the default; numerics unchanged),
      ``arrayflex``      the Pallas K-collapse kernel at the planned k,
      ``arrayflex_int8`` the same kernel on int8 weights + per-output-
                         channel fp32 scales (fp32 accumulation, dequant
                         at the carry-propagate boundary), planned with
                         the int8 datapath's Eq.(5) coefficients,
      ``arrayflex_w8a8`` int8 weights AND dynamically quantized int8
                         activations: each grid step quantizes its
                         activation tile in-kernel (per-tile fp32 scale)
                         and the MAC chain runs int8 x int8 -> int32,
                         planned with the w8a8 datapath's coefficients
                         plus the Eq.(5') activation-quantize boundary
                         term (``timing.W8A8TimingParams.d_actq_ps``),
      ``ref``            an fp32-everywhere oracle for equivalence tests.

**Int8 weight quantization** (the ``arrayflex_int8`` backend): dispatch
quantizes each weight once through a per-weight-identity memo
(:func:`quantize_weight` — symmetric per-output-channel int8, fp32
scales), so eager dispatch never re-quantizes a weight it has seen (the
bench gates that hit rate at 100%).  Dispatch under a jit trace sees
tracers, not weight identities: quantization is staged into the
compiled step (once per compilation, but re-executed by XLA per call) —
hoisting it out via pre-quantized parameter trees is the ROADMAP
follow-up.  The kernel accumulates raw int8 codes in fp32 and the
dequant multiply resolves at the carry-propagate store, priced into
Eq.(5') as one boundary op per contraction.  Because the int8 datapath's collapse stages are cheap
(``timing.IntTimingParams``), the Eq.(6') argmin lands on deeper k than
fp32 picks at the same shape — the plan cache keys on the backend name,
which carries the precision.  Attention QK/PV products dispatch their
*activation* operands (K/V are not weights), so ``batched_gemm`` under
the int8 backend falls back to the fp32 arrayflex kernel and plan;
``moe.router`` is quantization-exempt (:data:`QUANT_EXEMPT_SITES`) —
router logits feed a discrete top-k, where quantization noise would
change expert routing rather than add bounded output error.

**Epilogues**: ``gemm(..., epilogue="silu"|"gelu"|"swiglu", bias=...,
w2=..., residual=...)`` fuses bias add, activation, the dual-contraction
gated multiply (swiglu: ``silu(x@w [+bias]) * (x@w2 [+bias2])``), and the
sublayer residual join (``residual + f(x)``) into the arrayflex kernel's
carry-propagate store — no HBM round-trip between a GEMM and its
activation or residual add.  Unfused backends (xla/ref) apply the identical
math as a post-pass (``apply_epilogue``), so every backend computes the
same function and equivalence tests stay meaningful.  The epilogue's
vector ops are priced into Eq.(5')/(6') and can shift the planned k.

``ModelConfig.gemm_backend`` selects the backend model-wide and
``ModelConfig.pallas_interpret`` (or ``REPRO_PALLAS_INTERPRET``) the
Pallas interpret mode; callers thread both through (see models/lm.py).
New backends (quantized, ...) register with :func:`register_backend`.

**Sharded SPMD dispatch**: every entry point accepts a :class:`ShardCtx`
(mesh + operand PartitionSpecs + contraction reduce axes, derived per
site by ``parallel.sharding.gemm_shard_ctx`` and friends).  The dispatch
then runs the backend inside ``jax.shard_map`` so each device executes
its *post-partition* per-shard GEMM through the planned kernel, and the
plan itself is computed on the per-shard (M, N, T) — under tensor/FSDP
partitioning that is the shape the array actually executes, so the
Eq.(6') k-selection stays correct for sharded runs.  A TP row-parallel
weight (``attn.wo``-style, contraction sharded over 'model') psums its
partial accumulators at the collapsed-block boundary, *before* the
epilogue, and the psum's combine tree is priced into Eq.(5') as boundary
ops (``ShardSig.reduce_ops``) — which can legitimately shift the argmin
toward deeper collapse.

Shape convention matches core.planner: a call ``gemm(x, w)`` with
``x: (..., K)`` and ``w: (K, N_out)`` is the planner GEMM
``X[T, M] = A[T, N] x B[N, M]`` with ``M = N_out`` (output columns),
``N = K`` (contraction), ``T = prod(leading dims)`` (streamed rows).
``GemmPlan`` keeps those *logical* values and records the post-partition
``M_shard/N_shard/T_shard`` plus the per-shard Eq.(4) ``cycles``.
"""
from __future__ import annotations

import contextvars
import dataclasses
import functools
import math
import os
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import planner, timing
from repro.kernels import ops
from repro.kernels.arrayflex_gemm import apply_epilogue, prologue_phase


# ---------------------------------------------------------------------------
# epilogue spec (hashable: lives in the plan-cache key and in GemmPlan)

EPILOGUE_KINDS = ("none", "silu", "gelu", "swiglu")


@dataclass(frozen=True)
class Epilogue:
    """What is fused after the contraction, at the carry-propagate store.

    ``kind`` names the activation structure (``swiglu`` = silu-gated dual
    contraction, which requires the ``w2`` operand); ``bias``/``bias2``
    record whether bias vectors ride along.  Pure shape-level metadata —
    the actual arrays are per-call operands — so the spec is hashable and
    participates in the memoized Eq.(6') plan.
    """

    kind: str = "none"
    bias: bool = False
    bias2: bool = False
    # residual-add fused after the activation/gate at the same boundary
    # (the transformer sublayer ``x + f(x)`` — one more Eq.(5') vector op)
    residual: bool = False
    # rmsnorm-scale multiply fused as a *prologue*: the per-input-channel
    # norm gain rides the x tile into the array (x * g before the MACs),
    # so the pre-attention norm is no longer a separate elementwise pass.
    # Still one Eq.(5') boundary ALU on the period — the scale stage sits
    # at the tile boundary in front of the array, exactly where the W8A8
    # quantizer does.
    norm_scale: bool = False

    @property
    def dual(self) -> bool:
        return self.kind == "swiglu"

    @property
    def activation(self) -> str:
        return "silu" if self.kind == "swiglu" else self.kind

    @property
    def ops(self) -> int:
        """Fused vector ops at the collapsed-block boundary (Eq. 5' ``e``):
        one per activation, gate multiply, bias add, residual add, and
        prologue norm-scale multiply."""
        return ((self.activation != "none") + self.dual
                + self.bias + self.bias2 + self.residual
                + self.norm_scale)

    @property
    def contractions(self) -> int:
        return 2 if self.dual else 1


EPILOGUE_NONE = Epilogue()


@dataclass
class GemmCall:
    """Per-call execution context handed to backends (operand arrays are
    not part of the memoized plan)."""

    out_dtype: Any = None       # None -> operand dtype; else fp32-acc cast
    w2: Any = None              # second contraction (epilogue.dual)
    bias: Any = None            # (N_out,) fused bias
    bias2: Any = None           # (N_out,) fused bias on the w2 contraction
    # per-output-channel fp32 dequant scales of an int8-quantized w / w2
    # (set by the dispatch for quantizing backends; None = fp32 weights)
    w_scale: Any = None
    w2_scale: Any = None
    # (T, N_out) residual stream added after the epilogue (epilogue.residual)
    residual: Any = None
    # (K,) per-input-channel rmsnorm gain fused as a prologue x-tile scale
    norm_scale: Any = None
    interpret: Optional[bool] = None   # Pallas interpret override


# ---------------------------------------------------------------------------
# plan-key introspection metadata (audited by analysis.kernel_check)
#
# The Eq.(6') plan cache keys on exactly these _plan_gemm_cached params;
# every GemmCall / BackendInfo field must either be covered by that key or
# be declared plan-irrelevant below.  analysis.kernel_check fails (AF006)
# on any dataclass field missing from its declaration table — adding a
# field to GemmCall/BackendInfo without deciding its keying story here is
# a build error, not silent plan-cache aliasing.

PLAN_KEY_PARAMS = ("M", "N", "T", "backend", "epilogue", "shard")

# GemmCall field -> keying declaration.  "epilogue:<attr>" means the field's
# presence is forced by that Epilogue attribute (which IS in the key);
# "backend:<attr>" likewise via BackendInfo (the backend name is in the
# key and re-registration evicts cached plans); "operand:" means the field
# is pure per-call runtime data that cannot change the planned k.
CALL_FIELD_KEYING = {
    "out_dtype": "operand: output cast only — the planned k is blind to the "
                 "store dtype (datapath precision rides the backend name)",
    "w2": "epilogue:dual — w2 present iff kind=='swiglu' (_epilogue_spec "
          "enforces the iff)",
    "bias": "epilogue:bias",
    "bias2": "epilogue:bias2",
    "w_scale": "backend:quantize — scales present iff the keyed backend "
               "quantizes (dequant_ops priced from BackendInfo.quantize)",
    "w2_scale": "backend:quantize",
    "residual": "epilogue:residual — residual present iff the keyed "
                "Epilogue spec carries the fused residual add",
    "norm_scale": "epilogue:norm_scale — the prologue rmsnorm gain is "
                  "present iff the keyed Epilogue spec prices it",
    "interpret": "operand: Pallas interpret mode swaps the executor, never "
                 "the plan (identical math at the same k)",
}

# BackendInfo field -> how the plan key covers it.  All metadata is carried
# by the backend *name* in the key: register_backend evicts cached plans on
# (re-)registration, so a name whose metadata changed cannot serve stale k.
BACKEND_FIELD_KEYING = {
    "fn": "identity: the name resolves fn at dispatch; plans never embed it",
    "collapse": "keyed-by-name: read inside _plan_gemm_cached",
    "precision": "keyed-by-name: read inside _plan_gemm_cached",
    "quantize": "keyed-by-name: read inside _plan_gemm_cached (dequant_ops)",
    "act_quantize": "keyed-by-name: read inside _plan_gemm_cached "
                    "(actq_ops — the Eq.(5') quantize boundary term)",
}


# ---------------------------------------------------------------------------
# strict-audit mode: routing violations become runtime errors
#
# REPRO_STRICT_AUDIT=1 (env) or the strict_audit_scope context manager turns
# an unknown/empty dispatch site label into a RuntimeError at dispatch time
# ([AF007], the finding code analysis.jaxpr_audit reports for the same
# violation) — the engine's jit traces then fail loudly instead of logging
# a silent new DISPATCH_COUNTS key.

_STRICT_AUDIT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_strict_audit", default=None)


def strict_audit_enabled() -> bool:
    """Contextvar wins when set; else the REPRO_STRICT_AUDIT env var."""
    v = _STRICT_AUDIT.get()
    if v is not None:
        return bool(v)
    return os.environ.get("REPRO_STRICT_AUDIT", "") not in ("", "0")


class strict_audit_scope:
    """``with strict_audit_scope(): ...`` — site-label violations raise."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._token = None

    def __enter__(self):
        self._token = _STRICT_AUDIT.set(self.enabled)
        return self

    def __exit__(self, *exc):
        _STRICT_AUDIT.reset(self._token)
        return False


def _known_sites() -> frozenset:
    from repro.core.planner import site_registry
    return site_registry()


def check_dispatch_sites(counts: Optional[Dict[str, int]] = None) -> None:
    """Assert every recorded dispatch label is planner-known.

    The cheap DISPATCH_COUNTS <-> planner.model_gemms drift check: a
    dispatch under a site the planner does not know is an error, not a
    silent new dict key.  Call it next to ``clear_plan_cache`` in test
    utilities (and the engine does under strict audit)."""
    known = _known_sites()
    unknown = sorted(
        label
        for site in (counts if counts is not None else DISPATCH_COUNTS)
        for label in site.split("+") if label not in known)
    if unknown:
        raise RuntimeError(
            f"[AF007] dispatch site labels unknown to planner.model_gemms: "
            f"{unknown}; known sites: {sorted(known)}")


# ---------------------------------------------------------------------------
# weight quantization (the arrayflex_int8 backend's memoized prologue)

# site labels whose weights stay fp32 under a quantizing backend: the
# router's logits feed a discrete top-k — quantization noise there changes
# *which experts run* instead of adding bounded output error, which would
# break the backend-equivalence tolerance contract.
QUANT_EXEMPT_SITES = frozenset({"moe.router"})

# id(weight) -> (weakref-or-thunk, int8 codes, fp32 scales).  Keyed on the
# weight array's identity: model params are long-lived objects, so every
# dispatch after the first is a pure dict hit — the hot path never
# re-quantizes.  The weakref death callback evicts the entry, so a reused
# id can never serve a stale quantization (the `ref() is w` guard below
# covers interpreters whose GC defers callbacks).
_QUANT_CACHE: Dict[int, tuple] = {}
QUANT_CACHE_STATS = {"hits": 0, "misses": 0, "traced": 0}


def _quantize(w):
    """Symmetric per-output-channel int8: codes in [-127, 127], fp32
    scales over the contraction axis (-2), so ``codes * scale`` recovers
    the weight to within scale/2 per element."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w32 / scale[..., None, :]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def quantize_weight(w):
    """(int8 codes, fp32 per-output-channel scales) for a weight array,
    memoized on the array's identity.

    A 2-D (K, N) weight quantizes per output column (scales (N,)); an
    expert bank (E, K, N) per (expert, column) (scales (E, N)).  Concrete
    arrays hit the memo (``hits``/``misses`` in
    :data:`QUANT_CACHE_STATS`); tracers (dispatch under a jit trace)
    quantize in-graph and count as ``traced`` — the trace itself is
    cached by jit, so that cost is per-compilation, not per-step.
    """
    if isinstance(w, jax.core.Tracer):
        QUANT_CACHE_STATS["traced"] += 1
        return _quantize(w)
    key = id(w)
    ent = _QUANT_CACHE.get(key)
    if ent is not None and ent[0]() is w:
        QUANT_CACHE_STATS["hits"] += 1
        return ent[1], ent[2]
    QUANT_CACHE_STATS["misses"] += 1
    q, s = _quantize(w)
    if isinstance(q, jax.core.Tracer):
        # concrete weight quantized under an ambient trace (make_jaxpr /
        # jit over a closure lifts even concrete-operand ops into the
        # trace): memoizing the traced codes would leak tracers into
        # later dispatches — treat it as the in-trace path instead
        QUANT_CACHE_STATS["misses"] -= 1
        QUANT_CACHE_STATS["traced"] += 1
        return q, s
    try:
        ref = weakref.ref(w, lambda _, k=key: _QUANT_CACHE.pop(k, None))
    except TypeError:       # array type without weakref support: pin it
        ref = functools.partial(lambda v: v, w)
    _QUANT_CACHE[key] = (ref, q, s)
    return q, s


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """A weight pre-quantized at load time: int8 ``codes`` + fp32
    per-output-channel ``scale`` (the :func:`_quantize` pair), packaged
    as one pytree leaf-pair so it rides param trees through jit/scan —
    the scan over stacked super-blocks slices codes and scale together.

    ``lm.prequantize_params`` builds these once from the compute-dtype
    cast of each weight; the dispatch (:func:`gemm` / :func:`expert_gemm`)
    unpacks them directly instead of staging an in-trace requantize (the
    AF008 finding).  ``astype`` is a no-op: layers cast weights to the
    compute dtype *before* dispatch, and that cast is already baked into
    the codes."""

    __slots__ = ("codes", "scale")

    def __init__(self, codes, scale):
        self.codes = codes
        self.scale = scale

    def tree_flatten(self):
        return (self.codes, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    def astype(self, dtype):
        return self

    def __repr__(self):
        return (f"QuantizedTensor(codes={getattr(self.codes, 'shape', ())},"
                f" scale={getattr(self.scale, 'shape', ())})")


def prequantize(w) -> QuantizedTensor:
    """Eagerly quantize a weight into a :class:`QuantizedTensor`.

    Runs the same :func:`_quantize` the in-trace path stages (elementwise
    round/clip plus an exact max reduction), so eager codes are bitwise
    identical to what a compiled step would have recomputed — the
    pre-quantized tree changes *where* quantization runs, never its
    values."""
    q, s = _quantize(w)
    return QuantizedTensor(q, s)


def backend_quantizes(name: str) -> bool:
    """Whether the registered backend consumes int8 weights (and so a
    pre-quantized param tree applies to it)."""
    check_backend(name)
    return _BACKEND_INFO[name].quantize


def backend_act_quantizes(name: str) -> bool:
    """Whether the registered backend also quantizes activation tiles
    dynamically (the W8A8 datapath): its in-trace int8 activation casts
    are the priced Eq.(5') quantize boundary, not rogue re-quantization
    — the jaxpr auditor keys its AF003 classification on this."""
    check_backend(name)
    return _BACKEND_INFO[name].act_quantize


def quantize_cache_info() -> Dict[str, int]:
    """hits / misses / traced counters plus the memo's current size."""
    return dict(QUANT_CACHE_STATS, size=len(_QUANT_CACHE))


def clear_quant_cache():
    _QUANT_CACHE.clear()
    for k in QUANT_CACHE_STATS:
        QUANT_CACHE_STATS[k] = 0


# ---------------------------------------------------------------------------
# shard signature / context

@dataclass(frozen=True)
class ShardSig:
    """Post-partition signature of a sharded dispatch (plan-cache key part).

    ``rows``/``contraction``/``cols`` are the shard counts of the logical
    T / N / M dims; ``reduce_ops`` prices the contraction psum's combine
    tree (``ceil(log2(shards))`` boundary adds) into Eq.(5') — the reduce
    resolves at the collapsed-block boundary alongside the epilogue, so it
    rides the same ``d_epilogue_ps`` critical-path term.

    ``transfer_ops``/``transfer_cycles`` price a pipeline-stage boundary
    (the 'pod'-axis ``collective_permute`` of a GPipe stage) into the
    plan, the same way the TP psum already is: ``transfer_ops`` are
    boundary ALU stages on the period (the egress combine/packetize tree
    — k-independent, so they deepen the argmin exactly like epilogue
    ops), while ``transfer_cycles`` serialize the incoming activation's
    ICI ingress in front of the schedule at the array's clock (Eq. 6'' —
    paid at the k-collapsed period, so they SHALLOW the argmin).  A
    throughput-bound prefill stage prices the egress tree; a
    latency-bound decode stage sits behind the full ingress — which is
    how ``best_k`` legitimately differs per serving role at the same
    (M, N, T).
    """

    rows: int = 1
    contraction: int = 1
    cols: int = 1
    reduce_ops: int = 0
    transfer_ops: int = 0
    transfer_cycles: int = 0


SHARD_NONE = ShardSig()


def _spec_shards(mesh, entry) -> int:
    """Total shard count a PartitionSpec entry induces under ``mesh``
    (absent axes count 1 — ``sharding.mesh_axis_size`` is the single
    source of truth for that rule)."""
    from repro.parallel.sharding import mesh_axis_size
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    n = 1
    for a in axes:
        n *= mesh_axis_size(mesh, a)
    return n


@dataclass(frozen=True)
class ShardCtx:
    """How one substrate dispatch runs under the SPMD mesh.

    ``x_spec``/``w_spec``/``out_spec`` are PartitionSpecs of the operands
    *as dispatched* (x already flattened to ``(T, K)`` for :func:`gemm`;
    the batched/expert entries keep their leading batch/expert dims).
    ``reduce_axes`` names mesh axes the contraction is sharded over: each
    device computes a partial GEMM and the psum applies at the
    collapsed-block boundary, before the epilogue.  Derivation from the
    ``parallel.sharding`` site rules lives in ``sharding.gemm_shard_ctx``
    / ``batched_shard_ctx`` / ``expert_shard_ctx``.

    ``transfer_ops``/``transfer_cycles`` carry a pipeline-stage boundary
    price into the :class:`ShardSig` (see there).  A **pricing-only**
    context (``mesh is None``, replicated specs — built by
    ``sharding.pricing_shard_ctx``) keys the plan with the transfer terms
    but executes the dispatch unsharded: the GPipe path already runs the
    whole step under one 'pod' shard_map, so the per-stage GEMM must not
    nest another.
    """

    mesh: Any
    x_spec: Any
    w_spec: Any
    out_spec: Any
    reduce_axes: Tuple[str, ...] = ()
    transfer_ops: int = 0
    transfer_cycles: int = 0

    def axis_shards(self, entry) -> int:
        return _spec_shards(self.mesh, entry)

    def signature(self) -> ShardSig:
        """ShardSig for the 2-D :func:`gemm` entry (the plan-cache key)."""
        r = _spec_shards(self.mesh, tuple(self.reduce_axes) or None)
        return ShardSig(
            rows=self.axis_shards(self.x_spec[0]),
            contraction=self.axis_shards(self.x_spec[1]),
            cols=self.axis_shards(self.w_spec[1]),
            reduce_ops=math.ceil(math.log2(r)) if r > 1 else 0,
            transfer_ops=self.transfer_ops,
            transfer_cycles=self.transfer_cycles)

    def divides(self, T: int, K: int, N_out: int) -> bool:
        s = self.signature()
        return (T % s.rows == 0 and K % s.contraction == 0
                and N_out % s.cols == 0)


@dataclass(frozen=True)
class GemmPlan:
    """One plan-cache entry: logical shape, epilogue, shard signature,
    chosen depth, and *per-shard* Eq.(6') predictions (ps)."""

    M: int              # output columns (logical, pre-partition)
    N: int              # contraction (logical)
    T: int              # streamed rows (logical)
    backend: str
    k: int              # collapse depth the kernel runs with (1 off-ArrayFlex)
    t_pred_ps: float    # per-shard Eq.(6') model time at k
    t_conventional_ps: float  # per-shard fixed-pipeline SA baseline
    epilogue: Epilogue = EPILOGUE_NONE
    shard: ShardSig = SHARD_NONE
    M_shard: int = 0    # post-partition shape each device executes
    N_shard: int = 0
    T_shard: int = 0
    cycles: int = 0     # per-shard Eq.(4) cycles x fused contractions
    precision: str = "fp32"   # datapath the Eq.(5)-(7) pricing used

    @property
    def saving(self) -> float:
        return 1.0 - self.t_pred_ps / self.t_conventional_ps


@functools.lru_cache(maxsize=None)
def _plan_gemm_cached(M: int, N: int, T: int, backend: str,
                      epilogue: Epilogue, shard: ShardSig) -> GemmPlan:
    info = _BACKEND_INFO.get(backend)
    collapse = info.collapse if info else False
    precision = info.precision if info else "fp32"
    params = timing.timing_for(precision)
    Ms = -(-M // shard.cols)
    Ns = -(-N // shard.contraction)
    Ts = -(-T // shard.rows)
    # a quantizing backend's per-output-channel dequant multiply resolves
    # at the carry-propagate boundary like any fused op: one per contraction
    dequant_ops = epilogue.contractions if (info and info.quantize) else 0
    # a W8A8 backend's per-tile activation quantizer (amax + scale +
    # round/clip) is one more boundary stage, priced with its own Eq.(5')
    # coefficient (d_actq_ps) rather than d_epilogue_ps
    actq_ops = 1 if (info and info.act_quantize) else 0
    # a pipeline-stage boundary prices like the TP psum: its egress tree
    # is boundary ALU ops on the period, its ingress serializes cycles
    e_ops = (epilogue.ops + shard.reduce_ops + shard.transfer_ops
             + dequant_ops)
    k = (ops.plan_collapse(Ms, Ns, Ts, epilogue_ops=e_ops,
                           precision=precision, actq_ops=actq_ops,
                           transfer_cycles=shard.transfer_cycles)
         if collapse else 1)
    return GemmPlan(
        M=M, N=N, T=T, backend=backend, k=k, epilogue=epilogue, shard=shard,
        M_shard=Ms, N_shard=Ns, T_shard=Ts, precision=precision,
        cycles=epilogue.contractions * timing.total_cycles(
            Ms, Ns, Ts, ops.SA_R, ops.SA_C, k),
        t_pred_ps=timing.t_abs_ps(Ms, Ns, Ts, ops.SA_R, ops.SA_C, k,
                                  params=params, epilogue_ops=e_ops,
                                  contractions=epilogue.contractions,
                                  actq_ops=actq_ops,
                                  extra_cycles=shard.transfer_cycles),
        t_conventional_ps=timing.t_abs_conventional_ps(
            Ms, Ns, Ts, ops.SA_R, ops.SA_C, params=params,
            contractions=epilogue.contractions,
            epilogue_ops=e_ops, actq_ops=actq_ops,
            extra_cycles=shard.transfer_cycles))


# backend name -> {"hits": n, "misses": n} of plan_gemm lookups: which
# backends are planning fresh shapes vs running cache-hit-only.  Steady-
# state serving must be all hits (see plan_cache_info / the serving test).
PLAN_CACHE_STATS: Dict[str, Dict[str, int]] = {}


def plan_gemm(M: int, N: int, T: int, backend: str = "arrayflex",
              epilogue: Epilogue = EPILOGUE_NONE,
              shard: ShardSig = SHARD_NONE) -> GemmPlan:
    """Plan-cache entry point: Eq.(6') argmin once per
    (M, N, T, backend, epilogue, shard).

    (M, N, T) are the *logical* dims; the argmin runs on the
    post-partition per-shard shape — the GEMM the array actually executes
    under the mesh — and a sharded contraction prices its psum combine
    tree into the boundary ops (see :class:`ShardSig`).  The backend name
    carries the datapath precision: a quantizing backend prices Eq.(5')
    with its own ``timing`` coefficients plus one dequant boundary op per
    contraction, so the same shape legitimately plans a different k under
    int8 than under fp32.  Lookups are tallied per backend in
    :data:`PLAN_CACHE_STATS`."""
    before = _plan_gemm_cached.cache_info().misses
    plan = _plan_gemm_cached(M, N, T, backend, epilogue, shard)
    st = PLAN_CACHE_STATS.setdefault(backend, {"hits": 0, "misses": 0})
    missed = _plan_gemm_cached.cache_info().misses > before
    st["misses" if missed else "hits"] += 1
    return plan


@dataclass(frozen=True)
class PlanCacheInfo:
    """Aggregate lru stats plus the per-backend hit/miss tallies and the
    ``planner.attention_plan`` memo counters (chunk/page geometry picks —
    the serving zero-miss guarantee covers them too)."""

    hits: int
    misses: int
    maxsize: Optional[int]
    currsize: int
    per_backend: Dict[str, Dict[str, int]] = field(default_factory=dict)
    attention_plan: Dict[str, int] = field(default_factory=dict)

    def _asdict(self):
        return dataclasses.asdict(self)


def plan_cache_info() -> PlanCacheInfo:
    info = _plan_gemm_cached.cache_info()
    ap = planner.attention_plan.cache_info()
    return PlanCacheInfo(
        hits=info.hits, misses=info.misses, maxsize=info.maxsize,
        currsize=info.currsize,
        per_backend={b: dict(st) for b, st in PLAN_CACHE_STATS.items()},
        attention_plan={"hits": ap.hits, "misses": ap.misses,
                        "currsize": ap.currsize})


def clear_plan_cache():
    """Reset every plan memo this process holds: the Eq.(6') plan cache
    (and its per-backend tallies) AND the planner memos it feeds from
    (``ops.plan_collapse``, ``planner.attention_plan``) — a
    timing-parameter or config change must not see stale picks — plus the
    per-trace site/dispatch logs.  The weight-quantization memo is NOT a
    plan and survives (``clear_quant_cache`` resets it)."""
    _plan_gemm_cached.cache_clear()
    PLAN_CACHE_STATS.clear()
    ops.plan_collapse.cache_clear()
    planner.attention_plan.cache_clear()
    SITE_PLANS.clear()
    DISPATCH_COUNTS.clear()


# ---------------------------------------------------------------------------
# backend registry

def _prescale(x2, norm_scale):
    """Unfused-backend form of the prologue rmsnorm-scale: the same
    ``prologue_phase`` expression the kernel inlines per tile, applied to
    the whole x — fused and unfused paths agree bit for bit."""
    if norm_scale is None:
        return x2
    return prologue_phase(x2, norm_scale)


def _xla_backend(x2, w, plan: GemmPlan, call: GemmCall):
    ep = plan.epilogue
    x2 = _prescale(x2, call.norm_scale)
    if call.out_dtype is None:
        # bit-for-bit the pre-substrate path: operand-dtype contraction(s),
        # epilogue applied in the same op order the unfused layers used
        # (residual + out matches the layers' ``x + f(x)``)
        y = x2 @ w
        y2 = x2 @ call.w2 if ep.dual else None
        out = apply_epilogue(y, y2, call.bias, call.bias2, ep.activation)
        return out if call.residual is None else call.residual + out
    y = jnp.dot(x2, w, preferred_element_type=jnp.float32)
    y2 = (jnp.dot(x2, call.w2, preferred_element_type=jnp.float32)
          if ep.dual else None)
    out = apply_epilogue(y, y2, call.bias, call.bias2, ep.activation)
    if call.residual is not None:
        out = call.residual.astype(jnp.float32) + out
    return out.astype(call.out_dtype)


def _arrayflex_backend(x2, w, plan: GemmPlan, call: GemmCall):
    return ops.arrayflex_matmul(x2, w, w2=call.w2, bias=call.bias,
                                bias2=call.bias2, residual=call.residual,
                                norm_scale=call.norm_scale,
                                activation=plan.epilogue.activation,
                                k_collapse=plan.k, out_dtype=call.out_dtype,
                                interpret=call.interpret)


def _ref_backend(x2, w, plan: GemmPlan, call: GemmCall):
    x32 = _prescale(x2, call.norm_scale).astype(jnp.float32)
    y = jnp.dot(x32, w.astype(jnp.float32))
    y2 = (jnp.dot(x32, call.w2.astype(jnp.float32))
          if plan.epilogue.dual else None)
    b = None if call.bias is None else call.bias.astype(jnp.float32)
    b2 = None if call.bias2 is None else call.bias2.astype(jnp.float32)
    out = apply_epilogue(y, y2, b, b2, plan.epilogue.activation)
    if call.residual is not None:
        out = call.residual.astype(jnp.float32) + out
    return out.astype(call.out_dtype or x2.dtype)


def _arrayflex_int8_backend(x2, w, plan: GemmPlan, call: GemmCall):
    # w arrives pre-quantized from the dispatch's weight memo: int8 codes
    # with call.w_scale the per-output-channel fp32 dequant (w2 likewise).
    # A quantization-exempt site (moe.router) passes fp32 w with no scale
    # and runs the fp32 kernel unchanged, under the fp32-priced plan the
    # dispatch substitutes for exempt sites.
    return ops.arrayflex_matmul(x2, w, w2=call.w2, bias=call.bias,
                                bias2=call.bias2, w_scale=call.w_scale,
                                w2_scale=call.w2_scale,
                                residual=call.residual,
                                norm_scale=call.norm_scale,
                                activation=plan.epilogue.activation,
                                k_collapse=plan.k, out_dtype=call.out_dtype,
                                interpret=call.interpret)


def _arrayflex_w8a8_backend(x2, w, plan: GemmPlan, call: GemmCall):
    # Same operand contract as the int8 backend (codes + scales from the
    # dispatch memo); ``act_quant`` keys on the scales' presence, so an
    # exempt site (fp32 w, no scale — planned as the fp32 base) runs the
    # fp32 kernel while every quantized site engages the in-kernel
    # per-tile activation quantizer and the int8 x int8 -> int32 chain.
    return ops.arrayflex_matmul(x2, w, w2=call.w2, bias=call.bias,
                                bias2=call.bias2, w_scale=call.w_scale,
                                w2_scale=call.w2_scale,
                                act_quant=call.w_scale is not None,
                                residual=call.residual,
                                norm_scale=call.norm_scale,
                                activation=plan.epilogue.activation,
                                k_collapse=plan.k, out_dtype=call.out_dtype,
                                interpret=call.interpret)


@dataclass(frozen=True)
class BackendInfo:
    """Registry metadata driving planning and dispatch for one backend.

    ``collapse``: plans an Eq.(6') collapse depth (ArrayFlex-family
    kernels); others run k=1.  ``precision``: the datapath whose
    ``timing`` coefficients price Eq.(5)-(7) for this backend (part of
    the plan, carried by the backend name in the cache key).
    ``quantize``: the dispatch pre-quantizes weight operands through
    :func:`quantize_weight` and hands int8 codes + scales to ``fn``.
    ``act_quantize``: the backend also quantizes activation tiles
    dynamically in-kernel (W8A8) — planning prices one Eq.(5')
    activation-quantize boundary op (``timing`` ``d_actq_ps``) on top of
    the dequant ops.  Requires ``quantize`` (the kernel's int8 chain
    needs int8 weight codes on the other operand).
    """

    fn: Callable
    collapse: bool = False
    precision: str = "fp32"
    quantize: bool = False
    act_quantize: bool = False


_BACKENDS: Dict[str, Callable] = {}
_BACKEND_INFO: Dict[str, BackendInfo] = {}


def register_backend(name: str, fn: Callable, *, collapse: bool = False,
                     precision: str = "fp32",
                     quantize: bool = False,
                     act_quantize: bool = False) -> None:
    """fn(x2: (T, K), w: (K, N_out), plan: GemmPlan, call: GemmCall)
    -> (T, N_out).  ``call`` carries out_dtype, the epilogue operands
    (w2/bias/bias2 — apply with ``kernels.arrayflex_gemm.apply_epilogue``
    if not fusing), the dequant scales of a quantizing backend
    (``call.w_scale is None`` on paths that do not quantize: exempt
    sites, batched activation products — the fn must handle fp32
    operands then), and the Pallas interpret override.  See
    :class:`BackendInfo` for the keyword metadata.

    (Re-)registration evicts cached Eq.(6') plans: a plan embeds the
    backend's collapse/precision metadata, so a name whose metadata
    changes must not keep serving stale k picks."""
    timing.timing_for(precision)     # fail fast on unknown precisions
    if act_quantize and not quantize:
        raise ValueError(
            f"backend {name!r}: act_quantize requires quantize — the W8A8 "
            f"int8 chain multiplies quantized activation tiles against "
            f"int8 weight codes")
    _BACKENDS[name] = fn
    _BACKEND_INFO[name] = BackendInfo(fn=fn, collapse=collapse,
                                      precision=precision,
                                      quantize=quantize,
                                      act_quantize=act_quantize)
    _plan_gemm_cached.cache_clear()
    PLAN_CACHE_STATS.clear()


def backends():
    return sorted(_BACKENDS)


def check_backend(name: str) -> None:
    """Validate a backend name against the registry (the config-resolve-
    time guard: ModelConfig.gemm_backend / serve.py --gemm-backend call
    this before any dispatch, so an unknown name fails with the
    registered list instead of deep inside a jit trace)."""
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered: {backends()}")


def get_backend(name: str) -> Callable:
    check_backend(name)
    return _BACKENDS[name]


register_backend("xla", _xla_backend)
register_backend("arrayflex", _arrayflex_backend, collapse=True)
register_backend("arrayflex_int8", _arrayflex_int8_backend, collapse=True,
                 precision="int8", quantize=True)
register_backend("arrayflex_w8a8", _arrayflex_w8a8_backend, collapse=True,
                 precision="w8a8", quantize=True, act_quantize=True)
register_backend("ref", _ref_backend)

_BUILTIN_BACKENDS = {"xla": _xla_backend, "arrayflex": _arrayflex_backend,
                     "arrayflex_int8": _arrayflex_int8_backend,
                     "arrayflex_w8a8": _arrayflex_w8a8_backend,
                     "ref": _ref_backend}

# builtin quantizing backend -> the fp32 ArrayFlex base that exempt sites
# and non-quantizable dispatches plan (and, on the batched path, execute)
# instead — the recorded Eq.(6') prediction must match the datapath the
# array actually runs.
_QUANT_FP32_BASE = {"arrayflex_int8": "arrayflex",
                    "arrayflex_w8a8": "arrayflex"}

# Batched (activation x activation) sites the W8A8 backend quantizes:
# attn.qk only.  Both QK operands quantize dynamically — K per key column
# in-trace (one scale per key position, via _quantize), q per tile in the
# kernel prologue — and the resulting logit error is bounded relative to
# |q||k|, which the softmax tolerates at the gated tolerances.  attn.pv
# stays on the fp32 base: softmax concentrates the probability operand's
# mass near zero, and symmetric per-tile int8 (resolution amax/127 with
# amax ~ 1) would zero exactly the long tail of small attention weights
# that distinguishes outputs.  Cross-attention QK keeps the conservative
# fp32 base until separately validated.
BATCHED_ACTQ_SITES = frozenset({"attn.qk"})


def _is_builtin(name: str) -> bool:
    """True when ``name`` still resolves to the built-in implementation —
    a re-registered override must win on the batched/expert fast paths
    exactly as it does in :func:`gemm`."""
    return _BACKENDS.get(name) is _BUILTIN_BACKENDS.get(name)


# site label -> GemmPlan of the most recent trace through that site.
# Populated at jit-trace time (shapes are static there), so one model
# forward leaves exactly its GEMM working set behind for inspection.
# A fused dual-GEMM site like "mlp.wi_gate+mlp.wi_up" records the shared
# plan under BOTH component labels.
SITE_PLANS: Dict[str, GemmPlan] = {}

# site label (as passed, fused labels kept joined) -> number of substrate
# dispatches traced through that site.  For the arrayflex backend one
# dispatch == one kernel launch, so this is the launch count the MoE
# batching and epilogue fusion reduce (3E -> 3, 2 GEMM launches -> 1).
DISPATCH_COUNTS: Dict[str, int] = {}


def _maybe_chaos_fault(site: str) -> None:
    """Chaos injection point ``substrate.dispatch``: fail this GEMM launch
    when the ambient :mod:`repro.runtime.chaos` engine says so (no-op —
    one contextvar read — when chaos is inactive).  Dispatch runs at
    jit-trace time, so a fault fires at the launch/trace boundary of a
    compiled step; failed traces are not cached, so a retry re-dispatches
    and draws again.  Imports are lazy: substrate must not import serving
    at module load (serving imports substrate)."""
    from repro.runtime import chaos
    if chaos.fire("substrate.dispatch", site):
        from repro.serving.errors import KernelFault
        raise KernelFault(
            f"[chaos] injected GEMM launch fault at site {site!r} "
            f"(replayable: seed + draw index in the chaos log)")


def _record(site: str, plan: GemmPlan, launches: int = 1) -> None:
    if not site:
        if strict_audit_enabled():
            raise RuntimeError(
                "[AF007] unlabeled substrate dispatch under strict audit: "
                "every model GEMM must carry a planner site label")
        return
    if strict_audit_enabled():
        known = _known_sites()
        bad = [label for label in site.split("+") if label not in known]
        if bad:
            raise RuntimeError(
                f"[AF007] dispatch site {site!r} carries labels unknown to "
                f"planner.model_gemms: {bad}")
    for label in site.split("+"):
        SITE_PLANS[label] = plan
    DISPATCH_COUNTS[site] = DISPATCH_COUNTS.get(site, 0) + launches


def _epilogue_spec(epilogue: str, w2, bias, bias2, residual=None,
                   norm_scale=None) -> Epilogue:
    if epilogue not in EPILOGUE_KINDS:
        raise ValueError(f"unknown epilogue {epilogue!r}; "
                         f"supported: {EPILOGUE_KINDS}")
    if (epilogue == "swiglu") != (w2 is not None):
        raise ValueError("epilogue='swiglu' requires w2 (and only swiglu "
                         "takes a second contraction)")
    if bias2 is not None and w2 is None:
        raise ValueError("bias2 requires the w2 contraction")
    return Epilogue(kind=epilogue, bias=bias is not None,
                    bias2=bias2 is not None,
                    residual=residual is not None,
                    norm_scale=norm_scale is not None)


# ---------------------------------------------------------------------------
# dispatch

def _sharded_gemm(fn, x2, w, plan: GemmPlan, ctx: ShardCtx, call: GemmCall):
    """Run one planned 2-D GEMM under ``jax.shard_map``: each device
    executes the post-partition per-shard GEMM through ``fn`` at the
    plan's k.  A sharded contraction (``ctx.reduce_axes``) psums the
    partial fp32 accumulators at the collapsed-block boundary and applies
    the epilogue *after* the reduce (a per-shard bias/activation on
    partial sums would be wrong).

    Int8 operands (a quantizing backend): the dequant scales are (N_out,)
    vectors and shard with the output-column axis exactly like fused
    biases — replicated for a row-parallel (contraction-sharded) weight,
    column-sharded for a column-parallel one.  On the reduce path each
    shard dequants its *partial* accumulator before the psum (per-column
    scales distribute over the K sum, so pre-psum dequant is exact) and
    the cross-device psum itself stays fp32."""
    ep = plan.epilogue
    reduce_axes = ctx.reduce_axes
    col_spec = P(ctx.w_spec[1])          # (N_out,) operands follow out cols
    operands, in_specs = [x2, w], [ctx.x_spec, ctx.w_spec]
    flags = []
    for arr, spec in ((call.w2, ctx.w_spec), (call.w_scale, col_spec),
                      (call.w2_scale, col_spec), (call.bias, col_spec),
                      (call.bias2, col_spec),
                      # the residual stream is output-shaped: shard like out
                      (call.residual, ctx.out_spec),
                      # the prologue norm scale is (K,): follows x's
                      # contraction axis, so each shard scales its x slice
                      (call.norm_scale, P(ctx.x_spec[1]))):
        flags.append(arr is not None)
        if arr is not None:
            operands.append(arr)
            in_specs.append(spec)
    has_w2, has_s, has_s2, has_b, has_b2, has_r, has_g = flags
    # reduce path: the per-shard kernel runs the contraction(s) only, at
    # the SAME k the (reduce-priced) plan picked
    exec_plan = (dataclasses.replace(plan, epilogue=EPILOGUE_NONE)
                 if reduce_axes else plan)

    def body(*ops_):
        it = iter(ops_)
        xs, ws = next(it), next(it)
        w2s = next(it) if has_w2 else None
        ss = next(it) if has_s else None
        s2s = next(it) if has_s2 else None
        bs = next(it) if has_b else None
        b2s = next(it) if has_b2 else None
        rs = next(it) if has_r else None
        gs = next(it) if has_g else None
        if not reduce_axes:
            return fn(xs, ws, plan,
                      GemmCall(out_dtype=call.out_dtype, w2=w2s, bias=bs,
                               bias2=b2s, w_scale=ss, w2_scale=s2s,
                               residual=rs, norm_scale=gs,
                               interpret=call.interpret))
        # per-shard prologue scale is exact under the reduce: the (K,)
        # scale slice multiplies exactly the x columns this shard contracts
        pc = GemmCall(out_dtype=jnp.float32, w_scale=ss, norm_scale=gs,
                      interpret=call.interpret)
        y = jax.lax.psum(fn(xs, ws, exec_plan, pc), reduce_axes)
        y2 = (jax.lax.psum(fn(xs, w2s, exec_plan,
                              dataclasses.replace(pc, w_scale=s2s)),
                           reduce_axes)
              if has_w2 else None)
        out = apply_epilogue(
            y, y2,
            None if bs is None else bs.astype(jnp.float32),
            None if b2s is None else b2s.astype(jnp.float32),
            ep.activation)
        if rs is not None:       # residual joins after the post-psum epilogue
            out = rs.astype(jnp.float32) + out
        return out.astype(call.out_dtype or xs.dtype)

    return shard_map(body, mesh=ctx.mesh, in_specs=tuple(in_specs),
                     out_specs=ctx.out_spec, check_rep=False)(*operands)


def gemm(x, w, *, site: str = "", backend: str = "xla", out_dtype=None,
         epilogue: str = "none", w2=None, bias=None, bias2=None,
         residual=None, norm_scale=None, interpret=None,
         shard: Optional[ShardCtx] = None):
    """The substrate entry: x (..., K) @ w (K, N_out) -> (..., N_out).

    ``out_dtype=None`` returns the operands' dtype with the backend's
    native accumulation; passing a dtype requests fp32 accumulation cast
    to it (the unembed/logits contract).

    ``epilogue`` fuses post-GEMM work into the dispatch (one kernel launch
    on the arrayflex backend): ``"silu"``/``"gelu"`` apply the activation
    to ``x@w [+ bias]``; ``"swiglu"`` computes
    ``silu(x@w [+ bias]) * (x@w2 [+ bias2])`` — the dual-GEMM gated MLP in
    ONE launch.  ``residual`` (an output-shaped ``(..., N_out)`` array)
    fuses the transformer sublayer's ``residual + f(x)`` add after the
    activation/gate, at the same carry-propagate boundary — no extra HBM
    round-trip between a sublayer GEMM and its residual join.  A fused
    site label like ``"mlp.wi_gate+mlp.wi_up"`` records the shared plan
    under both component names.

    ``shard`` (a :class:`ShardCtx`) dispatches under the SPMD mesh: the
    plan is computed on the post-partition per-shard (M, N, T) — keyed in
    the plan cache by the shard signature — and each device runs its
    per-shard GEMM inside ``jax.shard_map`` (contraction shards psum at
    the collapsed-block boundary, then the epilogue applies).  A shard
    context whose counts do not divide the dims (or an empty operand)
    falls back to replicated dispatch.

    On a quantizing backend (``arrayflex_int8`` / ``arrayflex_w8a8``) the
    dispatch swaps ``w`` (and ``w2``) for int8 codes + per-output-channel
    fp32 scales through the weight memo (:func:`quantize_weight`) before
    planning/sharding — unless the site is quantization-exempt
    (:data:`QUANT_EXEMPT_SITES`).
    """
    fn = get_backend(backend)
    _maybe_chaos_fault(site)
    info = _BACKEND_INFO[backend]
    if norm_scale is not None and norm_scale.shape != (x.shape[-1],):
        raise ValueError(
            f"site {site!r}: norm_scale shape {norm_scale.shape} must be "
            f"(K,) = ({x.shape[-1]},) — it scales x's contraction axis")
    ep = _epilogue_spec(epilogue, w2, bias, bias2, residual, norm_scale)
    w_scale = w2_scale = None
    plan_backend = backend
    if isinstance(w, QuantizedTensor):
        # load-time pre-quantized weight (lm.prequantize_params): unpack
        # codes + scales directly — no in-trace requantize to stage
        if not info.quantize:
            raise ValueError(
                f"site {site!r}: pre-quantized weight dispatched on "
                f"non-quantizing backend {backend!r}")
        if site in QUANT_EXEMPT_SITES:
            raise ValueError(
                f"site {site!r} is quantization-exempt but received a "
                f"pre-quantized weight")
        w, w_scale = w.codes, w.scale
        if isinstance(w2, QuantizedTensor):
            w2, w2_scale = w2.codes, w2.scale
    elif info.quantize and site in QUANT_EXEMPT_SITES:
        # an exempt site runs fp32 weights with no dequant (the w8a8
        # kernel's activation quantizer keys off the scales and stays off
        # too): price (and record) it as the fp32 base so its Eq.(6')
        # prediction matches the datapath it actually executes
        plan_backend = _QUANT_FP32_BASE.get(backend, plan_backend)
    elif info.quantize and w.shape[0] and w.shape[-1]:
        w, w_scale = quantize_weight(w)
        if w2 is not None:
            w2, w2_scale = quantize_weight(w2)
    lead = x.shape[:-1]
    K = x.shape[-1]
    N_out = w.shape[-1]
    x2 = x.reshape(math.prod(lead), K)   # explicit rows: K may be 0
    T = x2.shape[0]
    r2 = (None if residual is None
          else residual.reshape(T, N_out))   # raises on shape mismatch
    if shard is not None and (T * K * N_out == 0
                              or not shard.divides(T, K, N_out)):
        shard = None
    call = GemmCall(out_dtype=out_dtype, w2=w2, bias=bias, bias2=bias2,
                    w_scale=w_scale, w2_scale=w2_scale, residual=r2,
                    norm_scale=norm_scale, interpret=interpret)
    if shard is not None:
        plan = plan_gemm(N_out, K, T, plan_backend, ep, shard.signature())
        _record(site, plan)
        # pricing-only context (mesh=None): the plan is keyed/priced with
        # the role's transfer terms but the dispatch itself is unsharded —
        # pipeline-stage transfer cost is paid by the ppermute, not here
        out = (fn(x2, w, plan, call) if shard.mesh is None
               else _sharded_gemm(fn, x2, w, plan, shard, call))
    else:
        plan = plan_gemm(N_out, K, T, plan_backend, ep)
        _record(site, plan)
        out = fn(x2, w, plan, call)
    return out.reshape(*lead, N_out)


def _batched_exec(x, w, plan: GemmPlan, backend: str, out_dtype, interpret):
    """Builtin batched execution (B, T, K) @ (B, K, N): ONE launch."""
    if backend == "arrayflex":
        return ops.arrayflex_expert_matmul(x, w, k_collapse=plan.k,
                                           out_dtype=out_dtype,
                                           interpret=interpret)
    if backend == "arrayflex_w8a8":
        # W8A8 QK: both operands are activations, and both quantize
        # dynamically — the "w" operand (K^T) per (batch, column) in-trace,
        # one scale per key position, and each q tile in the kernel
        # prologue.  The int8 x int8 -> int32 chain runs exactly as on
        # weight GEMMs; the per-key scales dequant at the store.
        qw, ws = _quantize(w)
        return ops.arrayflex_expert_matmul(x, qw, w_scale=ws,
                                           act_quant=True,
                                           k_collapse=plan.k,
                                           out_dtype=out_dtype,
                                           interpret=interpret)
    if backend == "ref":
        out = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
        return out.astype(out_dtype or x.dtype)
    if out_dtype is None:
        return jnp.matmul(x, w)
    return jnp.matmul(
        x, w, preferred_element_type=jnp.float32).astype(out_dtype)


def batched_gemm(x, w, *, site: str = "", backend: str = "xla",
                 out_dtype=None, interpret=None,
                 shard: Optional[ShardCtx] = None):
    """Batched GEMM: x (B, T, K) @ w (B, K, N) -> (B, T, N).

    The substrate path for attention QK/PV products (``attn.qk`` /
    ``attn.pv`` sites): every batch element runs the same planned shape,
    and the arrayflex backend executes ALL of them in one expert-batched
    kernel launch (batch = the leading grid dimension).  ``out_dtype``
    follows the :func:`gemm` contract (None -> operand dtype; a dtype ->
    fp32 accumulation cast once).

    ``shard`` (3-dim specs) splits the batch dim over mesh axes under
    ``jax.shard_map`` — each device runs ONE launch over its batch slice.
    Batch sharding leaves the per-element (M, N, T) unchanged, so the plan
    key does not change.  Custom backends and indivisible batches fall
    back to replicated dispatch.

    The batched operands are attention K/V *activations*, not weights —
    there is nothing to quantize once (weights-only quantization) — so
    the builtin ``arrayflex_int8`` backend maps to its fp32 ArrayFlex
    base (kernel AND plan), and a custom quantizing backend dispatches
    itself with ``call.w_scale=None`` (fp32 operands, the registry
    contract).  The ``arrayflex_w8a8`` backend *can* quantize an
    activation product — both operands dynamically — and does so on the
    sites in :data:`BATCHED_ACTQ_SITES` (``attn.qk``; PV stays on the
    fp32 base — see the constant's rationale), planned and recorded under
    the w8a8 datapath with the quantize boundary term priced.
    """
    check_backend(backend)
    _maybe_chaos_fault(site)
    if backend in _QUANT_FP32_BASE and not (
            _BACKEND_INFO[backend].act_quantize and _is_builtin(backend)
            and site in BATCHED_ACTQ_SITES):
        backend = _QUANT_FP32_BASE[backend]
    B, T, K = x.shape
    N_out = w.shape[-1]
    plan = plan_gemm(N_out, K, T, backend)
    if shard is not None and (not _is_builtin(backend)
                              or B % shard.axis_shards(shard.x_spec[0])):
        shard = None
    if shard is not None:
        _record(site, plan)

        def body(xs, ws):
            return _batched_exec(xs, ws, plan, backend, out_dtype, interpret)

        return shard_map(body, mesh=shard.mesh,
                         in_specs=(shard.x_spec, shard.w_spec),
                         out_specs=shard.out_spec, check_rep=False)(x, w)
    if _is_builtin(backend):
        _record(site, plan)
        return _batched_exec(x, w, plan, backend, out_dtype, interpret)
    # custom backend: unroll the (static) batch through the 2-D entry —
    # B launches, each recorded against the shared per-shape plan
    _record(site, plan, launches=B)
    fn = get_backend(backend)
    call = GemmCall(out_dtype=out_dtype, interpret=interpret)
    return jnp.stack([fn(x[b], w[b], plan, call) for b in range(B)])


def _expert_exec(x, w, plan: GemmPlan, backend: str, interpret,
                 w_scale=None, act_quant: bool = False):
    """Builtin expert execution (G, E, C, K) @ (E, K, N): ONE launch.
    ``w_scale`` (E, N): int8 expert bank, dequantized per expert at the
    kernel's carry-propagate store.  ``act_quant`` (W8A8): the kernel
    additionally quantizes each activation tile in its prologue and runs
    the int8 x int8 -> int32 chain."""
    if backend == "xla":
        return jnp.einsum("gecd,edf->gecf", x, w)
    if backend == "ref":
        out = jnp.einsum("gecd,edf->gecf", x.astype(jnp.float32),
                         w.astype(jnp.float32))
        return out.astype(x.dtype)
    G, E, C, K = x.shape
    N_out = w.shape[-1]
    xe = x.transpose(1, 0, 2, 3).reshape(E, G * C, K)
    out = ops.arrayflex_expert_matmul(xe, w, w_scale=w_scale,
                                      act_quant=act_quant,
                                      k_collapse=plan.k,
                                      interpret=interpret)
    return out.reshape(E, G, C, N_out).transpose(1, 0, 2, 3)


def expert_gemm(x, w, *, site: str = "", backend: str = "xla",
                interpret=None, shard: Optional[ShardCtx] = None):
    """Batched expert GEMM: x (G, E, C, K) @ w (E, K, N) -> (G, E, C, N).

    Every backend plans ONE consistent (M=N, N=K, T=G*C) shape per site —
    the per-expert GEMMs of a capacity-buffered MoE layer are identical,
    so one plan covers all E of them.  The xla backend keeps the einsum
    the MoE layer always used (one fused batched contraction); the
    arrayflex backend folds the dispatch groups into the row dim and runs
    ALL experts in ONE kernel launch whose leading grid dimension is the
    expert axis (per-site launch count: 1, was E).

    ``shard`` (from ``sharding.expert_shard_ctx``) runs expert-parallel:
    the expert axis splits over 'model' under ``jax.shard_map`` and each
    device launches once over its E/tp experts (per-expert shape — and so
    the plan — unchanged).  Custom backends and indivisible expert counts
    fall back to replicated dispatch.

    A quantizing backend swaps the expert bank for int8 codes + (E, N)
    scales through the weight memo; the scales shard with the expert
    axis, exactly as the bank does.
    """
    check_backend(backend)
    _maybe_chaos_fault(site)
    G, E, C, K = x.shape
    N_out = w.shape[-1]
    info = _BACKEND_INFO[backend]
    w_scale = None
    if isinstance(w, QuantizedTensor):
        if not info.quantize:
            raise ValueError(
                f"site {site!r}: pre-quantized expert bank dispatched on "
                f"non-quantizing backend {backend!r}")
        w, w_scale = w.codes, w.scale
    elif info.quantize and E and K and N_out:
        w, w_scale = quantize_weight(w)
    # W8A8: the expert kernel engages its in-kernel activation quantizer
    # whenever the bank is quantized (the plan priced the boundary term)
    actq = bool(info.act_quantize and w_scale is not None)
    plan = plan_gemm(N_out, K, G * C, backend)
    if shard is not None and (not _is_builtin(backend)
                              or E % shard.axis_shards(shard.x_spec[1])):
        shard = None
    if shard is not None:
        _record(site, plan)

        if w_scale is not None:
            def body_q(xs, ws, ss):
                return _expert_exec(xs, ws, plan, backend, interpret, ss,
                                    actq)

            return shard_map(
                body_q, mesh=shard.mesh,
                in_specs=(shard.x_spec, shard.w_spec,
                          P(shard.w_spec[0], None)),
                out_specs=shard.out_spec, check_rep=False)(x, w, w_scale)

        def body(xs, ws):
            return _expert_exec(xs, ws, plan, backend, interpret)

        return shard_map(body, mesh=shard.mesh,
                         in_specs=(shard.x_spec, shard.w_spec),
                         out_specs=shard.out_spec, check_rep=False)(x, w)
    if _is_builtin(backend):
        _record(site, plan)
        return _expert_exec(x, w, plan, backend, interpret, w_scale, actq)
    # custom backend: unroll the (static) expert axis through the 2-D
    # entry — E launches, each recorded against the shared per-shape plan
    # (a quantizing backend's per-expert dequant scales ride along)
    _record(site, plan, launches=E)
    fn = get_backend(backend)
    outs = [fn(x[:, e].reshape(G * C, K), w[e], plan,
               GemmCall(interpret=interpret,
                        w_scale=None if w_scale is None else w_scale[e])
               ).reshape(G, C, N_out)
            for e in range(E)]
    return jnp.stack(outs, axis=1)
