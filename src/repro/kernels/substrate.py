"""GEMM execution substrate: one dispatch layer for every model GEMM.

The paper's selection loop (core.planner / core.timing, Eqs. 6-7) picks a
pipeline-collapse depth k *per GEMM shape*; this module is the pipe that
makes those picks configure actual execution.  Every dense contraction in
nn/ and models/ routes through :func:`gemm` (or :func:`expert_gemm` for the
MoE batched form), which

  * resolves the GEMM's :class:`GemmPlan` from a process-wide **plan
    cache** keyed on ``(M, N, T, backend)`` — the Eq.(6) argmin runs once
    per shape, not once per jit trace or serving request;
  * records the plan under the caller's **site label** (``attn.wq``,
    ``mlp.wo``, ...), the same names ``core.planner.model_gemms`` emits,
    so analytic plans and executed kernels are the same objects (the
    substrate benchmark joins the two tables on these labels);
  * dispatches to a **backend** from a pluggable registry:

      ``xla``       today's ``x @ w`` (the default; numerics unchanged),
      ``arrayflex`` the Pallas K-collapse kernel at the planned k,
      ``ref``       an fp32-everywhere oracle for equivalence tests.

``ModelConfig.gemm_backend`` selects the backend model-wide; callers thread
it through (see models/lm.py).  New backends (quantized, sharded, ...)
register with :func:`register_backend`.

Shape convention matches core.planner: a call ``gemm(x, w)`` with
``x: (..., K)`` and ``w: (K, N_out)`` is the planner GEMM
``X[T, M] = A[T, N] x B[N, M]`` with ``M = N_out`` (output columns),
``N = K`` (contraction), ``T = prod(leading dims)`` (streamed rows).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax.numpy as jnp

from repro.core import timing
from repro.kernels import ops


@dataclass(frozen=True)
class GemmPlan:
    """One plan-cache entry: shape, chosen depth, Eq.(6) predictions (ps)."""

    M: int              # output columns
    N: int              # contraction
    T: int              # streamed rows
    backend: str
    k: int              # collapse depth the kernel runs with (1 off-ArrayFlex)
    t_pred_ps: float    # Eq.(6) model time at k
    t_conventional_ps: float  # fixed-pipeline SA baseline

    @property
    def saving(self) -> float:
        return 1.0 - self.t_pred_ps / self.t_conventional_ps


@functools.lru_cache(maxsize=None)
def plan_gemm(M: int, N: int, T: int, backend: str = "arrayflex") -> GemmPlan:
    """Plan-cache entry point: Eq.(6) argmin once per (M, N, T, backend)."""
    k = ops.plan_collapse(M, N, T) if backend == "arrayflex" else 1
    return GemmPlan(
        M=M, N=N, T=T, backend=backend, k=k,
        t_pred_ps=timing.t_abs_ps(M, N, T, ops.SA_R, ops.SA_C, k),
        t_conventional_ps=timing.t_abs_conventional_ps(
            M, N, T, ops.SA_R, ops.SA_C))


def plan_cache_info():
    return plan_gemm.cache_info()


def clear_plan_cache():
    plan_gemm.cache_clear()
    SITE_PLANS.clear()


# ---------------------------------------------------------------------------
# backend registry

def _xla_backend(x2, w, plan: GemmPlan, out_dtype):
    if out_dtype is None:
        return x2 @ w                       # bit-for-bit the pre-substrate path
    return jnp.dot(x2, w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def _arrayflex_backend(x2, w, plan: GemmPlan, out_dtype):
    return ops.arrayflex_matmul(x2, w, k_collapse=plan.k,
                                out_dtype=out_dtype)


def _ref_backend(x2, w, plan: GemmPlan, out_dtype):
    out = jnp.dot(x2.astype(jnp.float32), w.astype(jnp.float32))
    return out.astype(out_dtype or x2.dtype)


_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str, fn: Callable) -> None:
    """fn(x2: (T, K), w: (K, N_out), plan: GemmPlan, out_dtype) -> (T, N_out)."""
    _BACKENDS[name] = fn


def backends():
    return sorted(_BACKENDS)


def get_backend(name: str) -> Callable:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered: {backends()}")


register_backend("xla", _xla_backend)
register_backend("arrayflex", _arrayflex_backend)
register_backend("ref", _ref_backend)


# site label -> GemmPlan of the most recent trace through that site.
# Populated at jit-trace time (shapes are static there), so one model
# forward leaves exactly its GEMM working set behind for inspection.
SITE_PLANS: Dict[str, GemmPlan] = {}


# ---------------------------------------------------------------------------
# dispatch

def gemm(x, w, *, site: str = "", backend: str = "xla", out_dtype=None):
    """The substrate entry: x (..., K) @ w (K, N_out) -> (..., N_out).

    ``out_dtype=None`` returns the operands' dtype with the backend's
    native accumulation; passing a dtype requests fp32 accumulation cast
    to it (the unembed/logits contract).
    """
    fn = get_backend(backend)
    lead = x.shape[:-1]
    K = x.shape[-1]
    N_out = w.shape[-1]
    x2 = x.reshape(-1, K)
    plan = plan_gemm(N_out, K, x2.shape[0], backend)
    if site:
        SITE_PLANS[site] = plan
    out = fn(x2, w, plan, out_dtype)
    return out.reshape(*lead, N_out)


def expert_gemm(x, w, *, site: str = "", backend: str = "xla"):
    """Batched expert GEMM: x (G, E, C, K) @ w (E, K, N) -> (G, E, C, N).

    The xla backend keeps the einsum the MoE layer always used (one fused
    batched contraction); other backends unroll the (static) expert axis
    into per-expert substrate GEMMs so each runs the planned kernel.
    """
    G, E, C, K = x.shape
    N_out = w.shape[-1]
    if backend == "xla":
        if site:
            SITE_PLANS[site] = plan_gemm(N_out, K, G * C, backend)
        return jnp.einsum("gecd,edf->gecf", x, w)
    outs = [gemm(x[:, e], w[e], site=site if e == 0 else "",
                 backend=backend)
            for e in range(E)]
    return jnp.stack(outs, axis=1)
