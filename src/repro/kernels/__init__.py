# Pallas TPU kernels for the paper's compute hot-spot (the systolic-array
# GEMM itself, with configurable pipeline collapse) plus the fused flash
# attention that removes the framework's dominant HBM-traffic term.
from repro.kernels import ref, ops, substrate  # noqa: F401
from repro.kernels.arrayflex_gemm import arrayflex_gemm  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
