"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gemm_ref(x, w, out_dtype=None):
    """fp32-accumulated matmul oracle for arrayflex_gemm."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0):
    """Dense softmax-attention oracle.  q: (BH,S,D), k/v: (BH,T,D)."""
    BH, S, D = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bsd,btd->bst", q, k,
                   preferred_element_type=jnp.float32) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (cols <= rows)
    if window:
        ok = ok & (cols > rows - window)
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok[None], p, 0.0)
    out = jnp.einsum("bst,btd->bsd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
