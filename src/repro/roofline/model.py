"""Three-term roofline model for TPU v5e (the TARGET hardware).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

(The per-chip division is inherent: the analyzed HLO is the per-device
SPMD program.)  MODEL_FLOPS uses the 6·N·D / 2·N·D convention with
N = active parameters for MoE; an attention-inclusive variant is also
reported so long-context cells have an honest useful-FLOPs ratio.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute-term share of the binding term: 1.0 = compute-bound at
        peak; <1 means memory/collective dominate."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0


def terms_from_analysis(hlo: dict) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo["flops_per_device"] / PEAK_FLOPS_BF16,
        memory_s=hlo["hbm_bytes_per_device"] / HBM_BW,
        collective_s=hlo["collective_total_per_device"] / ICI_BW,
    )


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Useful-FLOPs estimates (whole job, all chips)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        toks = shape.tokens
        base = 6.0 * n_active * toks
        fwd_mult = 3.0
    elif shape.kind == "prefill":
        toks = shape.tokens
        base = 2.0 * n_active * toks
        fwd_mult = 1.0
    else:  # decode: one token per sequence
        toks = shape.global_batch
        base = 2.0 * n_active * toks
        fwd_mult = 1.0

    # attention score/value flops (excluded from the 6ND convention)
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.is_attn_layer(i))
    S = shape.seq_len
    B = shape.global_batch
    win = cfg.sliding_window or 0
    if shape.kind == "decode":
        ctx = min(S, win) if win else S
        attn = 4.0 * n_attn * B * ctx * cfg.n_heads * hd
    else:
        if win and win < S:
            pairs = S * win - win * win / 2.0
        else:
            pairs = S * S / 2.0
        attn = 4.0 * n_attn * B * pairs * cfg.n_heads * hd * fwd_mult
        attn += (4.0 * cfg.n_encoder_layers * B * S * S
                 * cfg.n_heads * hd * fwd_mult)
    return {"model_flops": base, "model_flops_with_attn": base + attn,
            "n_active_params": n_active,
            "n_params": cfg.param_count()}
