from repro.roofline import hlo, model  # noqa: F401
