"""Optimized-HLO analyzer: FLOPs / HBM bytes / collective bytes per device.

``jax.stages.Compiled.cost_analysis()`` counts every while-loop body ONCE and
reports per-device numbers — useless for scan-over-layers models where the
whole transformer lives inside a while body.  This module re-derives the
three roofline inputs from ``compiled.as_text()`` with correct loop
multipliers (XLA records ``known_trip_count`` in backend_config):

  * flops            — dot/convolution ops (everything else is noise)
  * hbm_bytes        — operand+result bytes of top-level (unfused) ops, with
                       slice-aware accounting: dynamic-slice / gather /
                       dynamic-update-slice fusions touch only their slice,
                       not the loop-carried buffer they index into
  * collective bytes — by kind, scaled by (n-1)/n with replica-group size n

All numbers are per-device: post-SPMD HLO shapes are shard-local.
``analyze(text, top_k=...)`` also returns per-source-op attributions so the
perf loop can see exactly which jax-level op dominates each term.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_ROOT_RE = re.compile(r"^\s*ROOT\s+%")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=\[\d+\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIM_LABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast",
                  "ragged-all-to-all")

_ZERO_MEM_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast",
                 "constant", "after-all", "partition-id", "replica-id",
                 "while", "conditional", "call", "iota", "rng-bit-generator"}

_SLICY = {"dynamic-slice", "gather", "slice"}


def _shapes_bytes(type_str: str) -> float:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return float(total)


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(d) for d in m.group(1).split(",")]
        return dims[-1] if dims else 1
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _trim_opname(meta: str) -> str:
    if not meta:
        return "(unattributed)"
    meta = re.sub(r"^jit\([^)]*\)/", "", meta)
    return meta


@dataclass
class Op:
    opcode: str
    flops: float = 0.0
    mem: float = 0.0
    res: float = 0.0
    coll_kind: str = ""
    coll_moved: float = 0.0
    edge: tuple = ()          # (name, mult, kind)
    src: str = ""


@dataclass
class Comp:
    ops: list = field(default_factory=list)
    # properties of the fused computation (when called via fusion)
    root_opcode: str = ""
    dus_update_bytes: float = 0.0
    slicy: bool = False
    unknown_trip: bool = False


def parse(text: str) -> tuple:
    comps: dict = {}
    cur = None
    symtab: dict = {}
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            is_entry, name = mc.groups()
            cur = Comp()
            comps[name] = cur
            symtab = {}
            if is_entry:
                entry = name
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, rest = md.groups()
        mop = re.match(r"^(\(.*?\)|[a-z][a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?)\s+"
                       r"([a-z][a-z0-9\-]*)\(", rest)
        if not mop:
            continue
        typ, opcode = mop.groups()
        symtab[name] = typ
        res_bytes = _shapes_bytes(typ)
        op_args = rest[mop.end():]
        # operand list ends at the first "), " at top paren depth — use a
        # cheap approximation: first ')' not inside brackets is fine for HLO
        close = op_args.find(")")
        operand_str = op_args[:close] if close >= 0 else op_args
        operands = _OPERAND_NAME_RE.findall(operand_str)
        opnd_types = [symtab.get(o, "") for o in operands]
        opnd_bytes = sum(_shapes_bytes(t) for t in opnd_types)
        mmeta = _METADATA_RE.search(rest)
        src = _trim_opname(mmeta.group(1) if mmeta else "")
        is_root = bool(_ROOT_RE.match(line))
        op = Op(opcode=opcode, src=src)

        if is_root:
            cur.root_opcode = opcode
            if opcode == "dynamic-update-slice" and len(opnd_types) > 1:
                cur.dus_update_bytes = _shapes_bytes(opnd_types[1])
        if opcode in _SLICY:
            cur.slicy = True

        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in COLLECTIVE_OPS:
            n = _group_size(line)
            if base == "all-gather":
                moved = res_bytes * (n - 1) / max(n, 1)
            elif base == "all-reduce":
                moved = opnd_bytes * 2.0 * (n - 1) / max(n, 1)
            elif base == "reduce-scatter":
                moved = opnd_bytes * (n - 1) / max(n, 1)
            elif base in ("all-to-all", "ragged-all-to-all"):
                moved = opnd_bytes * (n - 1) / max(n, 1)
            else:
                moved = res_bytes
            op.coll_kind = base
            op.coll_moved = moved
            op.mem = res_bytes + opnd_bytes
            cur.ops.append(op)
            continue
        if opcode.endswith("-done"):
            continue

        if opcode == "dot":
            mcd = _CONTRACT_RE.search(rest)
            inline = _SHAPE_RE.findall(operand_str)
            lhs_typ = opnd_types[0] if opnd_types else ""
            if not lhs_typ and inline:
                lhs_typ = inline[0][0] + "[" + inline[0][1] + "]"
            _, lhs_dims = _first_shape_dims(lhs_typ)
            _, res_dims = _first_shape_dims(typ)
            csize = 1
            if mcd and mcd.group(1):
                for i in (int(i) for i in mcd.group(1).split(",")):
                    if i < len(lhs_dims):
                        csize *= lhs_dims[i]
            out_n = 1
            for d in res_dims:
                out_n *= d
            op.flops = 2.0 * out_n * csize
            op.mem = res_bytes + opnd_bytes
            cur.ops.append(op)
            continue
        if opcode == "convolution":
            _, res_dims = _first_shape_dims(typ)
            out_n = 1
            for d in res_dims:
                out_n *= d
            _, ker_dims = _first_shape_dims(
                opnd_types[1] if len(opnd_types) > 1 else "")
            ml = _DIM_LABELS_RE.search(rest)
            k_mult = 1
            if ml and ker_dims:
                for ch, dim in zip(ml.group(2), ker_dims):
                    if ch != "o":
                        k_mult *= dim
            op.flops = 2.0 * out_n * k_mult
            op.mem = res_bytes + opnd_bytes
            cur.ops.append(op)
            continue

        if opcode == "fusion":
            mcall = _CALLS_RE.search(rest)
            if mcall:
                op.edge = (mcall.group(1), 1.0, "fusion")
            op.mem = res_bytes + opnd_bytes   # refined in analyze()
            op.res = res_bytes
            cur.ops.append(op)
            continue
        if opcode == "while":
            mb = _BODY_RE.search(rest)
            mt = _TRIP_RE.search(rest)
            trip = float(mt.group(1)) if mt else 1.0
            if not mt:
                cur.unknown_trip = True
            if mb:
                op.edge = (mb.group(1), trip, "while")
            cur.ops.append(op)
            continue
        ma = _TO_APPLY_RE.search(rest)
        if ma and opcode in ("call", "reduce", "sort", "scatter", "map",
                             "reduce-window", "select-and-scatter"):
            op.edge = (ma.group(1), 1.0, "call")
        if opcode == "conditional":
            for mm in re.finditer(r"computation[s]?=\{?%?([\w.\-]+)", rest):
                cur.ops.append(Op(opcode="call",
                                  edge=(mm.group(1), 1.0, "call"), src=src))
        if opcode in _ZERO_MEM_OPS:
            cur.ops.append(op)
            continue
        if opcode == "dynamic-slice":
            op.mem = 2.0 * res_bytes
        elif opcode == "dynamic-update-slice":
            upd = _shapes_bytes(opnd_types[1]) if len(opnd_types) > 1 else 0.0
            op.mem = 2.0 * upd
        elif opcode in ("gather", "slice"):
            op.mem = 2.0 * res_bytes
        else:
            op.mem = res_bytes + opnd_bytes
        cur.ops.append(op)

    return comps, entry


def analyze(text: str, top_k: int = 25) -> dict:
    comps, entry = parse(text)
    fusion_targets = set()
    for c in comps.values():
        for op in c.ops:
            if op.edge and op.edge[2] == "fusion":
                fusion_targets.add(op.edge[0])

    memo = {}

    def total(name, depth=0):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        zero = {"flops": 0.0, "mem": 0.0, "coll": {}, "unknown": False,
                "attr_flops": {}, "attr_mem": {}, "attr_coll": {}}
        if c is None or depth > 128:
            return zero
        res = {"flops": 0.0, "mem": 0.0, "coll": defaultdict(float),
               "unknown": c.unknown_trip,
               "attr_flops": defaultdict(float),
               "attr_mem": defaultdict(float),
               "attr_coll": defaultdict(float)}
        fused = name in fusion_targets
        for op in c.ops:
            res["flops"] += op.flops
            if op.flops:
                res["attr_flops"][op.src] += op.flops
            mem = 0.0 if fused else op.mem
            if op.edge:
                child, mult, kind = op.edge
                sub = total(child, depth + 1)
                if kind == "fusion":
                    tgt = comps.get(child)
                    if tgt is not None and not fused:
                        if tgt.root_opcode == "dynamic-update-slice":
                            mem = 2.0 * tgt.dus_update_bytes
                        elif tgt.slicy:
                            # touch the result + a same-sized read
                            mem = min(op.mem, 2.0 * op.res)
                res["flops"] += mult * sub["flops"]
                res["mem"] += mult * sub["mem"]
                res["unknown"] |= sub["unknown"]
                for k, v in sub["coll"].items():
                    res["coll"][k] += mult * v
                for k, v in sub["attr_flops"].items():
                    res["attr_flops"][k] += mult * v
                for k, v in sub["attr_mem"].items():
                    res["attr_mem"][k] += mult * v
                for k, v in sub["attr_coll"].items():
                    res["attr_coll"][k] += mult * v
            res["mem"] += mem
            if mem and not fused:
                res["attr_mem"][op.src] += mem
            if op.coll_kind:
                res["coll"][op.coll_kind] += op.coll_moved
                res["attr_coll"][op.src] += op.coll_moved
        memo[name] = res
        return res

    t = total(entry) if entry else None
    if t is None:
        return {"flops_per_device": 0.0, "hbm_bytes_per_device": 0.0,
                "collective_bytes_per_device": {},
                "collective_total_per_device": 0.0,
                "unknown_trip_count": True,
                "top_flops": [], "top_mem": [], "top_coll": []}

    def top(d):
        return sorted(((k, v) for k, v in d.items()), key=lambda kv: -kv[1])[
            :top_k]

    return {
        "flops_per_device": t["flops"],
        "hbm_bytes_per_device": t["mem"],
        "collective_bytes_per_device": dict(t["coll"]),
        "collective_total_per_device": float(sum(t["coll"].values())),
        "unknown_trip_count": bool(t["unknown"]),
        "top_flops": top(t["attr_flops"]),
        "top_mem": top(t["attr_mem"]),
        "top_coll": top(t["attr_coll"]),
    }
