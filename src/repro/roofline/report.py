"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep
results (results/dryrun/*.json).

Usage: PYTHONPATH=src python -m repro.roofline.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")
ARCH_ORDER = [
    "jamba-1.5-large-398b", "mixtral-8x22b", "qwen3-moe-30b-a3b",
    "llama-3.2-vision-90b", "qwen2-0.5b", "llama3-8b", "qwen2.5-14b",
    "stablelm-12b", "whisper-base", "mamba2-370m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    out = {}
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            p = os.path.join(RESULTS, f"{a}_{s}_{mesh}.json")
            if os.path.exists(p):
                try:
                    out[(a, s)] = json.load(open(p))
                except Exception:
                    pass
    return out


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 0.001:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def dryrun_table():
    lines = ["| arch | shape | mesh | status | compile | GiB/chip | fits |",
             "|---|---|---|---|---|---|---|"]
    for mesh in ("1pod", "2pod"):
        cells = load(mesh)
        for (a, s), r in cells.items():
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | {mesh} | SKIP ({r['reason'][:40]}…) | | | |")
            elif r["status"] == "ok":
                m = r["memory"]
                lines.append(
                    f"| {a} | {s} | {mesh} | ok | {r['compile_s']:.0f}s | "
                    f"{m['per_device_gib']:.2f} | "
                    f"{'yes' if m['fits_16g_hbm'] else 'NO'} |")
            else:
                lines.append(f"| {a} | {s} | {mesh} | {r['status']} | | | |")
    return "\n".join(lines)


def roofline_table():
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO | (+attn) | what would move the dominant term |",
             "|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        "compute": "larger per-step tiles (deeper collapse), drop remat "
                   "recompute, TP attention heads",
        "memory": "fuse attention/SSD inner loops into Pallas kernels "
                  "(VMEM-resident score blocks)",
        "collective": "overlap FSDP gathers with compute; EP dispatch "
                      "all-to-alls; int8 DP compression",
    }
    cells = load("1pod")
    for (a, s), r in cells.items():
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        lines.append(
            f"| {a} | {s} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {t['useful_flops_ratio']:.3f} | "
            f"{t['useful_flops_ratio_with_attn']:.3f} | "
            f"{fixes[t['dominant']]} |")
    return "\n".join(lines)


def collective_breakdown(arch, shape, mesh="1pod"):
    p = os.path.join(RESULTS, f"{arch}_{shape}_{mesh}.json")
    r = json.load(open(p))
    return r["hlo"]["collective_bytes_per_device"]


def main():
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline (single-pod, per chip)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
