"""CNN layer -> im2col GEMM shapes (M, N, T) for the paper's benchmarks.

Convention (paper §II): X[T,M] = A[T,N] x B[N,M] where for a conv layer
  M = C_out,  N = kh*kw*C_in,  T = H_out*W_out   (batch 1 inference).

Anchors from the paper (§III-C): ResNet-34 layer 20 -> (256, 2304, 196) and
layer 28 -> (512, 2304, 49); tests pin these.

Depthwise convolutions (MobileNet, ConvNeXt) do not map to a single dense
GEMM; following the paper's "everything is GEMM" mapping we model them as a
channel-batched GEMM with N = kh*kw and T = spatial*C (executed per channel
group on the SA) — a small fraction of total time either way.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ConvLayer:
    name: str
    M: int
    N: int
    T: int

    @property
    def mnt(self):
        return (self.M, self.N, self.T)


def _conv(name, c_out, c_in, k, out_hw):
    return ConvLayer(name, c_out, k * k * c_in, out_hw * out_hw)


def _dw(name, c, k, out_hw):
    # depthwise: channel-batched GEMM (see module docstring)
    return ConvLayer(name, c, k * k, out_hw * out_hw)


def _fc(name, c_out, c_in):
    return ConvLayer(name, c_out, c_in, 1)


def resnet34_layers():
    """The 33 conv layers + final fc of ResNet-34 at 224x224."""
    ls = [_conv("conv1", 64, 3, 7, 112)]
    # conv2_x: 3 blocks x 2 convs @ 56, 64ch
    for i in range(6):
        ls.append(_conv(f"conv2_{i}", 64, 64, 3, 56))
    # conv3_x: 4 blocks x 2 convs @ 28, 128ch (first takes 64ch)
    ls.append(_conv("conv3_0", 128, 64, 3, 28))
    for i in range(1, 8):
        ls.append(_conv(f"conv3_{i}", 128, 128, 3, 28))
    # conv4_x: 6 blocks x 2 convs @ 14, 256ch
    ls.append(_conv("conv4_0", 256, 128, 3, 14))
    for i in range(1, 12):
        ls.append(_conv(f"conv4_{i}", 256, 256, 3, 14))
    # conv5_x: 3 blocks x 2 convs @ 7, 512ch
    ls.append(_conv("conv5_0", 512, 256, 3, 7))
    for i in range(1, 6):
        ls.append(_conv(f"conv5_{i}", 512, 512, 3, 7))
    ls.append(_fc("fc", 1000, 512))
    return ls


def mobilenet_layers():
    """MobileNet-v1 (224x224, alpha=1): standard + 13x(dw,pw) + fc."""
    ls = [_conv("conv0", 32, 3, 3, 112)]
    spec = [  # (c_in, c_out, out_hw after this block's dw stride)
        (32, 64, 112), (64, 128, 56), (128, 128, 56), (128, 256, 28),
        (256, 256, 28), (256, 512, 14), (512, 512, 14), (512, 512, 14),
        (512, 512, 14), (512, 512, 14), (512, 512, 14), (512, 1024, 7),
        (1024, 1024, 7),
    ]
    for i, (cin, cout, hw) in enumerate(spec):
        ls.append(_dw(f"dw{i}", cin, 3, hw))
        ls.append(_conv(f"pw{i}", cout, cin, 1, hw))
    ls.append(_fc("fc", 1000, 1024))
    return ls


def convnext_layers():
    """ConvNeXt-T (224x224): stem + stages [3,3,9,3] x (dw7x7, pw, pw)."""
    ls = [_conv("stem", 96, 3, 4, 56)]
    dims = [96, 192, 384, 768]
    depths = [3, 3, 9, 3]
    hws = [56, 28, 14, 7]
    for s, (dim, depth, hw) in enumerate(zip(dims, depths, hws)):
        if s > 0:
            ls.append(_conv(f"ds{s}", dim, dims[s - 1], 2, hw))
        for b in range(depth):
            ls.append(_dw(f"s{s}b{b}_dw", dim, 7, hw))
            ls.append(_conv(f"s{s}b{b}_pw1", 4 * dim, dim, 1, hw))
            ls.append(_conv(f"s{s}b{b}_pw2", dim, 4 * dim, 1, hw))
    ls.append(_fc("head", 1000, 768))
    return ls


NETWORKS = {
    "resnet34": resnet34_layers,
    "mobilenet": mobilenet_layers,
    "convnext": convnext_layers,
}


def network_mnt(name: str):
    return [l.mnt for l in NETWORKS[name]()]
