"""ArrayFlex latency & clock models — Eqs. (1)-(7) of the paper.

Matrix multiply X[T,M] = A[T,N] x B[N,M] on an R x C weight-stationary SA:

  Eq.(1)  L        = 2R + C + T - 2                     (conventional, k=1)
  Eq.(3)  L(k)     = R + R/k + C/k + T - 2              (k-collapsed)
  Eq.(4)  L_tot(k) = L(k) * ceil(N/R) * ceil(M/C)
  Eq.(5)  T_clk(k) = d_FF + d_mul + d_add + k(d_CSA + 2 d_mux)
  Eq.(6)  T_abs(k) = L_tot(k) * T_clk(k)
  Eq.(7)  k_hat    = sqrt( (R+C)/(R+T-2) * (d_FF+d_mul+d_add)/(d_CSA+2d_mux) )

Clock numbers are calibrated to the paper's 28nm silicon results:
conventional SA 2.0 GHz; ArrayFlex 1.8 / 1.7 / 1.4 GHz at k = 1 / 2 / 4.
A least-squares fit of Eq.(5) to those three points gives
d_base = 492.6 ps and d_inc = 54.4 ps (the 'linear' model); 'table' mode
uses the published frequencies exactly and falls back to the fit elsewhere.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class TimingParams:
    # Eq.(5) coefficients (ps), least-squares fit to the paper's silicon
    d_base_ps: float = 492.6      # d_FF + d_mul + d_add
    d_inc_ps: float = 54.35       # d_CSA + 2*d_mux
    conventional_period_ps: float = 500.0   # 2.0 GHz fixed-pipeline SA
    # published ArrayFlex operating points (GHz)
    freq_table_ghz: tuple = ((1, 1.8), (2, 1.7), (4, 1.4))
    mode: str = "table"           # "table" | "linear"
    supported_k: tuple = (1, 2, 4)

    def clock_period_ps(self, k: int) -> float:
        """Minimum clock period of a k-collapsed ArrayFlex pipeline."""
        if self.mode == "table":
            for kk, ghz in self.freq_table_ghz:
                if kk == k:
                    return 1000.0 / ghz
        return self.d_base_ps + k * self.d_inc_ps

    def clock_ghz(self, k: int) -> float:
        return 1000.0 / self.clock_period_ps(k)


DEFAULT_TIMING = TimingParams()


def latency_cycles_conventional(R: int, C: int, T: int) -> int:
    """Eq.(1)."""
    return 2 * R + C + T - 2


def latency_cycles(R: int, C: int, T: int, k: int) -> int:
    """Eq.(3).  k must divide R and C for exact collapse."""
    return R + math.ceil(R / k) + math.ceil(C / k) + T - 2


def num_tiles(N: int, M: int, R: int, C: int) -> int:
    return math.ceil(N / R) * math.ceil(M / C)


def total_cycles(M: int, N: int, T: int, R: int, C: int, k: int) -> int:
    """Eq.(4)."""
    return latency_cycles(R, C, T, k) * num_tiles(N, M, R, C)


def total_cycles_conventional(M: int, N: int, T: int, R: int, C: int) -> int:
    return latency_cycles_conventional(R, C, T) * num_tiles(N, M, R, C)


def t_abs_ps(M: int, N: int, T: int, R: int, C: int, k: int,
             params: TimingParams = DEFAULT_TIMING) -> float:
    """Eq.(6): absolute execution time (ps) on a k-collapsed ArrayFlex."""
    return total_cycles(M, N, T, R, C, k) * params.clock_period_ps(k)


def t_abs_conventional_ps(M: int, N: int, T: int, R: int, C: int,
                          params: TimingParams = DEFAULT_TIMING) -> float:
    """Fixed-pipeline SA at its (higher) max clock."""
    return (total_cycles_conventional(M, N, T, R, C)
            * params.conventional_period_ps)


def k_hat(R: int, C: int, T: int,
          params: TimingParams = DEFAULT_TIMING) -> float:
    """Eq.(7): continuous optimal collapse depth."""
    return math.sqrt(((R + C) / (R + T - 2))
                     * (params.d_base_ps / params.d_inc_ps))


def best_k(M: int, N: int, T: int, R: int, C: int,
           params: TimingParams = DEFAULT_TIMING) -> int:
    """Discrete argmin of Eq.(6) over the supported collapse depths."""
    return min(params.supported_k,
               key=lambda k: t_abs_ps(M, N, T, R, C, k, params))
