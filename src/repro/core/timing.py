"""ArrayFlex latency & clock models — Eqs. (1)-(7) of the paper.

Matrix multiply X[T,M] = A[T,N] x B[N,M] on an R x C weight-stationary SA:

  Eq.(1)  L        = 2R + C + T - 2                     (conventional, k=1)
  Eq.(3)  L(k)     = R + R/k + C/k + T - 2              (k-collapsed)
  Eq.(4)  L_tot(k) = L(k) * ceil(N/R) * ceil(M/C)
  Eq.(5)  T_clk(k) = d_FF + d_mul + d_add + k(d_CSA + 2 d_mux)
  Eq.(6)  T_abs(k) = L_tot(k) * T_clk(k)
  Eq.(7)  k_hat    = sqrt( (R+C)/(R+T-2) * (d_FF+d_mul+d_add)/(d_CSA+2d_mux) )

Fused epilogues (bias add, activation, gated multiply) extend Eq.(5): the
carry-propagate stage at the collapsed-block boundary gains ``e`` fused
vector operations, each adding ``d_epi`` to the critical path, so

  Eq.(5')  T_clk(k, e) = T_clk(k) + e * d_epi
  Eq.(6')  T_abs(k, e) = n_con * L_tot(k) * T_clk(k, e)

where ``n_con`` counts fused contractions (2 for the dual-GEMM swiglu
epilogue, which streams both weight matrices through the same collapsed
schedule).  Because the epilogue term is k-independent while the cycle
count falls with k, a fused epilogue shifts the Eq.(6) argmin toward
deeper collapse — ``best_k`` re-picks k accordingly.

Clock numbers are calibrated to the paper's 28nm silicon results:
conventional SA 2.0 GHz; ArrayFlex 1.8 / 1.7 / 1.4 GHz at k = 1 / 2 / 4.
A least-squares fit of Eq.(5) to those three points gives
d_base = 492.6 ps and d_inc = 54.4 ps (the 'linear' model); 'table' mode
uses the published frequencies exactly and falls back to the fit elsewhere.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class TimingParams:
    # Eq.(5) coefficients (ps), least-squares fit to the paper's silicon
    d_base_ps: float = 492.6      # d_FF + d_mul + d_add
    d_inc_ps: float = 54.35       # d_CSA + 2*d_mux
    conventional_period_ps: float = 500.0   # 2.0 GHz fixed-pipeline SA
    # published ArrayFlex operating points (GHz)
    freq_table_ghz: tuple = ((1, 1.8), (2, 1.7), (4, 1.4))
    mode: str = "table"           # "table" | "linear"
    supported_k: tuple = (1, 2, 4)
    # Eq.(5') epilogue coefficient: critical-path cost of one fused vector
    # op (bias add / activation / gated multiply) at the carry-propagate
    # stage.  Sized like a CSA+mux stage — the epilogue ALU sits behind the
    # same collapsed-block boundary the carry-propagate adder does.
    d_epilogue_ps: float = 54.35
    # Eq.(5') activation-quantize coefficient: critical-path cost of the
    # dynamic per-tile quantizer (amax reduce + reciprocal scale +
    # round/clip) that feeds the MAC datapath each collapsed-block step.
    # 0 on datapaths with no quantize boundary (fp32, weight-only int8 —
    # activations arrive at datapath width there).
    d_actq_ps: float = 0.0

    def clock_period_ps(self, k: int, epilogue_ops: int = 0,
                        actq_ops: int = 0) -> float:
        """Eq.(5'): minimum clock period of a k-collapsed ArrayFlex
        pipeline with ``epilogue_ops`` fused vector ops and ``actq_ops``
        activation-quantize stages at the boundary."""
        epi = (epilogue_ops * self.d_epilogue_ps
               + actq_ops * self.d_actq_ps)
        if self.mode == "table":
            for kk, ghz in self.freq_table_ghz:
                if kk == k:
                    return 1000.0 / ghz + epi
        return self.d_base_ps + k * self.d_inc_ps + epi

    def clock_ghz(self, k: int, epilogue_ops: int = 0,
                  actq_ops: int = 0) -> float:
        return 1000.0 / self.clock_period_ps(k, epilogue_ops, actq_ops)


DEFAULT_TIMING = TimingParams()


@dataclass(frozen=True)
class IntTimingParams(TimingParams):
    """Eq.(5)/(7) coefficients for an **int8-weight** ArrayFlex datapath
    (fp32 accumulation, per-output-channel dequant at the boundary).

    What changes vs the fp32 fit and why:

    * ``d_base_ps`` (= d_FF + d_mul + d_add) shrinks *moderately*: the
      8x8 multiplier is far smaller than the fp32 one, but d_FF and the
      accumulate add stay — accumulation is fp32 by contract, so d_add is
      still the fp32 adder.  492.6 -> 372.6 ps (the fitted fp32 d_mul
      shrunk by ~120 ps).
    * ``d_inc_ps`` (= d_CSA + 2 d_mux, the per-k collapse cost) shrinks
      *a lot*: the transparent stages' carry-save chain carries 8-bit
      partial products instead of 32-bit ones, so the CSA stage is a
      single narrow full-adder row and the bypass muxes switch a narrow
      bus.  54.35 -> 15.0 ps.

    Because d_base/d_inc RISES (9.1 -> 24.8), Eq.(7)'s k_hat rises too:
    the int8 datapath amortizes its (cheap) collapse stages over more
    merged pipeline levels, so the Eq.(6') argmin moves toward DEEPER
    collapse than the fp32 datapath picks at the same (M, N, T) — e.g.
    T=512 plans k=2 under fp32 silicon and k=4 here.  There is no
    published int8 silicon to tabulate, so ``mode="linear"`` prices
    every k from the Eq.(5) fit.

    The conventional (fixed-pipeline) int8 SA comparator clocks at
    ``conventional_period_ps = 357.1`` (2.8 GHz): the k=1 linear period
    (387.6 ps) scaled by the same mux-overhead ratio the fp32 numbers
    exhibit (500 / 546.95).

    The per-output-channel dequant multiply is NOT part of these
    coefficients: it resolves at the carry-propagate boundary exactly
    like a fused epilogue op, so the substrate prices it as one extra
    Eq.(5') boundary op per contraction (``d_epilogue_ps``).
    """

    d_base_ps: float = 372.6     # d_FF + d_mul(int8) + d_add(fp32 accum)
    d_inc_ps: float = 15.0       # d_CSA(8-bit chain) + 2*d_mux(narrow bus)
    conventional_period_ps: float = 357.1   # 2.8 GHz fixed-pipeline int8 SA
    freq_table_ghz: tuple = ()
    mode: str = "linear"         # no published int8 silicon: use the fit


INT8_TIMING = IntTimingParams()


@dataclass(frozen=True)
class W8A8TimingParams(IntTimingParams):
    """Eq.(5)/(7) coefficients for the **fully-int8** (W8A8) datapath:
    int8 weights x int8 activations with an int32 accumulator.

    What changes vs the weight-only int8 fit and why:

    * ``d_base_ps`` shrinks again: the weight-only datapath still paid the
      fp32 accumulate adder (``d_add``) because activations arrived at
      fp32 width.  With activations quantized at the boundary the MAC is
      int8 x int8 -> int32 end to end, so d_add is a narrow int32
      carry-select add.  372.6 -> 280.0 ps (~93 ps shaved off the adder).
    * ``d_inc_ps`` stays 15.0: the collapse chain already carried narrow
      partial products under weight-only int8.
    * ``d_actq_ps = 54.35``: the *new* Eq.(5') boundary term.  The dynamic
      per-tile quantizer (amax reduce over the tile, reciprocal scale,
      round/clip to int8) sits at the collapsed-block boundary in front of
      the MAC array, exactly where the carry-propagate/epilogue ALU sits
      behind it, so it is sized like one epilogue stage.  Like the fused
      epilogue term it is k-independent while cycle counts fall with k —
      so pricing the quantize boundary pushes the Eq.(6') argmin toward
      deeper collapse.  On the pinned (M=896, N=4864, T=512) decode cell
      this term is decisive: without it the W8A8 coefficients pick k=2
      (like fp32 silicon), with it the argmin moves to k=4.

    The conventional fixed-pipeline W8A8 comparator clocks at 269.7 ps
    (3.71 GHz): the k=1 linear period (295.0 ps) scaled by the same
    mux-overhead ratio the fp32 numbers exhibit (500 / 546.95).  It pays
    the same ``d_actq_ps`` per period (a fixed pipeline still has to
    quantize), keeping the *saving* a measure of transparent pipelining.

    The per-tile activation scale resolves at the carry-propagate boundary
    together with the weight dequant — the substrate folds both into the
    fused ``store_phase`` dequant, so no extra epilogue op is priced for
    the activation scale beyond the ``d_actq_ps`` stage itself.
    """

    d_base_ps: float = 280.0     # d_FF + d_mul(int8) + d_add(int32 accum)
    conventional_period_ps: float = 269.7   # 3.71 GHz fixed-pipeline W8A8
    d_actq_ps: float = 54.35     # per-tile amax + scale + round/clip stage


W8A8_TIMING = W8A8TimingParams()

# precision name -> the TimingParams pricing that datapath's Eq.(5)-(7)
PRECISION_TIMING = {"fp32": DEFAULT_TIMING, "int8": INT8_TIMING,
                    "w8a8": W8A8_TIMING}


def timing_for(precision: str) -> TimingParams:
    """The Eq.(5)-(7) coefficient set for a datapath precision."""
    try:
        return PRECISION_TIMING[precision]
    except KeyError:
        raise ValueError(f"unknown datapath precision {precision!r}; "
                         f"supported: {sorted(PRECISION_TIMING)}")


def latency_cycles_conventional(R: int, C: int, T: int) -> int:
    """Eq.(1)."""
    return 2 * R + C + T - 2


def latency_cycles(R: int, C: int, T: int, k: int) -> int:
    """Eq.(3).  k must divide R and C for exact collapse."""
    return R + math.ceil(R / k) + math.ceil(C / k) + T - 2


def num_tiles(N: int, M: int, R: int, C: int) -> int:
    return math.ceil(N / R) * math.ceil(M / C)


def total_cycles(M: int, N: int, T: int, R: int, C: int, k: int) -> int:
    """Eq.(4)."""
    return latency_cycles(R, C, T, k) * num_tiles(N, M, R, C)


def total_cycles_conventional(M: int, N: int, T: int, R: int, C: int) -> int:
    return latency_cycles_conventional(R, C, T) * num_tiles(N, M, R, C)


def t_abs_ps(M: int, N: int, T: int, R: int, C: int, k: int,
             params: TimingParams = DEFAULT_TIMING,
             epilogue_ops: int = 0, contractions: int = 1,
             actq_ops: int = 0, extra_cycles: int = 0) -> float:
    """Eq.(6''): absolute execution time (ps) on a k-collapsed ArrayFlex.

    ``epilogue_ops`` prices fused post-GEMM work into the per-step period
    (Eq. 5'); ``actq_ops`` prices the dynamic activation-quantize boundary
    stages of a W8A8 datapath; ``contractions`` > 1 streams that many
    weight matrices through the same collapsed schedule (the dual-GEMM
    swiglu epilogue).  ``extra_cycles`` serializes additional array
    cycles in front of the schedule — the ICI ingress of a
    pipeline-stage activation transfer, clocked at the array's period.
    It multiplies the k-dependent period but not the k-dependent cycle
    count, so unlike the boundary-op terms it pushes the Eq.(6) argmin
    toward SHALLOWER collapse (a k-collapsed array pays the transfer at
    its slower clock).
    """
    return ((contractions * total_cycles(M, N, T, R, C, k) + extra_cycles)
            * params.clock_period_ps(k, epilogue_ops, actq_ops))


def t_abs_conventional_ps(M: int, N: int, T: int, R: int, C: int,
                          params: TimingParams = DEFAULT_TIMING,
                          contractions: int = 1,
                          epilogue_ops: int = 0,
                          actq_ops: int = 0,
                          extra_cycles: int = 0) -> float:
    """Fixed-pipeline SA at its (higher) max clock, with the SAME fused
    epilogue datapath (``epilogue_ops`` boundary ops on the period), the
    SAME activation-quantize stages (``actq_ops``), and the SAME
    serialized transfer cycles (``extra_cycles`` — a fixed pipeline must
    ship stage activations too).  Pricing all three into both machines
    keeps the *saving* a measure of the transparent-pipelining technique
    alone — otherwise every fused GEMM would be charged the epilogue
    against an epilogue-free baseline that must run it as an (uncosted)
    post-pass anyway."""
    return ((contractions * total_cycles_conventional(M, N, T, R, C)
             + extra_cycles)
            * (params.conventional_period_ps
               + epilogue_ops * params.d_epilogue_ps
               + actq_ops * params.d_actq_ps))


def k_hat(R: int, C: int, T: int,
          params: TimingParams = DEFAULT_TIMING) -> float:
    """Eq.(7): continuous optimal collapse depth."""
    return math.sqrt(((R + C) / (R + T - 2))
                     * (params.d_base_ps / params.d_inc_ps))


def best_k(M: int, N: int, T: int, R: int, C: int,
           params: TimingParams = DEFAULT_TIMING,
           epilogue_ops: int = 0, actq_ops: int = 0,
           extra_cycles: int = 0) -> int:
    """Discrete argmin of Eq.(6'') over the supported collapse depths.

    The epilogue and activation-quantize terms are additive on the
    period, so they never change the ordering *between* two depths with
    equal cycle counts but can tip the argmin toward deeper collapse
    (fewer boundary crossings amortize the fixed boundary cost better).
    ``extra_cycles`` (serialized stage-transfer ingress) works the other
    way: every extra cycle is paid at the k-collapsed period, so a
    transfer-heavy GEMM tips toward shallower collapse."""
    return min(params.supported_k,
               key=lambda k: t_abs_ps(M, N, T, R, C, k, params,
                                      epilogue_ops, actq_ops=actq_ops,
                                      extra_cycles=extra_cycles))
