"""ArrayFlex planner: per-layer pipeline-depth selection + framework hooks.

Three planning surfaces:

1. ``plan_network``    — the paper's use-case: per-CNN-layer optimal k
                         (latency, power, EDP vs a conventional SA).
2. ``model_gemms``     — walks a transformer ModelConfig x ShapeConfig into
                         its (M, N, T) GEMM list so the same planner drives
                         LLM workloads (beyond-paper generalization).
3. ``attention_plan``  — maps the paper's cycles-vs-clock tradeoff onto the
                         KV-chunk size of the sequence-sharded attention and
                         the K-block collapse of the Pallas GEMM kernel:
                         steps = T/kc (fewer with bigger chunks) while
                         per-step cost grows affinely with kc — literally
                         Eq.(3) x Eq.(5) with (kc/base) playing k.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass
from typing import List

from repro.configs.base import ModelConfig, ShapeConfig, SSMConfig
from repro.core import timing
from repro.core.timing import TimingParams, DEFAULT_TIMING
from repro.core import power as power_lib


@dataclass(frozen=True)
class GEMM:
    name: str
    M: int
    N: int
    T: int
    count: int = 1        # how many times this GEMM runs (e.g. layers)
    # fused-epilogue pricing (Eq. 5'/6'): vector ops at the collapsed-block
    # boundary and fused contraction count (2 = dual-GEMM swiglu)
    epilogue_ops: int = 0
    contractions: int = 1
    # pipeline-stage transfer pricing (disaggregated pod roles):
    # ``transfer_ops`` boundary send ops join the Eq.(5') per-step period
    # (a compute-bound prefill stage — pushes best_k DEEPER);
    # ``transfer_cycles`` serialize in front of the schedule at the
    # k-collapsed period (Eq. 6'', a latency-bound decode stage's ingress
    # — pushes best_k SHALLOWER).  model_gemms decorates the pipeline
    # boundary site with these from the config's pp role.
    transfer_ops: int = 0
    transfer_cycles: int = 0


@dataclass
class LayerPlan:
    gemm: GEMM
    k: int
    k_hat: float
    cycles: int
    clock_ghz: float
    t_abs_ps: float
    t_conventional_ps: float

    @property
    def saving(self) -> float:
        return 1.0 - self.t_abs_ps / self.t_conventional_ps


def plan_gemm(g: GEMM, R: int, C: int,
              tp: TimingParams = DEFAULT_TIMING,
              actq_ops: int = 0) -> LayerPlan:
    # transfer_ops price exactly like boundary epilogue ops (the same
    # Eq.(5') slot the substrate's shard.transfer_ops joins), and
    # transfer_cycles thread to the Eq.(6'') extra-cycles term — the
    # analytic table and the shard-keyed plan cache price identically.
    e = g.epilogue_ops + g.transfer_ops
    k = timing.best_k(g.M, g.N, g.T, R, C, tp, epilogue_ops=e,
                      actq_ops=actq_ops, extra_cycles=g.transfer_cycles)
    return LayerPlan(
        gemm=g, k=k, k_hat=timing.k_hat(R, C, g.T, tp),
        cycles=g.contractions * timing.total_cycles(g.M, g.N, g.T, R, C, k),
        clock_ghz=tp.clock_ghz(k, e, actq_ops),
        t_abs_ps=timing.t_abs_ps(g.M, g.N, g.T, R, C, k, tp,
                                 epilogue_ops=e,
                                 contractions=g.contractions,
                                 actq_ops=actq_ops,
                                 extra_cycles=g.transfer_cycles) * g.count,
        t_conventional_ps=timing.t_abs_conventional_ps(
            g.M, g.N, g.T, R, C, tp, contractions=g.contractions,
            epilogue_ops=e, actq_ops=actq_ops,
            extra_cycles=g.transfer_cycles) * g.count,
    )


def plan_gemm_precision(g: GEMM, R: int, C: int,
                        precision: str = "fp32") -> LayerPlan:
    """:func:`plan_gemm` priced for a datapath precision.

    ``int8`` uses ``timing.IntTimingParams`` (Eq. 5'/7 with the int8
    d_mul/d_CSA) and adds one dequant boundary op per contraction —
    exactly the pricing ``kernels.substrate`` applies for the
    ``arrayflex_int8`` backend, so the analytic table and the executed
    plan pick the same k.  ``w8a8`` uses ``timing.W8A8TimingParams``
    (int8 mul + int32-accumulate adder) and additionally prices the
    Eq.(5') activation-quantize boundary stage (``actq_ops=1``,
    ``d_actq_ps``) — the pricing the ``arrayflex_w8a8`` backend plans
    with."""
    tp = timing.timing_for(precision)
    actq = 0
    if precision in ("int8", "w8a8"):
        g = dataclasses.replace(g, epilogue_ops=g.epilogue_ops
                                + g.contractions)
    if precision == "w8a8":
        actq = 1
    return plan_gemm(g, R, C, tp, actq_ops=actq)


def precision_table(cfg: "ModelConfig", shape: "ShapeConfig",
                    R: int = 128, C: int = 128,
                    precisions=("fp32", "int8", "w8a8")) -> list:
    """Side-by-side per-GEMM plans across datapath precisions for one
    (model, shape) cell: every ``model_gemms`` entry with one
    :class:`LayerPlan` per precision.  This is where the quantized
    backends' planning story is visible analytically — the int8 datapath
    legitimately picks a different (usually deeper) k at the same shape,
    and the w8a8 datapath's quantize boundary term can deepen it again:
    the per-layer configurability the paper argues for, three ways."""
    return [{"gemm": g,
             "plans": {p: plan_gemm_precision(g, R, C, p)
                       for p in precisions}}
            for g in model_gemms(cfg, shape)]


def plan_network(gemms: List[GEMM], R: int, C: int,
                 tp: TimingParams = DEFAULT_TIMING,
                 pp=None) -> dict:
    pp = pp or power_lib.DEFAULT_POWER
    plans = [plan_gemm(g, R, C, tp) for g in gemms]
    t_af = sum(p.t_abs_ps for p in plans)
    t_cv = sum(p.t_conventional_ps for p in plans)
    e_af = sum(power_lib.power_arrayflex(p.k, tp, pp) * p.t_abs_ps
               for p in plans)
    e_cv = power_lib.power_conventional(tp, pp) * t_cv
    p_af, p_cv = e_af / t_af, e_cv / t_cv
    return {
        "plans": plans,
        "time_arrayflex_ps": t_af, "time_conventional_ps": t_cv,
        "latency_saving": 1.0 - t_af / t_cv,
        "avg_power_arrayflex": p_af, "avg_power_conventional": p_cv,
        "power_saving": 1.0 - p_af / p_cv,
        "edp_gain": (p_cv * t_cv ** 2) / (p_af * t_af ** 2),
    }


# ---------------------------------------------------------------------------
# transformer GEMM walker

def _postshard(g: GEMM, dp: int, tp: int, experts: int,
               qk_batch: int) -> GEMM:
    """Post-partition view of one analytic GEMM under a (data, model)
    mesh, mirroring ``parallel.sharding.gemm_shard_ctx``: column-parallel
    sites divide M by tp, row-parallel sites divide N by tp and price the
    boundary psum combine tree as epilogue ops, every 2-D site divides
    its streamed rows by dp, and the batched/expert sites divide their
    ``count`` by the shards of their batch/expert axis.  Indivisible axes
    replicate (dims unchanged) — the same fallback the dispatch takes.

    ``qk_batch`` is the runtime batch axis of the attention products
    (B*KV): the dispatch shards on it, NOT on the analytic count
    (n_attn*B*H), whose extra factors would claim sharding the runtime
    cannot perform (GQA under high TP).  The divisibility chain itself is
    ``sharding.batched_shard_count`` — the same function the dispatch
    uses."""
    from repro.parallel.sharding import (_COL_SITES, _ROW_SITES,
                                         batched_shard_count)
    if g.name in ("attn.qk", "attn.pv"):
        return dataclasses.replace(
            g, count=g.count // batched_shard_count(qk_batch, dp, tp))
    if g.name in ("moe.wi_gate", "moe.wi_up", "moe.wo"):
        if tp > 1 and experts % tp == 0:
            return dataclasses.replace(g, count=g.count // tp)
        return g
    M, N, T, e = g.M, g.N, g.T, g.epilogue_ops
    if dp > 1 and T % dp == 0:
        T //= dp
    if g.name in _COL_SITES and tp > 1 and M % tp == 0:
        M //= tp
    elif g.name in _ROW_SITES and tp > 1 and N % tp == 0:
        N //= tp
        e += math.ceil(math.log2(tp))
    return dataclasses.replace(g, M=M, N=N, T=T, epilogue_ops=e)


def model_gemms(cfg: ModelConfig, shape: ShapeConfig) -> List[GEMM]:
    """Every GEMM one step of this (model, shape) cell executes.

    T is the streamed dimension (tokens), N the contraction, M the output.
    Attention score/PV products fold batch*heads into the tile count via
    ``count`` (the SA processes them back to back).

    When ``cfg.mesh_shape`` declares a (data, model) mesh (and
    ``gemm_sharding`` is not "none"), every entry is the *post-partition*
    per-device GEMM — the shape the sharded substrate actually executes —
    so the analytic table and the shard-keyed plan cache stay joined.
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    toks = shape.global_batch if shape.kind == "decode" else shape.tokens
    S_ctx = (min(shape.seq_len, cfg.sliding_window or shape.seq_len)
             if shape.kind == "decode" else shape.seq_len)
    out: List[GEMM] = []
    n_attn = n_mamba = n_moe = n_dense = n_cross = 0
    for i in range(cfg.n_layers):
        if cfg.is_attn_layer(i):
            n_attn += 1
        else:
            n_mamba += 1
        if cfg.is_moe_layer(i):
            n_moe += 1
        elif cfg.d_ff:
            n_dense += 1
        if cfg.is_cross_attn_layer(i) or cfg.family == "audio":
            n_cross += 1
    if n_attn:
        # the qkv projections carry the fused rmsnorm scale (ln1 rides the
        # kernel's step prologue — see nn/layers.rmsnorm_normalize): one
        # Eq.(5') boundary op each
        out += [
            GEMM("attn.wq", H * hd, d, toks, n_attn, epilogue_ops=1),
            GEMM("attn.wk", KV * hd, d, toks, n_attn, epilogue_ops=1),
            GEMM("attn.wv", KV * hd, d, toks, n_attn, epilogue_ops=1),
            GEMM("attn.wo", d, H * hd, toks, n_attn),
            # scores & PV: per (batch, head): A[T=S_q, N=hd] x B[hd, S_kv]
            GEMM("attn.qk", S_ctx, hd,
                 1 if shape.kind == "decode" else shape.seq_len,
                 n_attn * shape.global_batch * H),
            GEMM("attn.pv", hd, S_ctx,
                 1 if shape.kind == "decode" else shape.seq_len,
                 n_attn * shape.global_batch * H),
        ]
    if n_mamba:
        ssm = cfg.ssm or SSMConfig()
        d_in = cfg.d_inner
        bc = 2 * ssm.n_groups * ssm.d_state
        out += [
            GEMM("mamba.z", d_in, d, toks, n_mamba),
            GEMM("mamba.xbc", d_in + bc, d, toks, n_mamba),
            GEMM("mamba.dt", cfg.ssm_heads, d, toks, n_mamba),
            GEMM("mamba.out", d, d_in, toks, n_mamba),
        ]
    if n_dense:
        # the wi pair executes as ONE fused dual-GEMM swiglu launch (see
        # nn/layers.swiglu): each entry carries the Eq.(5') epilogue term
        # (silu + gate + the fused ln2 rmsnorm scale = 3 boundary ops) so
        # per-entry t_abs sums to exactly the fused plan's contractions=2
        # prediction and best_k matches the substrate's
        # plan_collapse(..., epilogue_ops=3) pick
        out += [
            GEMM("mlp.wi_gate", cfg.d_ff, d, toks, n_dense, epilogue_ops=3),
            GEMM("mlp.wi_up", cfg.d_ff, d, toks, n_dense, epilogue_ops=3),
            GEMM("mlp.wo", d, cfg.d_ff, toks, n_dense),
        ]
    if n_moe and cfg.moe:
        m = cfg.moe
        eff = m.expert_d_ff or cfg.d_ff
        cap_toks = int(toks * m.top_k * m.capacity_factor / m.num_experts)
        cap_toks = max(cap_toks, 1)
        out += [
            GEMM("moe.router", m.num_experts, d, toks, n_moe),
            GEMM("moe.wi_gate", eff, d, cap_toks, n_moe * m.num_experts),
            GEMM("moe.wi_up", eff, d, cap_toks, n_moe * m.num_experts),
            GEMM("moe.wo", d, eff, cap_toks, n_moe * m.num_experts),
        ]
    if n_cross:
        xl = (cfg.n_image_tokens if cfg.family == "vlm"
              else cfg.max_source_positions)
        out += [
            GEMM("xattn.wq", H * hd, d, toks, n_cross),
            GEMM("xattn.kv", 2 * KV * hd, d,
                 xl * shape.global_batch, n_cross),
            GEMM("xattn.wo", d, H * hd, toks, n_cross),
        ]
    out.append(GEMM("unembed", cfg.padded_vocab, d,
                    shape.global_batch if shape.kind == "decode"
                    else shape.tokens, 1))
    ms = tuple(getattr(cfg, "mesh_shape", ()) or ())
    sharding_on = getattr(cfg, "gemm_sharding", "auto") != "none"
    if len(ms) == 2 and (ms[0] > 1 or ms[1] > 1) and sharding_on:
        E = cfg.moe.num_experts if cfg.moe else 0
        out = [_postshard(g, ms[0], ms[1], E, shape.global_batch * KV)
               for g in out]
    elif len(ms) == 3 and sharding_on:
        # (pod, data, model) role mesh: the intra-role (data, model)
        # partition applies as above, then the pipeline boundary site is
        # decorated with the role's stage-transfer terms — the
        # post-partition per-stage view a disaggregated pod actually plans
        pp, dp, tp_ = ms
        if dp > 1 or tp_ > 1:
            E = cfg.moe.num_experts if cfg.moe else 0
            out = [_postshard(g, dp, tp_, E, shape.global_batch * KV)
                   for g in out]
        role = getattr(cfg, "pp_role", "")
        if pp > 1 and role:
            from repro.parallel.sharding import (PP_BOUNDARY_SITE,
                                                 pp_transfer_terms)
            decorated = []
            for g in out:
                if g.name == PP_BOUNDARY_SITE:
                    t_ops, t_cyc = pp_transfer_terms(role, pp, g.T, g.N)
                    g = dataclasses.replace(g, transfer_ops=t_ops,
                                            transfer_cycles=t_cyc)
                decorated.append(g)
            out = decorated
    return out


def plan_model(cfg: ModelConfig, shape: ShapeConfig, R: int = 128,
               C: int = 128, tp: TimingParams = DEFAULT_TIMING) -> dict:
    return plan_network(model_gemms(cfg, shape), R, C, tp)


# ---------------------------------------------------------------------------
# dispatch-site registry (the substrate <-> planner naming contract)

# Dispatch sites the runtime labels but ``model_gemms`` does not walk:
#   frontend.img / frontend.audio — the VLM/audio frontend projections run
#     once per request, outside the per-step GEMM walk the analytic table
#     models (they are not part of any shape cell's steady-state cost);
#   mlp.wi — the biased gelu MLP variant nn.layers.gelu_mlp offers; no
#     registered arch uses it, but its dispatch label is contracted here so
#     the layer stays auditable.
EXTRA_DISPATCH_SITES = frozenset({"frontend.img", "frontend.audio",
                                  "mlp.wi"})


@functools.lru_cache(maxsize=None)
def site_registry() -> frozenset:
    """Every site label a substrate dispatch may legally carry: the union
    of ``model_gemms`` names over all registered archs (train + decode
    shapes, so every family branch is walked) plus
    :data:`EXTRA_DISPATCH_SITES`.  This is the single source of truth the
    strict-audit runtime check (``substrate._record``) and the jaxpr
    auditor validate dispatch labels against."""
    from repro.configs import ARCHS        # late: configs -> planner cycle
    names = set(EXTRA_DISPATCH_SITES)
    shapes = (ShapeConfig("audit_train", 64, 2, "train"),
              ShapeConfig("audit_decode", 64, 2, "decode"))
    for cfg in ARCHS.values():
        for shape in shapes:
            names.update(g.name for g in model_gemms(cfg, shape))
    return frozenset(names)


# ---------------------------------------------------------------------------
# attention-chunk planning (the kv-scan analogue of pipeline collapse)

def attention_plan(seq_len: int, kv_len: int,
                   choices=(256, 512, 1024, 2048, 4096),
                   step_overhead: float = 1.0, per_elem: float = 1.0 / 1024,
                   waste: float = 0.0):
    """Pick the KV chunk size: minimize steps * (overhead + work-per-step),
    the Eq.(6) structure with kc as the collapse factor.  Costs are in
    arbitrary units; overhead models the per-step fixed latency (dispatch,
    pipeline fill) exactly like the d_base term of Eq.(5).

    Memoized (pure function of small scalars): jit re-traces and
    per-request serving calls hit the same shapes repeatedly.

    Ragged ``kv_len`` is costed exactly: ``floor(kv_len/kc)`` full chunks
    plus one remainder chunk that only pays for the elements it covers, so
    every choice competes on its true ceil-step cost (no candidate is
    skipped, no uncosted fallback).

    ``waste`` prices the allocation granularity of the choice: the trailing
    ``ceil(kv_len/kc)*kc - kv_len`` elements are reserved but never touched.
    At 0 (chunk planning) the term vanishes — a scan chunk costs nothing
    when skipped; for K/V *page* planning (:func:`page_plan`) those elements
    are resident pool memory and compete against per-step overhead."""
    return _attention_plan_cached(seq_len, kv_len, tuple(choices),
                                  step_overhead, per_elem, waste)


@functools.lru_cache(maxsize=None)
def _attention_plan_cached(seq_len, kv_len, choices, step_overhead,
                           per_elem, waste=0.0):
    if not choices:
        raise ValueError("attention_plan needs at least one chunk choice")
    best, best_cost = None, float("inf")
    for kc in choices:
        kc_eff = min(kc, kv_len)
        full, rem = divmod(kv_len, kc_eff)
        cost = full * (step_overhead + per_elem * kc_eff * seq_len)
        if rem:
            cost += step_overhead + per_elem * rem * seq_len
        if waste:
            alloc = (full + (1 if rem else 0)) * kc_eff
            cost += waste * per_elem * (alloc - kv_len)
        if cost < best_cost:
            best, best_cost = kc_eff, cost
    return best


attention_plan.cache_info = _attention_plan_cached.cache_info
attention_plan.cache_clear = _attention_plan_cached.cache_clear


# Candidate K/V page sizes for the paged serving engine (tokens per page).
# Powers of two so that any power-of-two max_seq is exactly tiled — the
# engine requires page | max_seq to keep the gathered logical cache view the
# same length as the dense cache (the bit-exactness contract).
PAGE_SIZE_CHOICES = (8, 16, 32, 64, 128, 256)


def page_plan(max_seq: int, expected_len: int = 0,
              choices=PAGE_SIZE_CHOICES, step_overhead: float = 1.0,
              per_elem: float = 1.0 / 1024, waste: float = 0.5):
    """Pick the K/V page size with the same Eq.(6) machinery that picks the
    attention chunk: steps = pages walked per sequence (each pays the fixed
    block-table/gather overhead, the d_base analogue) against per-page work
    plus the ``waste`` term — the trailing page fraction a sequence of
    ``expected_len`` tokens reserves but never fills.  Small pages waste no
    memory but multiply per-step overhead; one giant page is the dense
    layout.  Shares :func:`attention_plan`'s memo, so the serving zero-miss
    guarantee covers page planning too.

    Only divisors of ``max_seq`` compete (the paged/dense bit-exactness
    contract needs ``page * n_pages_per_seq == max_seq``); the argmin is
    rounded up to the next divisor when ``expected_len`` clips it."""
    expected_len = expected_len or max(1, max_seq // 2)
    divs = tuple(c for c in choices if c <= max_seq and max_seq % c == 0)
    if not divs:
        return max_seq
    kc = attention_plan(1, expected_len, choices=divs,
                        step_overhead=step_overhead, per_elem=per_elem,
                        waste=waste)
    for d in divs:
        if d >= kc:
            return d
    return divs[-1]
