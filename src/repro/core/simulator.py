"""Cycle-accurate functional simulator of the ArrayFlex systolic array.

Weight-stationary R x C array computing X[T,C] = A[T,R] x B[R,C] per tile,
with configurable transparent pipelining (collapse depth k, paper §III):

  * horizontal: the input stream broadcasts to groups of k columns per cycle
    (bypassed+clock-gated inter-column registers),
  * vertical: the partial-sum path crosses k rows per cycle through the
    3:2 carry-save adder chain; a carry-propagate add fires at each
    group boundary (Fig. 3/4).

Two numeric modes:
  * int mode (int32 activations/weights): the k-deep CSA chain is emulated
    BIT-EXACTLY (xor/majority full-adder per bit position) — validates the
    paper's Fig. 3 hardware datapath, not just the math;
  * float mode: plain summation (carry-save has no float analogue).

The simulator asserts its cycle count against Eq.(3) and its output against
A @ B; it is the oracle for the latency model and the Pallas kernel tests.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core import timing


def csa_3_2(x, y, z):
    """Bit-exact 3:2 carry-save compressor on int32/int64 lanes."""
    s = jnp.bitwise_xor(jnp.bitwise_xor(x, y), z)
    c = jnp.left_shift(
        jnp.bitwise_or(jnp.bitwise_or(jnp.bitwise_and(x, y),
                                      jnp.bitwise_and(x, z)),
                       jnp.bitwise_and(y, z)), 1)
    return s, c


def _group_sum_csa(products, psum_in):
    """Reduce k products + incoming psum through a k-stage CSA chain ending
    in a carry-propagate adder (the collapsed-block datapath of Fig. 4)."""
    s, c = psum_in, jnp.zeros_like(psum_in)
    k = products.shape[0]
    for i in range(k):            # static k — mirrors the hardware chain
        s, c = csa_3_2(products[i], s, c)
    return s + c                  # carry-propagate adder at the block end


def simulate_tile(A, B, k: int, *, use_csa: bool = True):
    """Simulate one tile.  A: (T, R), B: (R, C).  Returns (X, cycles).

    The cycle count follows the dataflow: R preload cycles, then the skewed
    stream; output (t, c) leaves the array at cycle
        R + t + floor(c/k) + ceil(R/k) - 1 + 1
    and the total equals Eq.(3): R + R/k + C/k + T - 2  (k | R, C).
    """
    T, R = A.shape
    R2, C = B.shape
    assert R == R2 and R % k == 0 and C % k == 0
    nrg = R // k
    is_int = jnp.issubdtype(jnp.asarray(A).dtype, jnp.integer)

    # --- functional result via the same group-staged reduction -------------
    X = jnp.zeros((T, C), A.dtype if is_int else jnp.result_type(A, B))
    for rg in range(nrg):
        rows = slice(rg * k, (rg + 1) * k)
        prods = jnp.einsum("tr,rc->rtc", A[:, rows], B[rows, :])
        if is_int and use_csa:
            X = _group_sum_csa(prods, X)
        else:
            X = X + jnp.sum(prods, axis=0)

    # --- cycle accounting (wavefront schedule) ------------------------------
    # preload B: R cycles; first element of A enters at cycle R.
    # a[t] reaches column-group cg at cycle R + t + cg;
    # psum crosses row-group rg one cycle later each: exit after nrg stages.
    last_t, last_cg = T - 1, (C - 1) // k
    cycles = R + last_t + last_cg + nrg
    expected = timing.latency_cycles(R, C, T, k)
    assert cycles == expected, (cycles, expected)
    return X, cycles


def simulate_matmul(A, B, R: int, C: int, k: int, *, use_csa: bool = True):
    """Tiled X = A @ B on an R x C ArrayFlex at collapse k.

    A: (T, N), B: (N, M).  Output accumulators sit below the SA (Fig. 1a).
    Returns (X, total_cycles) and checks Eq.(4).
    """
    T, N = A.shape
    N2, M = B.shape
    assert N == N2
    nt = math.ceil(N / R)
    mt = math.ceil(M / C)
    is_int = jnp.issubdtype(jnp.asarray(A).dtype, jnp.integer)
    out_dtype = A.dtype if is_int else jnp.result_type(A, B)
    X = jnp.zeros((T, M), out_dtype)
    total = 0
    for i in range(nt):
        rows = slice(i * R, min((i + 1) * R, N))
        a_sub = A[:, rows]
        pad_r = R - a_sub.shape[1]
        if pad_r:
            a_sub = jnp.pad(a_sub, ((0, 0), (0, pad_r)))
        for j in range(mt):
            cols = slice(j * C, min((j + 1) * C, M))
            b_sub = B[rows, cols]
            pad = (R - b_sub.shape[0], C - b_sub.shape[1])
            if pad[0] or pad[1]:
                b_sub = jnp.pad(b_sub, ((0, pad[0]), (0, pad[1])))
            x_tile, cyc = simulate_tile(a_sub, b_sub, k, use_csa=use_csa)
            total += cyc
            X = X.at[:, cols].add(x_tile[:, :b_sub.shape[1] - pad[1]]
                                  if pad[1] else x_tile)
    expected = timing.total_cycles(M, N, T, R, C, k)
    assert total == expected, (total, expected)
    return X, total


def occupancy_trace(T: int, R: int, C: int, k: int):
    """Per-cycle count of active column-groups (for utilization plots)."""
    ncg = C // k
    nrg = R // k
    total = timing.latency_cycles(R, C, T, k)
    trace = np.zeros(total, np.int32)
    for t in range(T):
        for cg in range(ncg):
            arrive = R + t + cg
            for stage in range(nrg):
                cyc = arrive + stage
                if cyc < total:
                    trace[cyc] += 1
    return trace
