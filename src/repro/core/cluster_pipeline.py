"""ArrayFlex-at-cluster-scale: pipeline-depth planning with Eq.(6)/(7).

Beyond-paper extension (DESIGN.md §Beyond): the paper's tradeoff — merge
pipeline stages to cut cycle count at the cost of a slower clock — recurs
one level up in pipeline-parallel training across pods:

  collapse k pods into one pipeline stage
    -> fewer stages  P(k) = P/k          (shorter fill/drain "skew"),
    -> slower "clock" per stage: stage time grows with the per-stage layer
       count, exactly T_clock(k) = d_base + k*d_inc with
       d_base = per-microbatch dispatch/collective overhead and
       d_inc  = per-pod layer compute time.

GPipe latency for M microbatches on P/k stages:
  T = (M + P/k - 1) * T_stage(k)   — isomorphic to Eq.(6) with T<-M, R,C<-P.
Setting dT/dk = 0 reproduces Eq.(7) with the same structure; the discrete
argmin below picks the deployed stage count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class PipelineCost:
    n_pods: int                 # P: pods available (max pipeline stages)
    microbatches: int           # M: per-step microbatches
    layer_time_ms: float        # per-pod layer-block compute time
    overhead_ms: float          # per-microbatch stage overhead (dispatch+p2p)


def stage_time_ms(c: PipelineCost, k: int) -> float:
    """T_clock analogue: time of one collapsed stage (k pods' layers)."""
    return c.overhead_ms + k * c.layer_time_ms


def pipeline_latency_ms(c: PipelineCost, k: int) -> float:
    """Eq.(6) analogue: (M + P/k - 1) * T_stage(k)."""
    stages = max(1, c.n_pods // k)
    return (c.microbatches + stages - 1) * stage_time_ms(c, k)


def k_hat(c: PipelineCost) -> float:
    """Eq.(7) analogue (continuous optimum)."""
    if c.microbatches <= 1:
        return float(c.n_pods)
    return math.sqrt(c.n_pods * c.overhead_ms
                     / ((c.microbatches - 1) * c.layer_time_ms))


def best_collapse(c: PipelineCost) -> int:
    ks = [k for k in range(1, c.n_pods + 1) if c.n_pods % k == 0]
    return min(ks, key=lambda k: pipeline_latency_ms(c, k))


def plan(c: PipelineCost) -> dict:
    k = best_collapse(c)
    base = pipeline_latency_ms(c, 1)
    bestt = pipeline_latency_ms(c, k)
    return {
        "k": k, "k_hat": k_hat(c), "stages": c.n_pods // k,
        "latency_ms": bestt, "latency_ms_k1": base,
        "saving": 1.0 - bestt / base,
        "bubble_fraction": (c.n_pods // k - 1)
        / (c.microbatches + c.n_pods // k - 1),
    }
