"""ArrayFlex power / energy / EDP model (paper §IV-B).

Normalized switched-capacitance split of a conventional PE:
  combinational (multiplier+adder) : c_comb
  pipeline registers               : c_reg
  clock tree                       : c_clk
ArrayFlex adds the 3:2 CSA + bypass muxes (c_extra, in series even at k=1 —
the paper's 16% PE area overhead).  In shallow mode a (k-1)/k fraction of the
pipeline registers is bypassed AND clock-gated, removing their register and
clock-tree power.  Dynamic power = f * C_active (leakage is negligible at
28nm relative to the SA's switching power and is omitted, as in the paper's
relative comparisons).

Calibration targets (paper Fig. 9): ArrayFlex consumes slightly MORE power
than conventional in normal mode, 13-15% LESS averaged over full runs on a
128x128 SA, 17-23% less on 256x256, and 1.4-1.8x better EDP.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.timing import TimingParams, DEFAULT_TIMING, \
    t_abs_ps, t_abs_conventional_ps


@dataclass(frozen=True)
class PowerParams:
    c_comb: float = 0.50
    c_reg: float = 0.33
    c_clk: float = 0.17
    c_extra: float = 0.22     # CSA + bypass muxes (ArrayFlex only)
    # fraction of register/clock power that can NOT be gated in shallow mode
    # (weight-stationary regs, output accumulators, control): only the
    # bypassed pipeline registers inside collapsed blocks actually gate.
    reg_active_floor: float = 0.30

    def conventional_cap(self) -> float:
        return self.c_comb + self.c_reg + self.c_clk

    def arrayflex_cap(self, k: int) -> float:
        active = self.reg_active_floor + (1.0 - self.reg_active_floor) / k
        return (self.c_comb + self.c_extra
                + self.c_reg * active + self.c_clk * active)


DEFAULT_POWER = PowerParams()


def power_conventional(tp: TimingParams = DEFAULT_TIMING,
                       pp: PowerParams = DEFAULT_POWER) -> float:
    """Relative dynamic power of the fixed-pipeline SA (arbitrary units)."""
    return tp.clock_ghz(1) * 0.0 + (1000.0 / tp.conventional_period_ps) \
        * pp.conventional_cap()


def power_arrayflex(k: int, tp: TimingParams = DEFAULT_TIMING,
                    pp: PowerParams = DEFAULT_POWER) -> float:
    return tp.clock_ghz(k) * pp.arrayflex_cap(k)


def layer_energy(M, N, T, R, C, k, tp=DEFAULT_TIMING, pp=DEFAULT_POWER):
    """(energy, time_ps) of one layer on ArrayFlex at collapse k."""
    t = t_abs_ps(M, N, T, R, C, k, tp)
    return power_arrayflex(k, tp, pp) * t, t


def layer_energy_conventional(M, N, T, R, C, tp=DEFAULT_TIMING,
                              pp=DEFAULT_POWER):
    t = t_abs_conventional_ps(M, N, T, R, C, tp)
    return power_conventional(tp, pp) * t, t


def network_summary(layers, R, C, tp=DEFAULT_TIMING, pp=DEFAULT_POWER,
                    choose_k=None):
    """Full-run totals for a list of (M, N, T) layers.

    Returns dict with total times, average powers, savings and EDP gain —
    the quantities of paper Figs. 8 & 9.
    """
    from repro.core.timing import best_k
    t_af = e_af = t_cv = e_cv = 0.0
    ks = []
    for (M, N, T) in layers:
        k = choose_k(M, N, T) if choose_k else best_k(M, N, T, R, C, tp)
        ks.append(k)
        e, t = layer_energy(M, N, T, R, C, k, tp, pp)
        e_af += e
        t_af += t
        e, t = layer_energy_conventional(M, N, T, R, C, tp, pp)
        e_cv += e
        t_cv += t
    p_af, p_cv = e_af / t_af, e_cv / t_cv
    return {
        "k_per_layer": ks,
        "time_arrayflex_ps": t_af, "time_conventional_ps": t_cv,
        "latency_saving": 1.0 - t_af / t_cv,
        "avg_power_arrayflex": p_af, "avg_power_conventional": p_cv,
        "power_saving": 1.0 - p_af / p_cv,
        "edp_gain": (p_cv * t_cv * t_cv) / (p_af * t_af * t_af),
    }
