"""Token-choice top-k MoE with grouped, sort-based, gather-only dispatch.

Tokens are split into G independent dispatch groups (GShard-style; G = batch
by default so groups align with the data shards and every index op stays
shard-local).  Within a group:

  1. router -> top-k experts per token,
  2. a stable argsort of the flat (token,k) expert ids yields each
     assignment's rank within its expert,
  3. the per-expert capacity buffer is built with a GATHER from the sorted
     order (never a scatter — SPMD partitioners turn scatters on sharded
     operands into one-hot matmuls, which is catastrophic at 1M tokens),
  4. a batched expert GEMM 'gecd,edf->gecf' runs all experts,
  5. results gather back to token order and combine with router weights.

Memory is O(T*k*d); assignments beyond capacity are dropped (cf=1.25 train).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import substrate
from repro.nn import layers
from repro.parallel import sharding
from repro.parallel.sharding import constrain


def moe_init(key, d_model, d_ff, num_experts, *, num_shared=0,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d_model)
    p = {
        "router": layers.normal_init(ks[0], (d_model, num_experts), 0.02,
                                     jnp.float32),
        "wi_gate": layers.normal_init(ks[1], (num_experts, d_model, d_ff),
                                      scale, dtype),
        "wi_up": layers.normal_init(ks[2], (num_experts, d_model, d_ff),
                                    scale, dtype),
        "wo": layers.normal_init(ks[3], (num_experts, d_ff, d_model),
                                 1.0 / jnp.sqrt(d_ff), dtype),
    }
    if num_shared:
        p["shared"] = layers.swiglu_init(ks[4], d_model, d_ff * num_shared,
                                         dtype)
    return p


def moe_apply(p, x, *, top_k, capacity_factor=1.25, groups=0,
              compute_dtype=jnp.bfloat16, aux_loss_weight=0.01,
              backend="xla", interpret=None):
    """x: (B, S, d) -> (y, aux_loss).  groups=0 -> one group per sequence."""
    B, S, d = x.shape
    T = B * S
    G = groups or B
    Tg = T // G
    E = p["router"].shape[1]
    TK = Tg * top_k
    xf = x.reshape(G, Tg, d)

    logits = substrate.gemm(xf.astype(jnp.float32), p["router"],
                            site="moe.router", backend=backend,
                            interpret=interpret,
                            shard=sharding.gemm_shard_ctx(
                                "moe.router", G * Tg, d, E))
    probs = jax.nn.softmax(logits, axis=-1)                  # (G,Tg,E)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)          # (G,Tg,k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    flat_e = top_idx.reshape(G, TK)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)    # (G,TK,E)
    counts = jnp.sum(onehot, axis=1).astype(jnp.int32)       # (G,E)

    # ---- load-balance auxiliary loss (Switch-style), over all tokens ------
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.sum(counts, axis=0).astype(jnp.float32) / (T * top_k)
    aux = aux_loss_weight * E * jnp.sum(me * ce)

    # ---- rank-in-expert via stable sort (all shard-local per group) -------
    order = jnp.argsort(flat_e, axis=-1, stable=True)        # (G,TK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jnp.cumsum(counts, axis=-1) - counts            # (G,E) exclusive
    rank_sorted = (jnp.arange(TK, dtype=jnp.int32)[None, :]
                   - jnp.take_along_axis(starts, sorted_e, axis=-1))
    inv_order = jnp.argsort(order, axis=-1, stable=True)
    rank = jnp.take_along_axis(rank_sorted, inv_order, axis=-1)  # (G,TK)

    cap = int(max(1, round(Tg * top_k * capacity_factor / E)))
    keep = rank < cap

    # ---- build capacity buffer by GATHER from the sorted stream -----------
    slot_pos = starts[:, :, None] + jnp.arange(cap)[None, None, :]  # (G,E,cap)
    slot_valid = jnp.arange(cap)[None, None, :] < counts[:, :, None]
    slot_src = jnp.take_along_axis(
        order, jnp.minimum(slot_pos, TK - 1).reshape(G, E * cap),
        axis=-1).reshape(G, E, cap)
    slot_tok = slot_src // top_k                             # (G,E,cap)
    he = jnp.take_along_axis(
        xf.astype(compute_dtype),
        slot_tok.reshape(G, E * cap)[:, :, None], axis=1)
    he = he.reshape(G, E, cap, d) * slot_valid[..., None].astype(compute_dtype)
    he = constrain(he, "moe_buf4")

    # ---- expert GEMMs (substrate-dispatched; xla keeps the fused einsum,
    # arrayflex runs each site's E GEMMs in ONE expert-batched launch;
    # under the mesh the expert axis shards over 'model' when E % tp == 0
    # — the _MOE_EP condition — else dispatch stays replicated) ----------
    esh = sharding.expert_shard_ctx(E)
    wg = p["wi_gate"].astype(compute_dtype)
    wu = p["wi_up"].astype(compute_dtype)
    wo = p["wo"].astype(compute_dtype)
    hg = constrain(substrate.expert_gemm(he, wg, site="moe.wi_gate",
                                         backend=backend, shard=esh,
                                         interpret=interpret), "moe_h4")
    hu = constrain(substrate.expert_gemm(he, wu, site="moe.wi_up",
                                         backend=backend, shard=esh,
                                         interpret=interpret), "moe_h4")
    h = jax.nn.silu(hg) * hu
    hout = constrain(substrate.expert_gemm(h, wo, site="moe.wo",
                                           backend=backend, shard=esh,
                                           interpret=interpret), "moe_buf4")

    # ---- combine back (gather token slots, weight, sum over k) ------------
    dst = jnp.where(keep, flat_e * cap + rank, 0)            # (G,TK)
    y_rep = jnp.take_along_axis(hout.reshape(G, E * cap, d),
                                dst[:, :, None], axis=1)     # (G,TK,d)
    y_rep = y_rep * keep[..., None].astype(compute_dtype)
    w = top_vals.reshape(G, TK, 1).astype(compute_dtype)
    y = jnp.sum((y_rep * w).reshape(G, Tg, top_k, d), axis=2)

    y = y.reshape(B, S, d)
    if "shared" in p:
        y = y + layers.swiglu(p["shared"], x.reshape(B, S, d), compute_dtype,
                              backend=backend, interpret=interpret)
    return y.astype(x.dtype), aux


def moe_apply_reference(p, x, *, top_k, compute_dtype=jnp.float32):
    """O(T*E*d*ff) oracle: run every expert on every token, combine top-k.

    Used by tests to validate the dispatch path (with ample capacity the two
    must agree to numerical tolerance).
    """
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    E = p["router"].shape[1]
    g = jnp.einsum("td,edf->tef", xf, p["wi_gate"].astype(compute_dtype))
    u = jnp.einsum("td,edf->tef", xf, p["wi_up"].astype(compute_dtype))
    h = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u,
                   p["wo"].astype(compute_dtype))
    mask = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)     # (T,k,E)
    w = jnp.einsum("tk,tke->te", top_vals, mask)
    y = jnp.einsum("te,ted->td", w, h)
    if "shared" in p:
        y = y + layers.swiglu(p["shared"], xf, compute_dtype)
    return y.reshape(B, S, d).astype(x.dtype)
