"""Mamba-2 (SSD, state-space duality) block — chunked matmul form + decode.

The chunked SSD algorithm (arXiv:2405.21060 §6) decomposes the selective-SSM
recurrence into intra-chunk quadratic (matmul-friendly, MXU-native) terms and
a small sequential inter-chunk state scan — the TPU-native adaptation of the
CUDA selective-scan kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import layers
from repro.parallel.sharding import constrain


def _inv_softplus(x):
    return np.log(np.expm1(x))


def mamba_init(key, d_model, ssm, dtype=jnp.float32):
    """ssm: configs.base.SSMConfig."""
    d_inner = ssm.expand * d_model
    H = d_inner // ssm.head_dim
    G, N, K = ssm.n_groups, ssm.d_state, ssm.d_conv
    conv_ch = d_inner + 2 * G * N
    ks = jax.random.split(key, 8)
    dt = np.exp(np.random.RandomState(0).uniform(
        math.log(ssm.dt_min), math.log(ssm.dt_max), (H,)))
    p = {
        "z_proj": layers.linear_init(ks[0], d_model, d_inner, dtype=dtype),
        "xbc_proj": layers.linear_init(ks[1], d_model, conv_ch, dtype=dtype),
        "dt_proj": layers.linear_init(ks[2], d_model, H, dtype=dtype),
        "dt_bias": jnp.asarray(_inv_softplus(dt), jnp.float32),
        "a_log": jnp.log(jnp.asarray(
            np.random.RandomState(1).uniform(1.0, 16.0, (H,)), jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "conv_w": layers.normal_init(ks[3], (K, conv_ch), 0.1, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm": layers.rmsnorm_init(d_inner, dtype),
        "out_proj": layers.linear_init(ks[4], d_inner, d_model, dtype=dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B,S,ch), w: (K,ch)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype), (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def _segsum(dA):
    """dA: (..., c) -> (..., c, c) with out[i,j] = sum_{j<m<=i} dA[m]."""
    cs = jnp.cumsum(dA, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    c = dA.shape[-1]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, chunk):
    """Chunked SSD.  x:(B,S,H,P) dt:(B,S,H) A:(H,) B_/C_:(B,S,G,N).

    Returns y:(B,S,H,P), final_state:(B,G,H/G,P,N).  fp32 internal.

    All per-chunk work happens INSIDE the inter-chunk state scan with a
    rematted body, and the intra-chunk contraction is staged so no
    (c, c, P)-shaped tensor ever materializes: peak live memory is
    O(B*H*c^2) for one chunk instead of O(B*H*S*c*P) for all of them.
    """
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    hg = H // G
    nc = S // chunk
    assert S % chunk == 0
    f32 = jnp.float32
    A2 = A.reshape(G, hg)

    def cmajor(a, extra):
        return jnp.moveaxis(
            a.reshape((Bsz, nc, chunk) + extra), 1, 0)

    xs = (cmajor(x.astype(f32), (G, hg, P)),
          cmajor(dt.astype(f32), (G, hg)),
          cmajor(B_.astype(f32), (G, N)),
          cmajor(C_.astype(f32), (G, N)))

    def body(state, inp):
        xc, dtc, Bc, Cc = inp            # (B,c,G,hg,P) (B,c,G,hg) (B,c,G,N)
        dA = dtc * A2                                  # (B,c,G,hg)
        cs = jnp.cumsum(dA, axis=1)
        cs_last = cs[:, -1]                            # (B,G,hg)
        # intra-chunk: w[b,g,h,c,d] = scores * L * dt  (no P dim yet)
        scores = jnp.einsum("bcgs,bdgs->bgcd", Cc, Bc)     # (B,G,c,c)
        L = jnp.exp(_segsum(jnp.moveaxis(dA, 1, -1)))      # (B,G,hg,c,c)
        w = scores[:, :, None] * L \
            * jnp.moveaxis(dtc, 1, -1)[..., None, :]       # (B,G,hg,c,c)
        y_diag = jnp.einsum("bghcd,bdghp->bcghp", w, xc)
        # chunk state contribution
        decay = jnp.exp(cs_last[:, None] - cs)             # (B,c,G,hg)
        st_chunk = jnp.einsum("bcgs,bcgh,bcghp->bghps",
                              Bc, decay * dtc, xc)         # (B,G,hg,P,N)
        # inter-chunk: read incoming state, then update it
        y_off = jnp.einsum("bcgs,bghps,bcgh->bcghp",
                           Cc, state, jnp.exp(cs))
        new_state = state * jnp.exp(cs_last)[..., None, None] + st_chunk
        return new_state, y_diag + y_off

    state0 = jnp.zeros((Bsz, G, hg, P, N), f32)
    final_state, ys = jax.lax.scan(jax.checkpoint(body), state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final_state


def mamba_forward(p, x, ssm, compute_dtype=jnp.bfloat16, backend="xla",
                  interpret=None):
    """Full-sequence forward.  x: (B,S,d) -> (y, final_state, conv_state)."""
    B, S, d = x.shape
    d_inner = ssm.expand * d
    H = d_inner // ssm.head_dim
    G, N = ssm.n_groups, ssm.d_state
    z = layers.linear(p["z_proj"], x, compute_dtype, site="mamba.z",
                      backend=backend, interpret=interpret)
    xbc_raw = layers.linear(p["xbc_proj"], x, compute_dtype,
                            site="mamba.xbc", backend=backend, interpret=interpret)
    K = ssm.d_conv
    if S >= K - 1:
        conv_state = xbc_raw[:, S - (K - 1):]
    else:
        conv_state = jnp.pad(xbc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
    xbc = jax.nn.silu(_causal_conv(constrain(xbc_raw, "mamba_xbc"),
                                   p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_inner]
    Bmat = xbc[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
    Cmat = xbc[..., d_inner + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(
        layers.linear(p["dt_proj"], x, compute_dtype, site="mamba.dt",
                      backend=backend, interpret=interpret).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    chunk = min(ssm.chunk_size, S)
    y, final_state = ssd_chunked(
        constrain(xs.reshape(B, S, H, ssm.head_dim), "ssm_x"),
        dt, A, Bmat, Cmat, chunk)
    y = y + (p["D"].reshape(H, 1) * xs.reshape(B, S, H, ssm.head_dim)
             .astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return (layers.linear(p["out_proj"], y, compute_dtype, site="mamba.out",
                          backend=backend, interpret=interpret), final_state, conv_state)


def mamba_decode_step(p, x, state, conv_state, ssm,
                      compute_dtype=jnp.bfloat16, backend="xla",
                      interpret=None):
    """One-token step.  x: (B,d); state: (B,G,hg,P,N); conv_state: (B,K-1,ch).

    Returns (y, new_state, new_conv_state).
    """
    B, d = x.shape
    d_inner = ssm.expand * d
    H = d_inner // ssm.head_dim
    G, N, P = ssm.n_groups, ssm.d_state, ssm.head_dim
    hg = H // G
    z = layers.linear(p["z_proj"], x, compute_dtype, site="mamba.z",
                      backend=backend, interpret=interpret)
    xbc = layers.linear(p["xbc_proj"], x, compute_dtype, site="mamba.xbc",
                        backend=backend, interpret=interpret)                      # (B,ch)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,K,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window,
                          p["conv_w"].astype(window.dtype))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(conv_out.dtype))
    new_conv_state = window[:, 1:]
    xs = xbc[..., :d_inner].reshape(B, G, hg, P).astype(jnp.float32)
    Bmat = xbc[..., d_inner:d_inner + G * N].reshape(B, G, N).astype(jnp.float32)
    Cmat = xbc[..., d_inner + G * N:].reshape(B, G, N).astype(jnp.float32)
    dt = jax.nn.softplus(
        layers.linear(p["dt_proj"], x, compute_dtype, site="mamba.dt",
                      backend=backend, interpret=interpret).astype(jnp.float32)
        + p["dt_bias"]).reshape(B, G, hg)
    A = -jnp.exp(p["a_log"]).reshape(G, hg)
    dec = jnp.exp(dt * A)                                     # (B,G,hg)
    upd = jnp.einsum("bgn,bgh,bghp->bghpn", Bmat, dt, xs)
    new_state = state * dec[..., None, None] + upd
    y = jnp.einsum("bgn,bghpn->bghp", Cmat, new_state)
    y = y + p["D"].reshape(G, hg, 1) * xs
    y = y.reshape(B, d_inner).astype(compute_dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return layers.linear(p["out_proj"], y, compute_dtype, site="mamba.out",
                         backend=backend, interpret=interpret), new_state, new_conv_state
