"""Pure-JAX neural-net primitives (no flax): params are plain pytrees.

Every ``*_init`` returns a dict of arrays; the matching apply function is a
pure function of (params, inputs).  Parameter leaves carry logical sharding
axes via the parallel.sharding rules, keyed by their path names.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import substrate
from repro.parallel import sharding


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------- linear
def linear_init(key, in_dim, out_dim, *, bias=False, dtype=jnp.float32,
                scale=None):
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    p = {"w": normal_init(key, (in_dim, out_dim), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p, x, compute_dtype=None, *, site="", backend="xla",
           interpret=None, shard=None, residual=None, norm_scale=None):
    """Dense projection through the GEMM substrate (kernels.substrate).

    ``backend`` selects the execution backend; ``site`` labels the GEMM
    with its ``planner.model_gemms`` name so the plan cache lines up with
    the analytic model.  The default backend reproduces ``x @ w`` exactly.
    A bias rides the substrate's fused epilogue (one kernel launch on the
    arrayflex backend, no HBM round-trip between GEMM and add), and
    ``residual`` (an output-shaped array) fuses the sublayer's
    ``residual + f(x)`` join at the same boundary.  ``norm_scale`` (a
    (K,) vector — the preceding rmsnorm's ``scale`` param, with
    :func:`rmsnorm_normalize` handling the normalize) fuses the norm's
    elementwise scale into the kernel's step prologue.

    Under an active GEMM mesh (``sharding.use_gemm_mesh`` — the lm entry
    points activate it from ``ModelConfig.mesh_shape``) the dispatch
    derives the site's ShardCtx, so the substrate plans on post-partition
    shapes and each device runs its per-shard GEMM under
    ``jax.shard_map``.  Pass ``shard`` to override the derivation.
    """
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    if shard is None:
        shard = sharding.gemm_shard_ctx(site, math.prod(x.shape[:-1]),
                                        w.shape[0], w.shape[-1])
    return substrate.gemm(x, w, site=site, backend=backend,
                          bias=p.get("b"), residual=residual,
                          norm_scale=norm_scale,
                          interpret=interpret, shard=shard)


# ---------------------------------------------------------------- norms
def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def rmsnorm_normalize(x, eps=1e-5):
    """The rmsnorm *normalize* alone — no elementwise scale.  Pairs with
    the substrate's fused ``norm_scale`` prologue: a sublayer computes
    ``rmsnorm_normalize(x)`` and hands the norm's ``scale`` param to its
    projection GEMM, which applies the identical fp32 multiply-and-cast
    (``arrayflex_gemm.prologue_phase``) in-kernel — the scale pass stops
    being a separate elementwise op on the decode hot path, and every
    backend computes the same expression bit for bit."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- embed
def embedding_init(key, vocab, dim, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, dim), 0.02, dtype)}


def embed(p, ids, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[ids]


def unembed(p, x, *, backend="xla", interpret=None):
    """Logits against the embedding table (tied) — fp32 accumulation.

    A pre-quantized tree (lm.prequantize_params) carries ``table_q``, the
    already-transposed QuantizedTensor of the table; the lookup path keeps
    the fp ``table``."""
    w = p.get("table_q")
    if w is None:
        w = p["table"].astype(x.dtype).T
    shard = sharding.gemm_shard_ctx("unembed", math.prod(x.shape[:-1]),
                                    w.shape[0], w.shape[-1])
    return substrate.gemm(x, w, site="unembed",
                          backend=backend, out_dtype=jnp.float32,
                          interpret=interpret, shard=shard)


# ---------------------------------------------------------------- rope
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta=1e6):
    """x: (..., S, H, D) with positions (..., S) broadcastable."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))            # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp
def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": linear_init(k1, d_model, d_ff, dtype=dtype),
        "wi_up": linear_init(k2, d_model, d_ff, dtype=dtype),
        "wo": linear_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(p, x, compute_dtype=jnp.bfloat16, *, backend="xla",
           interpret=None, residual=None, norm_scale=None):
    """Gated MLP via the substrate's dual-GEMM swiglu epilogue:
    ``silu(x@Wg) * (x@Wu)`` is ONE dispatch (one fused kernel launch on
    the arrayflex backend — both contractions stream the collapsed
    schedule, the gate resolves at the carry-propagate store).

    ``residual`` fuses the sublayer's ``residual + mlp(x)`` join into the
    ``wo`` projection's store — the model's residual stream never makes a
    separate HBM round-trip for the add."""
    wg, wu = p["wi_gate"]["w"], p["wi_up"]["w"]
    if compute_dtype is not None:
        wg = wg.astype(compute_dtype)
        wu = wu.astype(compute_dtype)
        x = x.astype(compute_dtype)
    shard = sharding.gemm_shard_ctx("mlp.wi_gate+mlp.wi_up",
                                    math.prod(x.shape[:-1]),
                                    wg.shape[0], wg.shape[-1])
    h = substrate.gemm(x, wg, w2=wu, epilogue="swiglu",
                       bias=p["wi_gate"].get("b"),
                       bias2=p["wi_up"].get("b"),
                       norm_scale=norm_scale,
                       site="mlp.wi_gate+mlp.wi_up", backend=backend,
                       interpret=interpret, shard=shard)
    return linear(p["wo"], h, compute_dtype, site="mlp.wo",
                  backend=backend, interpret=interpret, residual=residual)


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"wi": linear_init(k1, d_model, d_ff, bias=True, dtype=dtype),
            "wo": linear_init(k2, d_ff, d_model, bias=True, dtype=dtype)}


def gelu_mlp(p, x, compute_dtype=jnp.bfloat16, *, backend="xla",
             interpret=None, residual=None):
    """Biased MLP with the gelu fused into the wi GEMM's epilogue (and
    the sublayer residual join fused into wo's, when passed)."""
    wi = p["wi"]["w"]
    if compute_dtype is not None:
        wi = wi.astype(compute_dtype)
        x = x.astype(compute_dtype)
    h = substrate.gemm(x, wi, bias=p["wi"].get("b"), epilogue="gelu",
                       site="mlp.wi", backend=backend, interpret=interpret)
    return linear(p["wo"], h, compute_dtype, site="mlp.wo",
                  backend=backend, interpret=interpret, residual=residual)


# ---------------------------------------------------------------- loss
def softmax_xent(logits, labels, mask=None):
    """logits (..., V) fp32-accumulated; labels int (...,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
