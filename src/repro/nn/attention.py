"""GQA attention: dense, wavefront-chunked (flash-style), and decode paths.

The chunked path processes the lower-triangular (q_chunk x kv_chunk) tile
grid as a static *wavefront schedule* — exactly the tile walk a
weight-stationary systolic array performs (see core.planner): the chunk size
plays the role of the ArrayFlex pipeline-collapse factor k, trading the
number of sequential steps against per-step work.  core.planner.attention_plan
picks the chunk size with the paper's Eq.(6)-style analytical model.

The dense and decode paths' QK and PV contractions dispatch through the
GEMM substrate under the ``attn.qk`` / ``attn.pv`` site labels
(:func:`qk_scores` / :func:`pv_mix`): the planner's Eq.(6) table and the
executed attention kernels are joined on the same names as every
projection GEMM, and the arrayflex backend runs all (batch x kv-head)
products of a step in ONE expert-batched kernel launch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import substrate
from repro.parallel import sharding
from repro.parallel.sharding import constrain

NEG_INF = -1e30


def qk_scores(qg, k, *, backend="xla", interpret=None):
    """Attention scores via the substrate (site ``attn.qk``).

    qg: (B, S, KV, g, D) grouped queries; k: (B, T, KV, D).  Returns fp32
    scores laid out (B, KV, g, S, T) — the ``bskgd,btkd->bkgst`` einsum,
    executed as a (B*KV)-batched GEMM with the g*S query rows streamed
    against each kv-head's K^T.  Unscaled: callers apply 1/sqrt(D).
    """
    B, S, KV, g, D = qg.shape
    T = k.shape[1]
    qb = qg.transpose(0, 2, 3, 1, 4).reshape(B * KV, g * S, D)
    kb = k.transpose(0, 2, 3, 1).reshape(B * KV, D, T)
    s = substrate.batched_gemm(qb, kb, site="attn.qk", backend=backend,
                               out_dtype=jnp.float32, interpret=interpret,
                               shard=sharding.batched_shard_ctx(B * KV))
    return s.reshape(B, KV, g, S, T)


def pv_mix(w, v, *, backend="xla", interpret=None):
    """Probability-weighted value mix via the substrate (site ``attn.pv``).

    w: (B, KV, g, S, T) attention weights (cast to v.dtype by callers);
    v: (B, T, KV, D).  Returns (B, S, KV, g, D) — the
    ``bkgst,btkd->bskgd`` einsum as a (B*KV)-batched GEMM.
    """
    B, KV, g, S, T = w.shape
    D = v.shape[-1]
    pb = w.reshape(B * KV, g * S, T)
    vb = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    o = substrate.batched_gemm(pb, vb, site="attn.pv", backend=backend,
                               interpret=interpret,
                               shard=sharding.batched_shard_ctx(B * KV))
    return o.reshape(B, KV, g, S, D).transpose(0, 3, 1, 2, 4)


def _causal_pairs(n_q: int, n_k: int, q_chunk: int, kv_chunk: int,
                  window: int, q_offset: int):
    """Static (qi, kj) tile list for the causal (optionally windowed) band."""
    pairs = []
    for i in range(n_q):
        row_lo = q_offset + i * q_chunk              # first global row
        row_hi = row_lo + q_chunk - 1                # last global row
        for j in range(n_k):
            col_lo = j * kv_chunk
            col_hi = col_lo + kv_chunk - 1
            if col_lo > row_hi:                      # strictly above diagonal
                continue
            if window and col_hi < row_lo - window:  # outside SWA band
                continue
            pairs.append((i, j))
    return np.asarray(pairs, dtype=np.int32)


def _block_mask(row0, col0, q_chunk, kv_chunk, window, causal):
    r = row0 + jnp.arange(q_chunk)[:, None]
    c = col0 + jnp.arange(kv_chunk)[None, :]
    ok = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
    if causal:
        ok = ok & (c <= r)
    if window:
        ok = ok & (c > r - window)
    return ok


def dense_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_len=None, backend="xla", interpret=None):
    """q: (B,S,H,D), k/v: (B,T,KV,D).  fp32 softmax.  Returns (B,S,H,D).

    QK and PV dispatch through the substrate (``attn.qk``/``attn.pv``)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = constrain(q.reshape(B, S, KV, g, D), "attn_q_seq")
    k = constrain(k, "attn_qkv")
    v = constrain(v, "attn_qkv")
    scale = 1.0 / math.sqrt(D)
    scores = qk_scores(qg, k, backend=backend, interpret=interpret) * scale
    scores = constrain(scores, "attn_scores_seq")
    r = q_offset + jnp.arange(S)[:, None]
    c = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), jnp.bool_)
    if causal:
        ok = ok & (c <= r)
    if window:
        ok = ok & (c > r - window)
    if kv_len is not None:
        ok = ok & (c < kv_len)
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = pv_mix(w, v, backend=backend, interpret=interpret)
    return out.reshape(B, S, H, D)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      q_chunk=1024, kv_chunk=1024):
    """Sequence-sharded flash-style attention: q rows stay resident (sharded
    over the 'model' axis under SPMD), KV is scanned in chunks with an online
    softmax.  Memory is O(B*S_local*H*D + B*H*S_local*kv_chunk).

    The KV chunk size is the ArrayFlex pipeline-collapse analogue: fewer,
    larger sequential steps vs more, smaller ones (core.planner picks it).

    T need not divide ``kv_chunk``: K/V are zero-padded to the chunk grid
    and padded columns are masked out of the online softmax, so a prime KV
    length (e.g. T=4097) runs in ``ceil(T/kc)`` steps instead of collapsing
    to chunk=1 via a largest-divisor search.
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    kv_chunk = min(kv_chunk, T)
    n_k = -(-T // kv_chunk)
    if n_k * kv_chunk != T:
        pad = n_k * kv_chunk - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(D)
    qg = constrain(q.reshape(B, S, KV, g, D), "attn_q_seq")
    k = constrain(k, "attn_qkv")
    v = constrain(v, "attn_qkv")
    rows = q_offset + jnp.arange(S)                       # global row ids

    o = constrain(jnp.zeros((B, S, KV, g, D), jnp.float32), "attn_q_seq")
    m = constrain(jnp.full((B, S, KV, g), NEG_INF, jnp.float32),
                  "attn_stat_seq")
    l = constrain(jnp.zeros((B, S, KV, g), jnp.float32), "attn_stat_seq")

    def step(carry, j):
        o, m, l = carry
        col0 = j * kv_chunk
        ks = jax.lax.dynamic_slice_in_dim(k, col0, kv_chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, col0, kv_chunk, axis=1)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, ks,
                       preferred_element_type=jnp.float32) * scale
        cols = col0 + jnp.arange(kv_chunk)
        ok = jnp.ones((S, kv_chunk), jnp.bool_)
        if causal:
            ok = ok & (cols[None, :] <= rows[:, None])
        if window:
            ok = ok & (cols[None, :] > rows[:, None] - window)
        if n_k * kv_chunk != T:                  # zero-padded ragged tail
            ok = ok & (cols[None, :] < T)
        okb = ok[None, None, None]                         # (1,1,1,S,kc)
        s = jnp.where(okb, s, NEG_INF)
        blk_max = jnp.moveaxis(jnp.max(s, axis=-1), -1, 1)  # (B,S,KV,g)
        m_new = jnp.maximum(m, blk_max)
        p = jnp.exp(s - jnp.moveaxis(m_new, 1, -1)[..., None])
        p = jnp.where(okb, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.moveaxis(jnp.sum(p, -1), -1, 1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(vs.dtype), vs,
                        preferred_element_type=jnp.float32)
        o = o * corr[..., None] + pv
        return (o, m_new, l), None

    # remat each KV step: backward recomputes the (bq x kc) score block
    # instead of saving every per-step intermediate (O(n_k) x 4GiB at 90B
    # scale); only the (o, m, l) carries persist.
    (o, m, l), _ = jax.lax.scan(jax.checkpoint(step), (o, m, l),
                                jnp.arange(n_k))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, H, D).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              q_chunk=1024, kv_chunk=1024, dense_below=2048,
              backend="xla", interpret=None):
    """``backend``/``interpret`` apply to the dense path's substrate QK/PV
    dispatch only: above ``dense_below`` the chunked flash-style scan runs
    its own schedule, whose ArrayFlex-collapse analogue is the KV chunk
    picked by ``planner.attention_plan`` (see docs/substrate.md) — it has
    no substrate GEMMs or Pallas launches to configure."""
    if q.shape[1] <= dense_below:
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, backend=backend,
                               interpret=interpret)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, q_chunk=q_chunk,
                             kv_chunk=kv_chunk)


def gather_pages(pool, block_tables):
    """Assemble per-sequence logical K/V views from a physical page pool.

    pool: (n_pages, page, KV, D); block_tables: (B, n_pg) int32 physical
    page ids.  Returns (B, n_pg*page, KV, D) — the contiguous cache view
    the existing ``attn.qk``/``attn.pv`` substrate dispatches consume, so
    a paged cache feeds the *same* GEMM plans as the dense one (the view
    length equals the dense cache length by the engine's page|max_seq
    contract, which is what keeps paged decoding bit-identical)."""
    B, n_pg = block_tables.shape
    page = pool.shape[1]
    return pool[block_tables].reshape((B, n_pg * page) + pool.shape[2:])


def scatter_pages(pool, block_tables, view):
    """Inverse of :func:`gather_pages`: write logical views back into the
    pool.  Rows may alias pages (shared prefixes, the scratch page); every
    aliased write carries the unchanged gathered bytes, so the scatter's
    pick-one-duplicate resolution is value-deterministic."""
    B, n_pg = block_tables.shape
    page = pool.shape[1]
    blocks = view.reshape((B, n_pg, page) + pool.shape[2:])
    return pool.at[block_tables].set(blocks)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, backend="xla",
                     interpret=None):
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B,1,H,D); caches (B,T,KV,D); pos: scalar int32 OR per-sequence
    (B,) int32 (ragged continuous batching).  For ring buffers (window>0)
    the cache length T == window and all slots are valid once pos >= window.
    QK and PV dispatch through the substrate (``attn.qk``/``attn.pv``).
    """
    B, _, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    qg = q.reshape(B, 1, KV, g, D)
    scale = 1.0 / math.sqrt(D)
    s = qk_scores(qg, k_cache, backend=backend, interpret=interpret) * scale
    idx = jnp.arange(T)
    pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if window:
        valid = idx[None, :] < jnp.minimum(pos_v + 1, T)[:, None]
        valid = valid | (pos_v + 1 >= T)[:, None]          # ring full
    else:
        valid = idx[None, :] <= pos_v[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v_cache.dtype)
    out = pv_mix(w, v_cache, backend=backend, interpret=interpret)
    return out.reshape(B, 1, H, D).astype(q.dtype)
