"""AST lint over ``src/repro``: the substrate contract, statically.

Three rules:

* **AFL01** — no raw GEMM syntax (``@``, ``jnp.dot``/``einsum``/
  ``matmul``/``tensordot``, ``lax.dot_general``/``conv_general_dilated``)
  in the model zones (``nn/``, ``models/``, ``serving/``) outside the
  :data:`repro.analysis.contract.ALLOWLIST` — the same allowlist the
  jaxpr auditor applies to traceback frames, so the static and traced
  views of the rule cannot diverge.
* **AFL02** — every ``substrate.gemm``/``batched_gemm``/``expert_gemm``
  call in the model zones carries a ``site=`` label; literal labels must
  be known to ``planner.site_registry()`` (non-literal labels — e.g. a
  forwarded parameter — are runtime-checked by strict-audit mode
  instead).
* **AFL03** — no mutation of owned mutable state outside its owner
  module(s).  Four ownership groups: the substrate's plan/dispatch state
  (``SITE_PLANS``, ``DISPATCH_COUNTS``, plan/quant caches) belongs to
  ``kernels/substrate.py`` — external code resets through
  ``clear_plan_cache()``/``clear_quant_cache()``, never by poking the
  dicts; the paged-KV page-table/pool state (``free_pages``,
  ``refcounts``, ``block_table``, radix node ``children``) belongs to
  ``serving/engine.py`` + ``serving/paged.py`` — everything else reads
  block tables but may not rewire them, so the refcount/COW invariants
  the prefix cache depends on cannot be broken from a distance; the
  chaos-injection draw state (``chaos_draws``, ``chaos_log``) belongs to
  ``runtime/chaos.py`` — replayability is a pure function of (seed,
  point, draw index) only while the counters advance through
  ``ChaosEngine.fire``; and the engine snapshot ring (``_snapshots``)
  belongs to ``serving/engine.py`` — crash-recovery bit-identity assumes
  a snapshot is immutable once taken.
"""
from __future__ import annotations

import ast
import os
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis import contract
from repro.analysis.findings import Finding

# zones where AFL01/AFL02 apply (model code; kernels/ is the substrate)
MODEL_ZONES = ("nn/", "models/", "serving/")

RAW_GEMM_ATTRS = frozenset({
    "dot", "matmul", "einsum", "tensordot", "vdot", "inner", "outer",
    "dot_general", "conv_general_dilated", "conv",
})

DISPATCH_FNS = frozenset({"gemm", "batched_gemm", "expert_gemm"})

# substrate-owned mutable state; only kernels/substrate.py may mutate it
TRACKED_STATE = frozenset({
    "SITE_PLANS", "DISPATCH_COUNTS", "PLAN_CACHE_STATS",
    "QUANT_CACHE_STATS", "_QUANT_CACHE", "_plan_gemm_cached",
    "plan_collapse", "attention_plan", "_BACKENDS", "_BACKEND_INFO",
})
MUTATORS = frozenset({"clear", "cache_clear", "pop", "popitem", "update",
                      "setdefault", "append", "extend", "insert", "remove",
                      "sort", "reverse"})
STATE_OWNER = os.path.join("kernels", "substrate.py").replace(os.sep, "/")

# paged-KV page-table/pool state; only the serving engine and the paged
# data structures themselves may rewire it (PagePool refcounts, per-seq
# block tables, radix-node children) — a stray append/subscript write
# elsewhere breaks the refcount/COW invariants silently
PAGED_STATE = frozenset({
    "free_pages", "refcounts", "block_table", "children",
})
PAGED_OWNERS = frozenset({
    os.path.join("serving", "engine.py").replace(os.sep, "/"),
    os.path.join("serving", "paged.py").replace(os.sep, "/"),
})

# chaos-injection draw state; only runtime/chaos.py may mutate it.  The
# replay guarantee (decision = f(seed, point, draw index)) dies the moment
# any other module advances a counter or rewrites the fired log
CHAOS_STATE = frozenset({"chaos_draws", "chaos_log"})
CHAOS_OWNER = os.path.join("runtime", "chaos.py").replace(os.sep, "/")

# engine crash-recovery snapshot ring; only serving/engine.py may mutate
# it — restore-time bit-identity assumes snapshots are immutable once taken
SNAPSHOT_STATE = frozenset({"_snapshots"})
SNAPSHOT_OWNER = os.path.join("serving", "engine.py").replace(os.sep, "/")

# ownership groups: (tracked names, owner predicate key, remedy for the msg)
STATE_GROUPS = (
    (TRACKED_STATE, "substrate",
     "substrate plan/dispatch state outside kernels/substrate.py — "
     "use substrate.clear_plan_cache()"),
    (PAGED_STATE, "paged",
     "paged-KV page-table/pool state outside serving/engine.py + "
     "serving/paged.py — go through PagePool/RadixCache methods"),
    (CHAOS_STATE, "chaos",
     "chaos draw-state outside runtime/chaos.py — fire through "
     "ChaosEngine.fire()/load_state(), never by poking counters"),
    (SNAPSHOT_STATE, "snapshot",
     "engine snapshot state outside serving/engine.py — snapshots are "
     "taken/restored only by the engine itself"),
)


def _name_chain(node) -> List[str]:
    """['substrate', 'DISPATCH_COUNTS', 'clear'] for the attribute chain."""
    chain: List[str] = []
    while isinstance(node, ast.Attribute):
        chain.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        chain.append(node.id)
    return list(reversed(chain))


def _subscript_base(node) -> List[str]:
    return _name_chain(node.value) if isinstance(node, ast.Subscript) else []


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str):
        self.rel = rel
        self.in_model_zone = rel.startswith(MODEL_ZONES)
        self.owns_state = rel == STATE_OWNER
        self.owned = {"substrate": self.owns_state,
                      "paged": rel in PAGED_OWNERS,
                      "chaos": rel == CHAOS_OWNER,
                      "snapshot": rel == SNAPSHOT_OWNER}
        self.def_stack: List[str] = []
        self.findings: List[Finding] = []

    def _where(self, node) -> str:
        return f"src/repro/{self.rel}:{node.lineno}"

    def _allowlisted(self) -> bool:
        return any(contract.allowlisted(self.rel, fn)
                   for fn in self.def_stack)

    def _emit(self, code: str, node, msg: str) -> None:
        self.findings.append(
            Finding(code, self._where(node), msg, pass_name="lint"))

    # --- scope tracking ---------------------------------------------------
    def visit_FunctionDef(self, node):
        self.def_stack.append(node.name)
        self.generic_visit(node)
        self.def_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # --- AFL01: raw GEMM syntax ------------------------------------------
    def visit_BinOp(self, node):
        if (self.in_model_zone and isinstance(node.op, ast.MatMult)
                and not self._allowlisted()):
            self._emit("AFL01", node,
                       "raw `@` matmul in a model zone — route through "
                       "kernels.substrate (or add an ALLOWLIST entry with "
                       "justification)")
        self.generic_visit(node)

    # --- calls: AFL01 (raw jnp GEMMs), AFL02 (site labels), AFL03 --------
    def visit_Call(self, node):
        chain = _name_chain(node.func)
        if chain:
            if (self.in_model_zone and chain[-1] in RAW_GEMM_ATTRS
                    and not self._allowlisted()):
                self._emit("AFL01", node,
                           f"raw `{'.'.join(chain)}` contraction in a "
                           f"model zone — route through kernels.substrate")
            if self.in_model_zone and chain[-1] in DISPATCH_FNS \
                    and (len(chain) == 1 or chain[-2] == "substrate"):
                self._check_site(node, chain)
            if chain[-1] in MUTATORS:
                for names, owner, remedy in STATE_GROUPS:
                    if (not self.owned[owner]
                            and any(c in names for c in chain[:-1])):
                        self._emit("AFL03", node,
                                   f"`{'.'.join(chain)}()` mutates {remedy}")
                        break
        self.generic_visit(node)

    def _check_site(self, node, chain) -> None:
        site_kw = next((kw for kw in node.keywords if kw.arg == "site"),
                       None)
        if site_kw is None:
            self._emit("AFL02", node,
                       f"substrate.{chain[-1]} dispatch without a site= "
                       f"label — the planner cannot attribute this GEMM")
            return
        val = site_kw.value
        if isinstance(val, ast.Constant) and isinstance(val.value, str):
            from repro.core import planner    # late: avoids jax at import
            known = planner.site_registry()
            bad = [p for p in val.value.split("+") if p not in known]
            if bad:
                self._emit("AFL02", node,
                           f"site={val.value!r} carries label(s) {bad} "
                           f"unknown to planner.model_gemms")

    # --- AFL03: subscript mutation ---------------------------------------
    def _check_subscript_targets(self, node, targets) -> None:
        for tgt in targets:
            chain = _subscript_base(tgt)
            for names, owner, remedy in STATE_GROUPS:
                if (not self.owned[owner]
                        and any(c in names for c in chain)):
                    self._emit("AFL03", node,
                               f"subscript write to `{'.'.join(chain)}` "
                               f"mutates {remedy}")
                    break

    def visit_Assign(self, node):
        self._check_subscript_targets(node, node.targets)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._check_subscript_targets(node, [node.target])
        self.generic_visit(node)

    def visit_Delete(self, node):
        self._check_subscript_targets(node, node.targets)
        self.generic_visit(node)


def lint_file(path: Path, rel: str) -> List[Finding]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as exc:
        return [Finding("AFL01", f"src/repro/{rel}:{exc.lineno or 0}",
                        f"file does not parse: {exc.msg}",
                        pass_name="lint")]
    linter = _Linter(rel.replace(os.sep, "/"))
    linter.visit(tree)
    return linter.findings


def _default_root() -> Path:
    # src/repro/analysis/ast_lint.py -> src/repro
    return Path(__file__).resolve().parent.parent


def lint_paths(paths: Optional[Sequence] = None,
               root: Optional[Path] = None) -> List[Finding]:
    """Lint ``paths`` (files or directories; default: all of src/repro).
    ``root`` anchors the zone-relative paths (default: the repro package
    directory)."""
    root = Path(root) if root is not None else _default_root()
    if paths is None:
        paths = [root]
    findings: List[Finding] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            try:
                rel = str(f.resolve().relative_to(root.resolve()))
            except ValueError:
                rel = f.name
            findings.extend(lint_file(f, rel))
    return findings


def run() -> List[Finding]:
    return lint_paths()
