"""One-command substrate-contract audit: ``python -m repro.analysis.audit``.

Runs the three analysis passes over the default matrix —

* models:   qwen2-0.5b (dense), qwen3-moe-30b-a3b (MoE), mamba2-370m (SSM)
* backends: xla, arrayflex, arrayflex_int8, arrayflex_w8a8
* meshes:   unsharded and TP2 (mesh ``(1, 2)`` on forced host devices)

— at ``reduced()`` smoke sizes, plus the kernel<->timing consistency
checks and the AST lint, and writes a machine-readable findings JSON.
Exit code 0 iff no error-severity finding (AF008 staged-quantize warnings
do not fail the run).

``--strict`` additionally flips ``REPRO_STRICT_AUDIT`` on for the
process, so any site-label violation raises at dispatch time while the
traces run (the runtime twin of the AF007 finding).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_MODELS = ("qwen2-0.5b", "qwen3-moe-30b-a3b", "mamba2-370m")
DEFAULT_BACKENDS = ("xla", "arrayflex", "arrayflex_int8", "arrayflex_w8a8")


def _force_host_devices(n: int) -> None:
    """Must run before jax initializes its backends (same pattern as
    launch/serve.py --host-devices)."""
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()


def build_report(models, backends, meshes, run_lint=True, run_kernel=True):
    """The full audit; importable for tests (jax-touching imports are
    deferred so the CLI can set XLA_FLAGS first)."""
    import dataclasses

    from repro.analysis import ast_lint, jaxpr_audit, kernel_check
    from repro.analysis.findings import Finding, Report
    from repro.configs import get_config, reduced

    report = Report(meta={
        "models": list(models), "backends": list(backends),
        "meshes": [list(m) for m in meshes],
        "passes": (["jaxpr"] + (["kernel"] if run_kernel else [])
                   + (["lint"] if run_lint else [])),
    })
    cells = []
    for name in models:
        for backend in backends:
            for mesh in meshes:
                cfg = reduced(get_config(name))
                cfg = dataclasses.replace(
                    cfg, gemm_backend=backend, mesh_shape=tuple(mesh))
                tag = f"{name}/{backend}/" + (
                    "tp" + str(mesh[-1]) if mesh else "unsharded")
                try:
                    found = jaxpr_audit.audit_model(cfg, label=tag)
                except Exception as exc:   # a trace crash is itself a finding
                    found = [Finding(
                        "AF001", tag,
                        f"entry-point trace failed: {type(exc).__name__}: "
                        f"{exc}", pass_name="jaxpr")]
                report.extend(found)
                cells.append({"cell": tag, "findings": len(found)})
    report.meta["cells"] = cells
    if run_kernel:
        report.extend(kernel_check.run())
    if run_lint:
        report.extend(ast_lint.run())
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Audit the substrate contract: jaxpr routing, "
                    "kernel/timing consistency, AST lint.")
    ap.add_argument("--models", nargs="*", default=list(DEFAULT_MODELS))
    ap.add_argument("--backends", nargs="*", default=list(DEFAULT_BACKENDS))
    ap.add_argument("--no-tp", action="store_true",
                    help="skip the TP2 sharded column")
    ap.add_argument("--no-lint", action="store_true")
    ap.add_argument("--no-kernel", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="also enable REPRO_STRICT_AUDIT while tracing")
    ap.add_argument("--out", default=os.path.join("results", "audit",
                                                  "audit.json"))
    ap.add_argument("--host-devices", type=int, default=2,
                    help="forced host device count for the TP column")
    args = ap.parse_args(argv)

    meshes = [()] if args.no_tp else [(), (1, 2)]
    if not args.no_tp:
        _force_host_devices(max(args.host_devices, 2))
    if args.strict:
        os.environ["REPRO_STRICT_AUDIT"] = "1"

    report = build_report(args.models, args.backends, meshes,
                          run_lint=not args.no_lint,
                          run_kernel=not args.no_kernel)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(report.to_dict(), fh, indent=2)
    for f in report.errors:
        print(f)
    # warnings are expected in bulk (AF008 per staged weight); tally per
    # code here, full list in the JSON report
    tally: dict = {}
    for f in report.warnings:
        tally[f.code] = tally.get(f.code, 0) + 1
    from repro.analysis.findings import CODES
    for code in sorted(tally):
        print(f"[{code}][warning] x{tally[code]}: {CODES[code][1]}")
    print(f"{'OK' if report.ok else 'FAIL'}: {len(report.errors)} error(s), "
          f"{len(report.warnings)} warning(s)")
    print(f"report: {args.out}")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
