"""Kernel <-> timing-model consistency checker.

Two static checks, no kernel execution:

* **AF005, epilogue pricing** — ``arrayflex_gemm.store_phase`` is the
  single definition of the carry-propagate boundary math (both Pallas
  kernels call it on their accumulator refs), and
  ``arrayflex_gemm.prologue_phase`` of the pre-contraction boundary (the
  fused rmsnorm scale).  For every valid ``Epilogue`` spec x
  quantization, trace both with ``jax.make_jaxpr`` and *count the
  boundary vector ops actually staged* (bias adds, gate multiply,
  dequant multiplies, activation, prologue scale multiply) by tracking
  operand provenance through the jaxpr.  The count must equal what the
  Eq.(5') timing term prices: ``Epilogue.ops`` plus
  ``Epilogue.contractions`` dequant multiplies on a quantizing backend
  (the ``dequant_ops`` term of ``_plan_gemm_cached``).  A fused op added
  to the kernel boundary without repricing — or priced without being
  executed — fails here.

* **AF006, plan-key completeness** — every ``GemmCall``/``BackendInfo``
  field must be covered by the ``_plan_gemm_cached`` key or declared
  plan-irrelevant in ``substrate.CALL_FIELD_KEYING`` /
  ``BACKEND_FIELD_KEYING``; the declarations must reference real
  ``Epilogue``/``BackendInfo`` attributes; ``Epilogue``/``ShardSig`` key
  components must compare/hash on all fields; and the cached planner's
  signature must be exactly ``PLAN_KEY_PARAMS``.  Adding a field that
  changes execution without deciding its keying story is caught here,
  before it aliases cached plans.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.kernels import substrate
from repro.kernels.arrayflex_gemm import prologue_phase, store_phase

_NONLINEAR = frozenset({"logistic", "tanh", "erf", "exp", "rsqrt", "cbrt"})
_CALL_JAXPR_KEYS = ("call_jaxpr", "jaxpr")


# ---------------------------------------------------------------------------
# AF005: provenance-counted boundary ops vs Epilogue.ops pricing

class _OpCount:
    def __init__(self):
        self.bias_adds = 0
        self.bias2_adds = 0
        self.gate_muls = 0
        self.dequant_muls = 0
        self.residual_adds = 0
        self.scale_muls = 0
        self.nonlinear = False

    @property
    def total(self) -> int:
        return (self.bias_adds + self.bias2_adds + self.gate_muls
                + self.residual_adds + self.scale_muls
                + int(self.nonlinear))


def _prov_of(prov, atom):
    """Provenance set of a jaxpr atom (unhashable Literals have none)."""
    try:
        return prov.get(atom, frozenset())
    except TypeError:
        return frozenset()


def _walk_count(jaxpr, prov, count: _OpCount) -> None:
    for eqn in jaxpr.eqns:
        sources = [_prov_of(prov, v) for v in eqn.invars]
        union = frozenset().union(*sources) if sources else frozenset()
        name = eqn.primitive.name
        inner = next((eqn.params[k] for k in _CALL_JAXPR_KEYS
                      if hasattr(eqn.params.get(k), "jaxpr")
                      or hasattr(eqn.params.get(k), "eqns")), None)
        if inner is not None:
            ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            sub = dict(zip(ij.invars, sources))
            for cv in ij.constvars:
                sub[cv] = frozenset()
            _walk_count(ij, sub, count)
            for ov, iv in zip(eqn.outvars, ij.outvars):
                prov[ov] = _prov_of(sub, iv)
            continue
        if name == "add":
            # one operand IS the bias vector (exactly-{bias} provenance);
            # downstream adds merely inherit bias provenance and are the
            # activation's internal arithmetic, not a boundary op
            if any(s == {"bias"} for s in sources):
                count.bias_adds += 1
            elif any(s == {"bias2"} for s in sources):
                count.bias2_adds += 1
            elif any(s == {"residual"} for s in sources):
                count.residual_adds += 1
        elif name == "mul":
            if any(s == {"norm_scale"} for s in sources):
                count.scale_muls += 1
            elif any(s in ({"w_scale"}, {"w2_scale"}) for s in sources):
                count.dequant_muls += 1
            elif (any("y2" in s for s in sources)
                  and any("y2" not in s and "y" in s for s in sources)):
                count.gate_muls += 1
        elif name in _NONLINEAR:
            count.nonlinear = True
        for ov in eqn.outvars:
            prov[ov] = union


def _count_store_ops(store_fn: Callable, ep: substrate.Epilogue,
                     quant: bool, n: int = 8,
                     prologue_fn: Callable = prologue_phase) -> _OpCount:
    """Trace ``store_fn`` (and, when the spec fuses the rmsnorm scale,
    ``prologue_fn``) on resolved-accumulator avals for ``ep`` and count
    the boundary ops they stage."""
    row = jnp.zeros((1, n), jnp.float32)
    vec = jnp.zeros((n,), jnp.float32)
    operands = {"y": row}
    if ep.dual:
        operands["y2"] = row
    if quant:
        operands["w_scale"] = vec
        if ep.dual:
            operands["w2_scale"] = vec
    if ep.bias:
        operands["bias"] = vec
    if ep.bias2:
        operands["bias2"] = vec
    if ep.residual:
        operands["residual"] = row
    names = list(operands)
    closed = jax.make_jaxpr(
        lambda *args: store_fn(activation=ep.activation,
                               **dict(zip(names, args))))(*operands.values())
    prov = {v: frozenset({nm})
            for v, nm in zip(closed.jaxpr.invars, names)}
    count = _OpCount()
    _walk_count(closed.jaxpr, prov, count)
    if ep.norm_scale:
        # the scale multiply rides the step prologue, not the store —
        # trace it separately and fold its op count in
        pro = jax.make_jaxpr(prologue_fn)(row, vec)
        prov_p = {v: frozenset({nm})
                  for v, nm in zip(pro.jaxpr.invars, ("x", "norm_scale"))}
        _walk_count(pro.jaxpr, prov_p, count)
    return count


def _valid_epilogues():
    for kind in substrate.EPILOGUE_KINDS:
        dual = kind == "swiglu"
        for bias in (False, True):
            for bias2 in ((False, True) if dual else (False,)):
                for residual in (False, True):
                    for norm_scale in (False, True):
                        yield substrate.Epilogue(
                            kind=kind, bias=bias, bias2=bias2,
                            residual=residual, norm_scale=norm_scale)


def check_epilogue_pricing(
        store_fn: Callable = store_phase,
        priced_ops: Optional[Callable] = None) -> List[Finding]:
    """AF005 over every valid Epilogue spec x quantization.

    ``priced_ops(ep, quant)`` is what the timing model charges at the
    collapsed-block boundary (default: the ``_plan_gemm_cached`` formula
    minus the shard reduce term, which has no kernel-side op to count).
    """
    if priced_ops is None:
        def priced_ops(ep, quant):
            return ep.ops + (ep.contractions if quant else 0)
    findings = []
    for ep in _valid_epilogues():
        for quant in (False, True):
            count = _count_store_ops(store_fn, ep, quant)
            measured = count.total + count.dequant_muls
            priced = priced_ops(ep, quant)
            if measured != priced:
                findings.append(Finding(
                    "AF005",
                    f"store_phase[kind={ep.kind}, bias={ep.bias}, "
                    f"bias2={ep.bias2}, residual={ep.residual}, "
                    f"norm_scale={ep.norm_scale}, quant={quant}]",
                    f"kernel boundary stages {measured} op(s) "
                    f"(bias={count.bias_adds}+{count.bias2_adds}, "
                    f"gate={count.gate_muls}, dequant={count.dequant_muls}, "
                    f"residual={count.residual_adds}, "
                    f"scale={count.scale_muls}, "
                    f"act={int(count.nonlinear)}) but the Eq.(5') pricing "
                    f"charges {priced}", pass_name="kernel"))
    return findings


# ---------------------------------------------------------------------------
# AF006: plan-cache key completeness

def _field_names(cls) -> set:
    return {f.name for f in dataclasses.fields(cls)}


def check_plan_key(
        call_keying=None, backend_keying=None, key_params=None,
        plan_fn=None, call_cls=None, backend_cls=None,
        epilogue_cls=None, shard_cls=None) -> List[Finding]:
    """AF006: the declared keying metadata must exactly cover the
    dataclasses, reference real attributes, and match the cached
    planner's actual signature.  All arguments default to the live
    ``substrate`` objects; tests override them to seed drift.
    """
    call_keying = substrate.CALL_FIELD_KEYING if call_keying is None \
        else call_keying
    backend_keying = substrate.BACKEND_FIELD_KEYING if backend_keying is None \
        else backend_keying
    key_params = substrate.PLAN_KEY_PARAMS if key_params is None \
        else key_params
    plan_fn = substrate._plan_gemm_cached if plan_fn is None else plan_fn
    call_cls = substrate.GemmCall if call_cls is None else call_cls
    backend_cls = substrate.BackendInfo if backend_cls is None else backend_cls
    epilogue_cls = substrate.Epilogue if epilogue_cls is None else epilogue_cls
    shard_cls = substrate.ShardSig if shard_cls is None else shard_cls

    findings = []

    def af006(where, msg):
        findings.append(Finding("AF006", where, msg, pass_name="kernel"))

    # (1) GemmCall fields <-> CALL_FIELD_KEYING, exactly
    call_fields = _field_names(call_cls)
    for f in sorted(call_fields - set(call_keying)):
        af006(f"GemmCall.{f}",
              "field has no keying declaration in CALL_FIELD_KEYING — "
              "decide whether it must enter the plan key or is "
              "plan-irrelevant per-call data")
    for f in sorted(set(call_keying) - call_fields):
        af006(f"CALL_FIELD_KEYING[{f!r}]",
              "declaration references a field GemmCall no longer has")

    # (2) declarations must point at real key-side attributes
    for f, decl in call_keying.items():
        kind = decl.split(":", 1)[0].strip()
        if kind == "epilogue":
            attr = decl.split(":", 1)[1].split()[0].strip()
            if not hasattr(epilogue_cls, attr) \
                    and attr not in _field_names(epilogue_cls):
                af006(f"CALL_FIELD_KEYING[{f!r}]",
                      f"claims coverage via Epilogue.{attr}, which does "
                      f"not exist")
        elif kind == "backend":
            attr = decl.split(":", 1)[1].split()[0].strip()
            if attr not in _field_names(backend_cls):
                af006(f"CALL_FIELD_KEYING[{f!r}]",
                      f"claims coverage via BackendInfo.{attr}, which "
                      f"does not exist")
        elif kind != "operand":
            af006(f"CALL_FIELD_KEYING[{f!r}]",
                  f"unknown keying kind {kind!r} (want epilogue:/backend:/"
                  f"operand:)")

    # (3) BackendInfo fields <-> BACKEND_FIELD_KEYING, exactly
    backend_fields = _field_names(backend_cls)
    for f in sorted(backend_fields - set(backend_keying)):
        af006(f"BackendInfo.{f}",
              "field has no keying declaration in BACKEND_FIELD_KEYING")
    for f in sorted(set(backend_keying) - backend_fields):
        af006(f"BACKEND_FIELD_KEYING[{f!r}]",
              "declaration references a field BackendInfo no longer has")

    # (4) cached planner signature == the declared key, in order
    target = inspect.unwrap(plan_fn)
    params = tuple(inspect.signature(target).parameters)
    if params != tuple(key_params):
        af006("_plan_gemm_cached",
              f"cache-key signature {params} != declared PLAN_KEY_PARAMS "
              f"{tuple(key_params)}")

    # (5) hashable key components must compare on every field
    for cls in (epilogue_cls, shard_cls):
        for f in dataclasses.fields(cls):
            if not f.compare:
                af006(f"{cls.__name__}.{f.name}",
                      "field is excluded from __eq__/__hash__ but the "
                      "class is a plan-cache key component — two specs "
                      "differing only here would alias one plan")
    return findings


def run() -> List[Finding]:
    return check_epilogue_pricing() + check_plan_key()
