"""The substrate contract: which code may legally emit contractions.

Shared by the jaxpr auditor (traceback-frame attribution) and the AST lint
(static call-site attribution), so one allowlist governs both views of the
same rule: every model GEMM routes through ``kernels.substrate``.
"""
from __future__ import annotations

import os
from typing import Iterable, Optional, Tuple

# Files whose contractions ARE the substrate: a dot_general/conv whose
# traceback passes through any of these is substrate-dispatched by
# construction (the dispatch layer itself, the Pallas kernels, and the
# kernel helpers they stage through).
SUBSTRATE_FILES = (
    os.path.join("kernels", "substrate.py"),
    os.path.join("kernels", "arrayflex_gemm.py"),
    os.path.join("kernels", "ops.py"),
    os.path.join("kernels", "flash_attention.py"),
    os.path.join("kernels", "ref.py"),
)

# (file suffix under src/repro, top-level function) -> justification.
# Contractions reached through these functions are genuinely out of the
# substrate's scope; every entry carries its reason.  The AST lint applies
# the same entries to raw-GEMM syntax in the same functions.
ALLOWLIST = {
    (os.path.join("nn", "mamba.py"), "ssd_chunked"):
        "SSD intra-chunk contractions live inside the inter-chunk state "
        "scan body (rematted, chunk-local shapes); they are part of the "
        "selective-scan recurrence, not a planned model GEMM — pricing "
        "them through Eq.(6') is the ROADMAP SSM-kernel follow-up.",
    (os.path.join("nn", "mamba.py"), "mamba_decode_step"):
        "single-token SSM state update: per-head (N,P)-shaped outer "
        "products and the depthwise-conv window einsum — state recurrence "
        "arithmetic, below the substrate's GEMM granularity.",
    (os.path.join("nn", "mamba.py"), "_causal_conv"):
        "depthwise causal conv (feature_group_count == channels): one "
        "MAC per tap per channel, not a dense contraction the systolic "
        "array would tile.",
    (os.path.join("nn", "attention.py"), "chunked_attention"):
        "flash-style online-softmax KV scan: its QK/PV blocks run inside "
        "the remat'd scan step whose schedule IS the ArrayFlex-collapse "
        "analogue (planner.attention_plan picks the chunk), so the "
        "substrate plan would double-count it.",
    (os.path.join("nn", "moe.py"), "moe_apply_reference"):
        "O(T*E*d*ff) dense oracle used only by equivalence tests — it "
        "deliberately bypasses dispatch to validate the dispatch path.",
}


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def repro_rel(file_name: str) -> Optional[str]:
    """Path relative to src/repro when ``file_name`` is inside it."""
    marker = _norm(os.path.join("src", "repro")) + "/"
    p = _norm(file_name)
    if marker in p:
        return p.rsplit(marker, 1)[1]
    return None


def is_substrate_file(rel: str) -> bool:
    return any(rel == _norm(s) for s in SUBSTRATE_FILES)


def allowlisted(rel: str, function: str) -> bool:
    return (rel.replace("/", os.sep), function) in ALLOWLIST


def classify_frames(frames: Iterable[Tuple[str, str]]) -> Tuple[str, str]:
    """Attribute an equation by its (file, function) traceback frames,
    innermost first.  Returns (verdict, where):

    * ``("substrate", rel)``   — reached through the dispatch/kernels;
    * ``("allowlisted", rel#fn)`` — an ALLOWLIST entry is on the stack;
    * ``("unattributed", rel-or-"?")`` — no substrate frame, no allowlist
      entry: a bypass contraction (AF001).
    """
    first_rel = None
    for file_name, function in frames:
        rel = repro_rel(file_name)
        if rel is None:
            continue
        if first_rel is None:
            first_rel = f"{rel}:{function}"
        if is_substrate_file(rel):
            return "substrate", rel
        if allowlisted(rel, function):
            return "allowlisted", f"{rel}#{function}"
    return "unattributed", first_rel or "?"
