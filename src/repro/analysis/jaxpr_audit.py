"""Jaxpr auditor: trace the lm entry points and verify GEMM routing.

For each (config, backend, entry-point) cell the auditor traces the jitted
computation to a closed jaxpr with ``jax.make_jaxpr`` and walks every
equation (recursing through pjit/scan/shard_map/remat/pallas_call inner
jaxprs).  Checks:

* **AF001** every ``dot_general``/``conv_general_dilated`` must be
  attributable — via its traceback frames — to the substrate dispatch
  layer or an explicit :data:`repro.analysis.contract.ALLOWLIST` entry;
* **AF002** every ``psum`` on a substrate contraction path (and, under a
  quantizing backend, every float psum anywhere) must be fp32; *and*
  (the sharding-contract leg, :func:`check_psum_boundaries`) every
  substrate psum staged under a quantizing backend must sit at a
  collapsed-block boundary the plan actually priced — some recorded
  ``substrate.SITE_PLANS`` entry carries ``ShardSig.reduce_ops > 0``, so
  the combine tree entered the Eq.(5') argmin rather than riding free;
  *and* (the pipeline-transfer leg, :func:`check_stage_boundaries`)
  every stage-boundary ``collective_permute`` staged by
  ``parallel.pipeline.staged_step`` must correspond to a recorded plan
  that priced the pod->pod transfer (``ShardSig.transfer_ops`` or
  ``transfer_cycles`` non-zero somewhere) — a pipeline hop whose cost
  never entered the argmin means the roles' collapse depths were chosen
  as if the ICI were free;
* **AF003/AF008** ``convert_element_type`` to int8 on a weight-shaped
  (ndim >= 2) operand inside the trace: through
  ``substrate.quantize_weight`` it is the *known* staged-quantization of
  the W8 weight path (warning AF008); on a declared W8A8 backend
  (``BackendInfo.act_quantize``) the *dynamic activation* casts — the
  in-kernel per-tile ``quantize_tile`` and the batched-QK in-trace
  ``_quantize`` of K — are the priced Eq.(5') quantize boundary and are
  clean; anywhere else it is a rogue re-quantization (error AF003);
* **AF004** every float scratch ref of a ``pallas_call`` (the carry-save
  accumulators) must be fp32;
* **AF007** every site label recorded in ``substrate.DISPATCH_COUNTS``
  during the trace must be known to ``planner.model_gemms``
  (``planner.site_registry``), and the labels this config's trace records
  must belong to this config's own GEMM walk.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import contract
from repro.analysis.findings import Finding
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm

_CONTRACTIONS = ("dot_general", "conv_general_dilated")


def _inner_jaxprs(eqn) -> Iterator:
    """Every jaxpr nested in an equation's params (pjit/scan/shard_map/
    remat/custom_* carry ClosedJaxpr or Jaxpr values; pallas_call handled
    separately for scratch analysis)."""
    for val in eqn.params.values():
        if hasattr(val, "eqns"):
            yield val
        elif hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
            yield val.jaxpr
        elif isinstance(val, (list, tuple)):
            for v in val:
                if hasattr(v, "eqns"):
                    yield v
                elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                    yield v.jaxpr


def iter_eqns(jaxpr) -> Iterator:
    """Depth-first walk of every equation, recursing into nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for inner in _inner_jaxprs(eqn):
            yield from iter_eqns(inner)


def _frames(eqn) -> List[Tuple[str, str]]:
    tb = getattr(eqn.source_info, "traceback", None)
    if tb is None:
        return []
    return [(fr.file_name, fr.function_name) for fr in tb.frames]


def _float_dtypes(eqn):
    out = []
    for v in eqn.invars:
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            out.append(dt)
    return out


def _check_pallas_scratch(eqn, label: str) -> List[Finding]:
    """AF004: float scratch refs (the carry-save accumulators) are fp32."""
    findings = []
    gm = eqn.params.get("grid_mapping")
    kj = eqn.params.get("jaxpr")
    n_scratch = getattr(gm, "num_scratch_operands", 0)
    if kj is None or not n_scratch:
        return findings
    jx = kj.jaxpr if hasattr(kj, "jaxpr") else kj
    for ref in jx.invars[len(jx.invars) - n_scratch:]:
        aval = getattr(ref.aval, "inner_aval", ref.aval)
        dt = getattr(aval, "dtype", None)
        if (dt is not None and jnp.issubdtype(dt, jnp.floating)
                and dt != jnp.float32):
            findings.append(Finding(
                "AF004", label,
                f"pallas_call float scratch accumulator is {dt}, must be "
                f"float32 (carry-save chain of the collapsed schedule)",
                pass_name="jaxpr"))
    return findings


def audit_closed_jaxpr(closed, *, quantized: bool = False,
                       act_quantized: bool = False,
                       label: str = "trace") -> List[Finding]:
    """Walk one closed jaxpr; returns AF001-AF004/AF008 findings.

    ``act_quantized`` declares a W8A8 backend: dynamic activation
    quantization — ``quantize_tile`` inside the Pallas kernels and the
    batched-path ``_quantize`` of K staged from ``_batched_exec`` — is
    then the priced quantize boundary, not an AF003/AF008 candidate."""
    findings: List[Finding] = []
    for eqn in iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if prim in _CONTRACTIONS:
            verdict, where = contract.classify_frames(_frames(eqn))
            if verdict == "unattributed":
                findings.append(Finding(
                    "AF001", f"{label} @ {where}",
                    f"{prim} not attributable to a substrate dispatch site "
                    f"or an ALLOWLIST entry (raw GEMM bypasses Eq.(6') "
                    f"planning)", pass_name="jaxpr"))
        elif prim.startswith("psum"):
            floats = _float_dtypes(eqn)
            bad = [dt for dt in floats if dt != jnp.float32]
            if not bad:
                continue
            verdict, where = contract.classify_frames(_frames(eqn))
            if quantized or verdict == "substrate":
                findings.append(Finding(
                    "AF002", f"{label} @ {where}",
                    f"psum on {[str(d) for d in bad]} operands — sharded "
                    f"contraction reductions must accumulate in fp32",
                    pass_name="jaxpr"))
        elif prim == "convert_element_type":
            if eqn.params.get("new_dtype") != jnp.int8:
                continue
            shape = getattr(eqn.invars[0].aval, "shape", ())
            if len(shape) < 2:
                continue
            frames = _frames(eqn)
            if act_quantized and any(
                    fn in ("quantize_tile", "_batched_exec")
                    and contract.repro_rel(f) is not None
                    for f, fn in frames):
                # declared W8A8 dynamic activation quantize: the per-tile
                # in-kernel quantizer / the batched-QK quantize of K is
                # the Eq.(5') boundary the plan priced (actq_ops), by
                # design re-executed per step — neither staged weight
                # quantization nor a rogue cast
                continue
            staged = any(fn in ("quantize_weight", "_quantize")
                         and contract.repro_rel(f) is not None
                         for f, fn in frames)
            _, where = contract.classify_frames(frames)
            if staged:
                findings.append(Finding(
                    "AF008", f"{label} @ {where}",
                    f"weight quantization of {shape} staged into the trace "
                    f"(substrate.quantize_weight on a tracer) — re-executed "
                    f"per compiled step until params are pre-quantized",
                    pass_name="jaxpr"))
            else:
                findings.append(Finding(
                    "AF003", f"{label} @ {where}",
                    f"in-trace convert_element_type to int8 on a "
                    f"weight-shaped {shape} operand outside "
                    f"substrate.quantize_weight (rogue re-quantization)",
                    pass_name="jaxpr"))
        elif prim == "pallas_call":
            findings.extend(_check_pallas_scratch(eqn, label))
    return findings


def check_psum_boundaries(closed, *, quantized: bool = False,
                          site_plans=None,
                          label: str = "trace") -> List[Finding]:
    """AF002, sharding-contract leg: every substrate ``psum`` staged under
    a quantizing backend must sit at a collapsed-block boundary the plan
    priced.

    The dtype leg (fp32 operands) lives in :func:`audit_closed_jaxpr`;
    this leg cross-checks the *pricing*: a substrate-attributed float
    psum in the trace means ``_sharded_gemm`` took the reduce path, so
    the recorded plans (``substrate.SITE_PLANS``, reset per entry trace)
    must include at least one whose ``ShardSig.reduce_ops > 0`` — the
    ``ceil(log2(shards))`` combine-tree adds entered the Eq.(5') argmin.
    A psum with no priced reduce anywhere means the collapse depth was
    chosen as if the cross-shard combine were free (the sharding rules
    only set ``reduce_axes`` for genuinely sharded contractions, so this
    never fires on a clean trace)."""
    if not quantized:
        return []
    plans = substrate.SITE_PLANS if site_plans is None else site_plans
    priced = any(p.shard.reduce_ops > 0 for p in plans.values())
    findings: List[Finding] = []
    for eqn in iter_eqns(closed.jaxpr):
        if not eqn.primitive.name.startswith("psum"):
            continue
        if not _float_dtypes(eqn):
            continue
        verdict, where = contract.classify_frames(_frames(eqn))
        if verdict != "substrate" or priced:
            continue
        findings.append(Finding(
            "AF002", f"{label} @ {where}",
            "substrate psum on the quantized path but no recorded site "
            "plan priced a reduce boundary (ShardSig.reduce_ops == 0 "
            "everywhere) — the collapse depth was chosen as if the "
            "cross-shard combine were free", pass_name="jaxpr"))
    return findings


def check_stage_boundaries(closed, *, site_plans=None,
                           label: str = "trace") -> List[Finding]:
    """AF002, pipeline-transfer leg: a stage-boundary
    ``collective_permute`` must be priced by some recorded plan.

    ``parallel.pipeline.staged_step`` moves the (rows, d_model)
    activation pod->pod once per tick; that hop is priced into the
    boundary site's plan by ``sharding.use_pp_pricing`` (prefill: Eq.(5')
    boundary ops; decode: Eq.(6'') serialized ingress cycles).  A
    ``ppermute`` staged from ``staged_step`` while *no* recorded
    ``substrate.SITE_PLANS`` entry carries ``ShardSig.transfer_ops > 0``
    or ``transfer_cycles > 0`` means the pipeline ran without a role
    pricing scope — the collapse depths were chosen as if the ICI
    transfer were free.  Never fires on the colocated paths (no
    ppermute) or on a correctly-scoped role trace (the
    ``PP_BOUNDARY_SITE`` plan prices the hop)."""
    plans = substrate.SITE_PLANS if site_plans is None else site_plans
    priced = any(p.shard.transfer_ops > 0 or p.shard.transfer_cycles > 0
                 for p in plans.values())
    findings: List[Finding] = []
    for eqn in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "ppermute":
            continue
        frames = _frames(eqn)
        ours = any(fn == "staged_step" and contract.repro_rel(f) is not None
                   for f, fn in frames)
        if not ours or priced:
            continue
        _, where = contract.classify_frames(frames)
        findings.append(Finding(
            "AF002", f"{label} @ {where}",
            "stage-boundary collective_permute staged by "
            "parallel.pipeline.staged_step but no recorded site plan "
            "priced the transfer (ShardSig.transfer_ops == "
            "transfer_cycles == 0 everywhere) — the pipeline hop never "
            "entered the Eq.(5')/(6'') argmin (missing "
            "sharding.use_pp_pricing role scope)", pass_name="jaxpr"))
    return findings


def check_recorded_sites(cfg: Optional[ModelConfig] = None,
                         label: str = "trace",
                         counts=None) -> List[Finding]:
    """AF007 over ``substrate.DISPATCH_COUNTS``: every recorded label must
    be planner-known; with a ``cfg``, labels must also belong to that
    config's own ``model_gemms`` walk (plus the extra dispatch sites)."""
    known = planner.site_registry()
    if cfg is not None:
        own = set(planner.EXTRA_DISPATCH_SITES)
        for shape in (ShapeConfig("audit_train", 64, 2, "train"),
                      ShapeConfig("audit_decode", 64, 2, "decode")):
            own.update(g.name for g in planner.model_gemms(cfg, shape))
    else:
        own = known
    findings = []
    counts = substrate.DISPATCH_COUNTS if counts is None else counts
    for site in counts:
        for part in site.split("+"):
            if part not in known:
                findings.append(Finding(
                    "AF007", f"{label} @ site={site!r}",
                    f"dispatch label {part!r} unknown to "
                    f"planner.model_gemms", pass_name="jaxpr"))
            elif part not in own:
                findings.append(Finding(
                    "AF007", f"{label} @ site={site!r}",
                    f"dispatch label {part!r} is not in this config's own "
                    f"GEMM walk", pass_name="jaxpr"))
    return findings


# ---------------------------------------------------------------------------
# entry-point tracing

def _trace_entries(cfg: ModelConfig, *, prequantize: bool = False):
    """(entry_name, thunk) pairs; each thunk returns a ClosedJaxpr."""
    B, S = 2, 8
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    if prequantize:
        params = lm.prequantize_params(cfg, params)
    tokens = jnp.zeros((B, S), jnp.int32)
    batch = {"tokens": tokens}

    def trace_forward():
        return jax.make_jaxpr(
            lambda p, b: lm.forward(cfg, p, b))(params, batch)

    cache = lm.init_cache(cfg, B, S)
    token = jnp.zeros((B,), jnp.int32)
    pos = jnp.int32(1)

    def trace_decode():
        return jax.make_jaxpr(
            lambda p, c, t, q: lm.decode_step(cfg, p, c, t, q))(
                params, cache, token, pos)

    entries = [("forward", trace_forward), ("decode_step", trace_decode)]

    if lm.supports_batched_prefill(cfg):
        ptoks = jnp.zeros((B, 4), jnp.int32)
        ppos = jnp.zeros((B,), jnp.int32)
        lens = jnp.full((B,), 4, jnp.int32)

        def trace_prefill():
            return jax.make_jaxpr(
                lambda p, c, t, q, n: lm.prefill_step(cfg, p, c, t, q, n))(
                    params, cache, ptoks, ppos, lens)

        entries.append(("prefill_step", trace_prefill))
    return entries


def audit_model(cfg: ModelConfig, label: str = "", *,
                prequantize: bool = False) -> List[Finding]:
    """Trace forward/decode_step/prefill_step for ``cfg`` and run every
    jaxpr check plus the dispatch-site cross-check.  ``cfg`` carries the
    backend (``gemm_backend``) and mesh (``mesh_shape``) under audit.

    ``prequantize`` audits the serving configuration: the param tree is
    pre-quantized via ``lm.prequantize_params`` so int8 codes enter the
    trace as constants — a quantizing backend should then emit zero
    AF008 staged-quantization warnings (the serving engine dispatches
    this tree; the default ``False`` audits the raw-tree path, which is
    expected to carry AF008)."""
    label = label or f"{cfg.name}/{cfg.gemm_backend}"
    quantized = substrate.backend_quantizes(cfg.gemm_backend)
    act_quantized = substrate.backend_act_quantizes(cfg.gemm_backend)
    findings: List[Finding] = []
    for entry, thunk in _trace_entries(cfg, prequantize=prequantize):
        substrate.clear_plan_cache()     # fresh site log per entry
        closed = thunk()
        cell = f"{label}/{entry}"
        findings.extend(audit_closed_jaxpr(closed, quantized=quantized,
                                           act_quantized=act_quantized,
                                           label=cell))
        findings.extend(check_psum_boundaries(closed, quantized=quantized,
                                              label=cell))
        findings.extend(check_recorded_sites(cfg, label=cell))
    substrate.clear_plan_cache()
    return findings


def audit_pipeline(cfg: ModelConfig, label: str = "") -> List[Finding]:
    """Jaxpr audit over the pipeline-sharded entry points
    (``lm.decode_step_pp`` / ``lm.prefill_step_pp``): every colocated
    check plus :func:`check_stage_boundaries`.

    ``cfg`` must satisfy ``lm.supports_pipeline`` (pp_stages > 1, a
    (pp, data, model) mesh_shape) and the host must have the mesh's
    devices — role configs from the disaggregated engine qualify.  The
    serving tree is audited (pre-quantized on a quantizing backend), so
    a clean cell is also AF008-free."""
    label = label or (f"{cfg.name}/{cfg.gemm_backend}/"
                      f"{cfg.pp_role or 'unscoped'}-pp{cfg.pp_stages}")
    quantized = substrate.backend_quantizes(cfg.gemm_backend)
    act_quantized = substrate.backend_act_quantizes(cfg.gemm_backend)
    B, S = 2, 8
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if quantized:
        params = lm.prequantize_params(cfg, params)
    cache = lm.init_cache(cfg, B, S)
    token = jnp.zeros((B,), jnp.int32)
    pos = jnp.int32(1)
    ptoks = jnp.zeros((B, 4), jnp.int32)
    ppos = jnp.zeros((B,), jnp.int32)
    lens = jnp.full((B,), 4, jnp.int32)
    entries = [
        ("decode_step_pp", lambda: jax.make_jaxpr(
            lambda p, c, t, q: lm.decode_step_pp(cfg, p, c, t, q))(
                params, cache, token, pos)),
        ("prefill_step_pp", lambda: jax.make_jaxpr(
            lambda p, c, t, q, n: lm.prefill_step_pp(cfg, p, c, t, q, n))(
                params, cache, ptoks, ppos, lens)),
    ]
    findings: List[Finding] = []
    for entry, thunk in entries:
        substrate.clear_plan_cache()     # fresh site log per entry
        closed = thunk()
        cell = f"{label}/{entry}"
        findings.extend(audit_closed_jaxpr(closed, quantized=quantized,
                                           act_quantized=act_quantized,
                                           label=cell))
        findings.extend(check_psum_boundaries(closed, quantized=quantized,
                                              label=cell))
        findings.extend(check_stage_boundaries(closed, label=cell))
        findings.extend(check_recorded_sites(cfg, label=cell))
    substrate.clear_plan_cache()
    return findings
