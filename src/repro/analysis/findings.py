"""Finding / Report types shared by the three auditor passes.

Every violation is a :class:`Finding` with a stable machine-readable code
(``AFxxx`` for the jaxpr/kernel passes, ``AFLxx`` for the AST lint), a
severity, and a location string.  :class:`Report` aggregates findings and
serializes to the JSON the CI audit job archives.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List

# code -> (default severity, one-line description).  docs/substrate.md
# ("Contract rules") documents the invariant behind each code.
CODES: Dict[str, tuple] = {
    "AF001": ("error", "dot_general/conv not attributable to a substrate "
                       "dispatch site (raw GEMM bypassed the planner)"),
    "AF002": ("error", "non-fp32 psum on a quantized/substrate contraction "
                       "path (the PR-5 fp32-psum rule)"),
    "AF003": ("error", "in-trace weight re-quantization: convert_element_"
                       "type to int8 on a weight-shaped operand outside "
                       "substrate.quantize_weight"),
    "AF004": ("error", "Pallas kernel accumulator (scratch ref) is a "
                       "non-fp32 float — carry-save chain must be fp32"),
    "AF005": ("error", "kernel store boundary-op count drifted from "
                       "Epilogue.ops/d_epilogue_ps pricing"),
    "AF006": ("error", "plan-cache key incompleteness: a GemmCall/ShardSig/"
                       "BackendInfo field changes execution but is not "
                       "keyed or declared plan-irrelevant"),
    "AF007": ("error", "dispatch site label unknown to planner.model_gemms"),
    "AF008": ("warning", "weight quantization staged into the jit trace "
                         "via substrate.quantize_weight (known ROADMAP "
                         "W8A8 follow-up: hoist via pre-quantized params)"),
    "AFL01": ("error", "raw jnp.dot/einsum/@ GEMM in nn/, models/ or "
                       "serving/ outside the explicit allowlist"),
    "AFL02": ("error", "substrate dispatch without a site= label, or with "
                       "a label unknown to the planner registry"),
    "AFL03": ("error", "owned mutable state touched outside its owner "
                       "module: substrate plan/dispatch caches outside "
                       "kernels/substrate.py, or paged-KV page-table/pool "
                       "state outside serving/engine.py+paged.py"),
}


@dataclass(frozen=True)
class Finding:
    code: str
    where: str                # file:line, or trace label (cfg/backend/entry)
    message: str
    pass_name: str = ""       # jaxpr | kernel | lint
    severity: str = ""        # defaults from CODES

    def __post_init__(self):
        if not self.severity:
            sev = CODES.get(self.code, ("error", ""))[0]
            object.__setattr__(self, "severity", sev)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"[{self.code}][{self.severity}] {self.where}: "
                f"{self.message}")


@dataclass
class Report:
    """Aggregated findings of one auditor run."""

    findings: List[Finding] = field(default_factory=list)
    meta: Dict[str, object] = field(default_factory=dict)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def exit_code(self) -> int:
        return 0 if self.ok else 1

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "meta": self.meta,
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def summary(self) -> str:
        lines = [str(f) for f in self.findings]
        lines.append(
            f"{'OK' if self.ok else 'FAIL'}: {len(self.errors)} error(s), "
            f"{len(self.warnings)} warning(s)")
        return "\n".join(lines)
