"""Static-analysis subsystem: the substrate contract auditor.

Three passes, one CLI (``python -m repro.analysis.audit``):

* :mod:`repro.analysis.jaxpr_audit` — trace the lm entry points to closed
  jaxprs and verify every contraction is substrate-attributed, psums on
  quantized paths are fp32, no rogue in-trace weight re-quantization, and
  Pallas accumulators are fp32;
* :mod:`repro.analysis.kernel_check` — statically compare the kernel
  store's boundary-op count against ``Epilogue.ops`` pricing, and audit
  the plan-cache key for field completeness;
* :mod:`repro.analysis.ast_lint` — AST rules over ``src/repro``: no raw
  GEMMs outside the substrate, ``site=`` labels at dispatch calls,
  no plan-cache mutation outside ``clear_plan_cache``.

Finding codes live in :mod:`repro.analysis.findings`; the enforced
invariants are documented in docs/substrate.md ("Contract rules").
"""
from repro.analysis.findings import Finding, Report, CODES

__all__ = ["Finding", "Report", "CODES"]
