"""Public model API: step builders + abstract input specs for the dry-run.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, zero allocation), and
``input_pspecs`` the matching PartitionSpec tree for a mesh.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SSMConfig
from repro.models import lm
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.parallel import sharding


def dec_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Decoder-token length for enc-dec (audio) models."""
    return max(shape.seq_len // 8, 16)


# ---------------------------------------------------------------------------
# abstract specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels=True):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        DL = dec_len(cfg, shape)
        out = {"frames": _sds((B, S, cfg.d_frontend), jnp.bfloat16),
               "tokens": _sds((B, DL), jnp.int32)}
        if with_labels:
            out["labels"] = _sds((B, DL), jnp.int32)
        return out
    out = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = _sds((B, cfg.n_image_tokens, cfg.d_frontend),
                                   jnp.bfloat16)
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache, token, pos) ShapeDtypeStructs for serve_step."""
    B = shape.global_batch
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, shape.seq_len))
    return cache, _sds((B,), jnp.int32), _sds((), jnp.int32)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: OptConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(partial(adamw_init, cfg=opt_cfg), params)


# ---------------------------------------------------------------------------
# partition specs

def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 *, with_labels=True):
    B = shape.global_batch
    tok = sharding.token_pspec(mesh, B)
    act = sharding.activation_pspec(mesh, B)
    if cfg.family == "audio":
        out = {"frames": act, "tokens": tok}
        if with_labels:
            out["labels"] = tok
        return out
    out = {"tokens": tok}
    if cfg.family == "vlm":
        out["image_embeds"] = act
    if with_labels:
        out["labels"] = tok
    return out


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    B = shape.global_batch
    ssm = cfg.ssm or SSMConfig()
    hg = (cfg.d_inner // ssm.head_dim) // ssm.n_groups
    conv_ch = cfg.d_inner + 2 * ssm.n_groups * ssm.d_state
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, shape.seq_len))

    def spec(path, leaf):
        name = str(path[-1].key)
        if name in ("k", "v", "xk", "xv"):
            return sharding.kv_cache_pspec(mesh, B, leaf.shape[2])
        if name == "state":
            return sharding.ssm_state_pspec(mesh, B, hg)
        if name == "conv":
            return sharding.conv_state_pspec(mesh, B, conv_ch)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def decode_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    B = shape.global_batch
    tok = P(sharding._maybe(B, mesh, sharding.batch_axes(mesh)))
    return cache_pspecs(cfg, shape, mesh), tok, P()


def param_pspecs(cfg: ModelConfig, mesh):
    return sharding.param_pspec_tree(
        abstract_params(cfg), mesh,
        moe_experts=cfg.moe.num_experts if cfg.moe else 0)


def opt_pspecs(cfg: ModelConfig, opt_cfg: OptConfig, mesh):
    pp = param_pspecs(cfg, mesh)
    out = {"m": pp, "v": pp, "step": P()}
    if opt_cfg.master_weights:
        out["master"] = pp
    return out


# ---------------------------------------------------------------------------
# step builders

def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    n_microbatches: int = 1):
    """Fused fwd+bwd+optimizer step; n_microbatches > 1 accumulates
    gradients over micro-slices of the global batch (activation memory
    scales 1/n at the cost of an fp32 grad accumulator)."""
    if n_microbatches <= 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, batch), has_aux=True)(params)
            new_params, new_opt, stats = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
            metrics = dict(metrics, loss=loss, **stats)
            return new_params, new_opt, metrics
        return train_step

    from repro.parallel.sharding import constrain

    def train_step(params, opt_state, batch):
        def split(x):
            n = n_microbatches
            b = x.shape[0] // n
            # micro m takes a stride-n slice so every microbatch spans all
            # data shards evenly
            xr = jnp.moveaxis(
                x.reshape((b, n) + x.shape[1:]), 1, 0)
            return constrain(xr, "micro_batch")

        micro = jax.tree.map(split, batch)
        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def step(carry, mb):
            gacc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, mb), has_aux=True)(params)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_microbatches,
                gacc, grads)
            return (gacc, loss_acc + loss / n_microbatches), None

        (grads, loss), _ = jax.lax.scan(
            step, (gacc0, jnp.float32(0.0)), micro)
        new_params, new_opt, stats = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics = dict(loss=loss, **stats)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        return lm.decode_step(cfg, params, cache, token, pos)
    return serve_step
