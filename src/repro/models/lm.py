"""Unified LM model family: dense / MoE / hybrid(Jamba) / SSM / VLM / audio.

Layers are grouped into *super-blocks* of ``period(cfg)`` sub-layers; every
super-block has identical structure, so the stack of ``n_layers/period``
super-blocks is executed with ``jax.lax.scan`` (one layer's HLO regardless of
depth — essential for 100-layer dry-runs) and optionally rematerialized.

Param/caches are plain pytrees; leaves of ``blocks``/``enc_blocks`` carry a
leading ``n_super`` stack dim consumed by the scan.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels import substrate
from repro.nn import layers, attention as attn_lib, moe as moe_lib, mamba as mamba_lib
from repro.parallel import sharding
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# structure helpers

def _lcm(a, b):
    return a * b // math.gcd(a, b)


def period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = _lcm(p, cfg.hybrid_period)
    if cfg.family == "vlm":
        p = _lcm(p, cfg.cross_attn_every)
    if cfg.moe is not None:
        p = _lcm(p, cfg.moe.moe_every)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return p


def n_super(cfg: ModelConfig) -> int:
    return cfg.n_layers // period(cfg)


def sublayer_kind(cfg: ModelConfig, pos: int) -> dict:
    return dict(
        mixer="attn" if cfg.is_attn_layer(pos) else "mamba",
        # every audio (whisper) decoder layer cross-attends to the encoder
        cross=cfg.is_cross_attn_layer(pos) or cfg.family == "audio",
        mlp=("moe" if cfg.is_moe_layer(pos) else
             ("dense" if cfg.d_ff else None)),
    )


def cross_len(cfg: ModelConfig) -> int:
    return (cfg.n_image_tokens if cfg.family == "vlm"
            else cfg.max_source_positions)


def _cdtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.compute_dtype]


def _pdtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    return min(cfg.sliding_window, max_seq) if cfg.sliding_window else max_seq


# ---------------------------------------------------------------------------
# attention sub-module

def attn_init(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.linear_init(ks[0], d, H * hd, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "wk": layers.linear_init(ks[1], d, KV * hd, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "wv": layers.linear_init(ks[2], d, KV * hd, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "wo": layers.linear_init(ks[3], H * hd, d, dtype=dtype),
    }


def _proj_qkv(p, x, kv_src, cfg, cd, norm_scale=None):
    B, S = x.shape[0], x.shape[1]
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    be, ip = cfg.gemm_backend, cfg.pallas_interpret
    cross = kv_src is not None
    # ``norm_scale`` (the ln1 scale, self-attention only): the sublayer
    # hands rmsnorm_normalize'd x here and the scale fuses into each
    # projection's kernel prologue
    q = layers.linear(p["wq"], x, cd,
                      site="xattn.wq" if cross else "attn.wq",
                      backend=be, interpret=ip,
                      norm_scale=norm_scale).reshape(B, S, H, hd)
    src = x if kv_src is None else kv_src
    T = src.shape[1]
    # the planner fuses cross-attention K/V into one "xattn.kv" GEMM
    k = layers.linear(p["wk"], src, cd,
                      site="xattn.kv" if cross else "attn.wk",
                      backend=be, interpret=ip,
                      norm_scale=norm_scale).reshape(B, T, KV, hd)
    v = layers.linear(p["wv"], src, cd,
                      site="xattn.kv" if cross else "attn.wv",
                      backend=be, interpret=ip,
                      norm_scale=norm_scale).reshape(B, T, KV, hd)
    return q, k, v


def attn_full(p, x, cfg: ModelConfig, positions, *, causal=True,
              kv_src=None, norm_scale=None):
    """Train/prefill attention.  Returns (out, (k, v)) with rope'd keys."""
    cd = _cdtype(cfg)
    q, k, v = _proj_qkv(p, x, kv_src, cfg, cd, norm_scale)
    if kv_src is None:                     # self-attention -> RoPE
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = attn_lib.attention(
        q, k, v, causal=causal, window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        dense_below=cfg.attn_dense_below, backend=cfg.gemm_backend,
        interpret=cfg.pallas_interpret)
    B, S = x.shape[0], x.shape[1]
    out = layers.linear(p["wo"], out.reshape(B, S, -1), cd,
                        site="xattn.wo" if kv_src is not None else "attn.wo",
                        backend=cfg.gemm_backend,
                        interpret=cfg.pallas_interpret)
    return out, (k, v)


def attn_decode(p, x, cfg: ModelConfig, cache, pos, norm_scale=None):
    """Single-token attention.  x: (B,1,d); cache: {'k','v'} ring buffers.

    pos may be a scalar (fused fleet decode; cheap dynamic-update-slice) or
    a (B,) vector (ragged continuous batching; masked per-row write).
    """
    cd = _cdtype(cfg)
    q, k_new, v_new = _proj_qkv(p, x, None, cfg, cd, norm_scale)
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos, (B,))[:, None]
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k_new = layers.apply_rope(k_new, positions, cfg.rope_theta)
    cl = cache["k"].shape[1]
    if pos.ndim == 0:
        slot = (pos % cl) if cfg.sliding_window else jnp.minimum(pos, cl - 1)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    else:
        slot = (pos % cl) if cfg.sliding_window else jnp.minimum(pos, cl - 1)
        hit = (jnp.arange(cl)[None, :] == slot[:, None])[:, :, None, None]
        k_cache = jnp.where(hit, k_new.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(hit, v_new.astype(cache["v"].dtype), cache["v"])
    out = attn_lib.decode_attention(q, k_cache, v_cache, pos,
                                    window=cfg.sliding_window,
                                    backend=cfg.gemm_backend,
                                    interpret=cfg.pallas_interpret)
    out = layers.linear(p["wo"], out.reshape(B, 1, -1), cd, site="attn.wo",
                        backend=cfg.gemm_backend,
                        interpret=cfg.pallas_interpret)
    return out, {"k": k_cache, "v": v_cache}


def attn_prefill(p, x, cfg: ModelConfig, cache, pos, lengths,
                 norm_scale=None):
    """Chunked-prefill attention.  x: (B,C,d) — a chunk of C prompt tokens
    per row starting at absolute position ``pos`` (B,); ``lengths`` (B,) is
    the number of valid tokens in each row's chunk (0 = row not prefilled
    this call: its cache bits are left untouched).

    K/V for the valid (row, position) pairs are written into the cache by a
    masked gather-select (no arithmetic on cache values), then every query
    attends over the full cache buffer with a ``key_pos <= q_pos`` mask.
    The numerics deliberately mirror ``attn_decode``/``decode_attention``
    step for step — same cache-dtype readback, same fp32 score/softmax,
    same einsum contractions — so a chunked prefill reproduces the
    token-by-token decode path bit for bit.
    """
    cd = _cdtype(cfg)
    q, k_new, v_new = _proj_qkv(p, x, None, cfg, cd, norm_scale)
    B, C = x.shape[0], x.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    positions = pos[:, None] + jnp.arange(C)[None, :]          # (B,C)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k_new = layers.apply_rope(k_new, positions, cfg.rope_theta)
    cl = cache["k"].shape[1]
    # masked scatter: cache slot j takes chunk element j - pos[b] when that
    # index is a valid token of this chunk, else keeps its current value.
    j = jnp.arange(cl)[None, :]                                # (1,cl)
    src = j - pos[:, None]                                     # (B,cl)
    ok = (src >= 0) & (src < lengths[:, None])
    idx = jnp.clip(src, 0, C - 1)[:, :, None, None]
    k_cache = jnp.where(
        ok[:, :, None, None],
        jnp.take_along_axis(k_new.astype(cache["k"].dtype), idx, axis=1),
        cache["k"])
    v_cache = jnp.where(
        ok[:, :, None, None],
        jnp.take_along_axis(v_new.astype(cache["v"].dtype), idx, axis=1),
        cache["v"])
    # causal attention of the C queries against the full (masked) buffer;
    # QK/PV dispatch through the substrate (attn.qk / attn.pv) exactly as
    # attn_lib.decode_attention does, preserving bit-for-bit prefill/decode
    # equivalence
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = H // KV
    qg = q.reshape(B, C, KV, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = attn_lib.qk_scores(qg, k_cache, backend=cfg.gemm_backend,
                           interpret=cfg.pallas_interpret) * scale
    valid = j[:, None, :] <= positions[:, :, None]             # (B,C,cl)
    s = jnp.where(valid[:, None, None], s, attn_lib.NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v_cache.dtype)
    out = attn_lib.pv_mix(w, v_cache, backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret)
    out = out.reshape(B, C, H, hd).astype(q.dtype)
    out = layers.linear(p["wo"], out.reshape(B, C, -1), cd, site="attn.wo",
                        backend=cfg.gemm_backend,
                        interpret=cfg.pallas_interpret)
    return out, {"k": k_cache, "v": v_cache}


def attn_decode_paged(p, x, cfg: ModelConfig, cache, pos, block_tables,
                      norm_scale=None):
    """Single-token attention against the paged K/V pool.

    cache: {'kp','vp'} physical pools (n_pages, page, KV, hd);
    block_tables: (B, n_pg) int32 physical page ids per sequence.  The
    pool is gathered into the (B, L = n_pg*page, KV, hd) logical view —
    L equals the dense cache length by the engine's page|max_seq
    contract — then the write, mask, softmax and QK/PV dispatches are
    *identical* to the dense ``attn_decode`` vector-pos path, which is
    what keeps paged and dense greedy streams bit-identical.  Only the
    written page scatters back: the engine's sharing invariant puts every
    write position in a uniquely-owned page (aliased scratch rows collide
    on page 0, which live rows never attend).
    """
    cd = _cdtype(cfg)
    q, k_new, v_new = _proj_qkv(p, x, None, cfg, cd, norm_scale)
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos, (B,))[:, None]
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k_new = layers.apply_rope(k_new, positions, cfg.rope_theta)
    bt = jnp.asarray(block_tables, jnp.int32)
    n_pg, page = bt.shape[1], cache["kp"].shape[1]
    k_view = attn_lib.gather_pages(cache["kp"], bt)
    v_view = attn_lib.gather_pages(cache["vp"], bt)
    L = n_pg * page
    slot = jnp.minimum(jnp.broadcast_to(pos, (B,)), L - 1)
    hit = (jnp.arange(L)[None, :] == slot[:, None])[:, :, None, None]
    k_view = jnp.where(hit, k_new.astype(cache["kp"].dtype), k_view)
    v_view = jnp.where(hit, v_new.astype(cache["vp"].dtype), v_view)
    out = attn_lib.decode_attention(q, k_view, v_view, pos, window=0,
                                    backend=cfg.gemm_backend,
                                    interpret=cfg.pallas_interpret)
    pg_idx = slot // page
    phys = jnp.take_along_axis(bt, pg_idx[:, None], axis=1)[:, 0]
    KV, hd = k_view.shape[2], k_view.shape[3]
    sel = jnp.broadcast_to(pg_idx[:, None, None, None, None],
                           (B, 1, page, KV, hd))
    kpage = jnp.take_along_axis(
        k_view.reshape(B, n_pg, page, KV, hd), sel, axis=1)[:, 0]
    vpage = jnp.take_along_axis(
        v_view.reshape(B, n_pg, page, KV, hd), sel, axis=1)[:, 0]
    kp = cache["kp"].at[phys].set(kpage)
    vp = cache["vp"].at[phys].set(vpage)
    out = layers.linear(p["wo"], out.reshape(B, 1, -1), cd, site="attn.wo",
                        backend=cfg.gemm_backend,
                        interpret=cfg.pallas_interpret)
    return out, {"kp": kp, "vp": vp}


def attn_prefill_paged(p, x, cfg: ModelConfig, cache, pos, lengths,
                       block_tables, norm_scale=None):
    """Chunked-prefill attention against the paged K/V pool.

    The logical view is gathered exactly as in :func:`attn_decode_paged`;
    the masked chunk scatter, causal mask and QK/PV dispatches then
    mirror the dense ``attn_prefill`` step for step.  The whole view
    scatters back (a chunk may span pages): rows alias only pages whose
    gathered bytes they did not modify — shared prefix pages (writes
    start at the page-aligned divergence point) and the scratch page —
    so every duplicate scatter carries identical values.
    """
    cd = _cdtype(cfg)
    q, k_new, v_new = _proj_qkv(p, x, None, cfg, cd, norm_scale)
    B, C = x.shape[0], x.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    positions = pos[:, None] + jnp.arange(C)[None, :]          # (B,C)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k_new = layers.apply_rope(k_new, positions, cfg.rope_theta)
    bt = jnp.asarray(block_tables, jnp.int32)
    n_pg, page = bt.shape[1], cache["kp"].shape[1]
    k_view = attn_lib.gather_pages(cache["kp"], bt)
    v_view = attn_lib.gather_pages(cache["vp"], bt)
    L = n_pg * page
    j = jnp.arange(L)[None, :]                                 # (1,L)
    src = j - pos[:, None]                                     # (B,L)
    ok = (src >= 0) & (src < lengths[:, None])
    idx = jnp.clip(src, 0, C - 1)[:, :, None, None]
    k_view = jnp.where(
        ok[:, :, None, None],
        jnp.take_along_axis(k_new.astype(cache["kp"].dtype), idx, axis=1),
        k_view)
    v_view = jnp.where(
        ok[:, :, None, None],
        jnp.take_along_axis(v_new.astype(cache["vp"].dtype), idx, axis=1),
        v_view)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = H // KV
    qg = q.reshape(B, C, KV, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = attn_lib.qk_scores(qg, k_view, backend=cfg.gemm_backend,
                           interpret=cfg.pallas_interpret) * scale
    valid = j[:, None, :] <= positions[:, :, None]             # (B,C,L)
    s = jnp.where(valid[:, None, None], s, attn_lib.NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v_view.dtype)
    out = attn_lib.pv_mix(w, v_view, backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret)
    out = out.reshape(B, C, H, hd).astype(q.dtype)
    kp = attn_lib.scatter_pages(cache["kp"], bt, k_view)
    vp = attn_lib.scatter_pages(cache["vp"], bt, v_view)
    out = layers.linear(p["wo"], out.reshape(B, C, -1), cd, site="attn.wo",
                        backend=cfg.gemm_backend,
                        interpret=cfg.pallas_interpret)
    return out, {"kp": kp, "vp": vp}


def cross_attn_decode(p, x, cfg: ModelConfig, cache):
    """Cross-attention against precomputed (xk, xv)."""
    cd = _cdtype(cfg)
    B = x.shape[0]
    hd, H = cfg.resolved_head_dim, cfg.n_heads
    q = layers.linear(p["wq"], x, cd, site="xattn.wq",
                      backend=cfg.gemm_backend,
                      interpret=cfg.pallas_interpret).reshape(B, 1, H, hd)
    out = attn_lib.dense_attention(q, cache["xk"].astype(cd),
                                   cache["xv"].astype(cd), causal=False,
                                   backend=cfg.gemm_backend,
                                   interpret=cfg.pallas_interpret)
    return layers.linear(p["wo"], out.reshape(B, 1, -1), cd, site="xattn.wo",
                         backend=cfg.gemm_backend,
                         interpret=cfg.pallas_interpret)


# ---------------------------------------------------------------------------
# sub-layer (one transformer/mamba layer)

def sublayer_init(key, cfg: ModelConfig, pos: int):
    kind = sublayer_kind(cfg, pos)
    dtype = _pdtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {"ln1": layers.rmsnorm_init(d, dtype)}
    if kind["mixer"] == "attn":
        p["attn"] = attn_init(ks[0], cfg, dtype)
    else:
        p["mamba"] = mamba_lib.mamba_init(ks[0], d, cfg.ssm or SSMConfig(),
                                          dtype)
    if kind["cross"]:
        p["lnx"] = layers.rmsnorm_init(d, dtype)
        p["xattn"] = attn_init(ks[1], cfg, dtype)
    if kind["mlp"] == "dense":
        p["ln2"] = layers.rmsnorm_init(d, dtype)
        p["mlp"] = layers.swiglu_init(ks[2], d, cfg.d_ff, dtype)
    elif kind["mlp"] == "moe":
        m = cfg.moe
        p["ln2"] = layers.rmsnorm_init(d, dtype)
        p["moe"] = moe_lib.moe_init(ks[3], d, m.expert_d_ff or cfg.d_ff,
                                    m.num_experts,
                                    num_shared=m.num_shared_experts,
                                    dtype=dtype)
    return p


def sublayer_full(p, cfg: ModelConfig, pos: int, x, aux, positions, ctx):
    """Full-sequence sub-layer.  Returns (x, aux, cache_entry)."""
    kind = sublayer_kind(cfg, pos)
    cache = {}
    if kind["mixer"] == "attn":
        # ln1 scale fuses into the q/k/v projection prologues
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        out, (k, v) = attn_full(p["attn"], h, cfg, positions,
                                norm_scale=p["ln1"]["scale"])
        cl = cache_len(cfg, k.shape[1])
        S = k.shape[1]
        k_c, v_c = k[:, S - cl:], v[:, S - cl:]
        if cfg.sliding_window and cl > 1:
            shift = S % cl
            k_c = jnp.roll(k_c, shift, axis=1)
            v_c = jnp.roll(v_c, shift, axis=1)
        cache = {"k": k_c.astype(jnp.bfloat16), "v": v_c.astype(jnp.bfloat16)}
    else:
        h = layers.rmsnorm(p["ln1"], x, cfg.rms_eps)
        out, state, conv = mamba_lib.mamba_forward(
            p["mamba"], h, cfg.ssm or SSMConfig(), _cdtype(cfg),
            backend=cfg.gemm_backend,
            interpret=cfg.pallas_interpret)
        cache = {"state": state.astype(jnp.float32),
                 "conv": conv.astype(jnp.bfloat16)}
    x = x + out
    if kind["cross"]:
        h = layers.rmsnorm(p["lnx"], x, cfg.rms_eps)
        out, (xk, xv) = attn_full(p["xattn"], h, cfg, positions,
                                  causal=False, kv_src=ctx)
        cache["xk"] = xk.astype(jnp.bfloat16)
        cache["xv"] = xv.astype(jnp.bfloat16)
        x = x + out
    if kind["mlp"] == "dense":
        # ln2 scale fuses into the dual-GEMM swiglu prologue; the
        # residual join fuses into the mlp.wo store
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        x = layers.swiglu(p["mlp"], h, _cdtype(cfg),
                          backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret, residual=x,
                          norm_scale=p["ln2"]["scale"])
    elif kind["mlp"] == "moe":
        h = layers.rmsnorm(p["ln2"], x, cfg.rms_eps)
        m = cfg.moe
        y, a = moe_lib.moe_apply(p["moe"], h, top_k=m.top_k,
                                 capacity_factor=m.capacity_factor,
                                 groups=0,  # one dispatch group per sequence
                                 compute_dtype=_cdtype(cfg),
                                 aux_loss_weight=m.aux_loss_weight,
                                 backend=cfg.gemm_backend,
                                 interpret=cfg.pallas_interpret)
        x = x + y
        aux = aux + a
    return x, aux, cache


def sublayer_decode(p, cfg: ModelConfig, pos_idx: int, x, cache, pos, ctx):
    """One-token sub-layer.  x: (B,1,d).  Returns (x, new_cache)."""
    kind = sublayer_kind(cfg, pos_idx)
    new_cache = dict(cache)
    if kind["mixer"] == "attn":
        # ln1 scale fuses into the q/k/v projection prologues
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        out, kv = attn_decode(p["attn"], h, cfg, cache, pos,
                              norm_scale=p["ln1"]["scale"])
        new_cache.update(kv)
    else:
        h = layers.rmsnorm(p["ln1"], x, cfg.rms_eps)
        out, state, conv = mamba_lib.mamba_decode_step(
            p["mamba"], h[:, 0], cache["state"], cache["conv"],
            cfg.ssm or SSMConfig(), _cdtype(cfg),
            backend=cfg.gemm_backend,
            interpret=cfg.pallas_interpret)
        out = out[:, None]
        new_cache["state"] = state
        new_cache["conv"] = conv.astype(cache["conv"].dtype)
    x = x + out
    if kind["cross"]:
        h = layers.rmsnorm(p["lnx"], x, cfg.rms_eps)
        x = x + cross_attn_decode(p["xattn"], h, cfg, cache)
    if kind["mlp"] == "dense":
        # ln2 scale fuses into the dual-GEMM swiglu prologue; the
        # residual join fuses into the mlp.wo store
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        x = layers.swiglu(p["mlp"], h, _cdtype(cfg),
                          backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret, residual=x,
                          norm_scale=p["ln2"]["scale"])
    elif kind["mlp"] == "moe":
        h = layers.rmsnorm(p["ln2"], x, cfg.rms_eps)
        m = cfg.moe
        y, _ = moe_lib.moe_apply(p["moe"], h, top_k=m.top_k,
                                 capacity_factor=max(m.capacity_factor, 2.0),
                                 groups=1,  # decode: one global group
                                 compute_dtype=_cdtype(cfg),
                                 aux_loss_weight=0.0,
                                 backend=cfg.gemm_backend,
                                 interpret=cfg.pallas_interpret)
        x = x + y
    return x, new_cache


def sublayer_prefill(p, cfg: ModelConfig, pos_idx: int, x, cache, pos,
                     lengths):
    """Chunk-of-tokens sub-layer.  x: (B,C,d).  Returns (x, new_cache).

    Attention-mixer sub-layers only (``supports_batched_prefill`` gates the
    callers); the residual/MLP arithmetic is row-wise identical to
    ``sublayer_decode``.
    """
    kind = sublayer_kind(cfg, pos_idx)
    assert kind["mixer"] == "attn" and not kind["cross"] \
        and kind["mlp"] != "moe", "use supports_batched_prefill() to gate"
    new_cache = dict(cache)
    h = layers.rmsnorm_normalize(x, cfg.rms_eps)
    out, kv = attn_prefill(p["attn"], h, cfg, cache, pos, lengths,
                           norm_scale=p["ln1"]["scale"])
    new_cache.update(kv)
    x = x + out
    if kind["mlp"] == "dense":
        # ln2 scale fuses into the dual-GEMM swiglu prologue; the
        # residual join fuses into the mlp.wo store
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        x = layers.swiglu(p["mlp"], h, _cdtype(cfg),
                          backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret, residual=x,
                          norm_scale=p["ln2"]["scale"])
    return x, new_cache


# ---------------------------------------------------------------------------
# whole model

def _stacked_init(key, n: int, fn):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key):
    P = period(cfg)
    NS = n_super(cfg)
    dtype = _pdtype(cfg)
    keys = jax.random.split(key, P + 6)
    params = {
        "embed": layers.embedding_init(keys[-1], cfg.padded_vocab,
                                       cfg.d_model, dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "blocks": tuple(
            _stacked_init(keys[i], NS,
                          partial(sublayer_init, cfg=cfg, pos=i))
            for i in range(P)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.linear_init(keys[-2], cfg.d_model,
                                               cfg.padded_vocab, dtype=dtype)
    if cfg.family == "vlm":
        params["img_proj"] = layers.linear_init(keys[-3], cfg.d_frontend,
                                                cfg.d_model, dtype=dtype)
    if cfg.family == "audio":
        params["audio_proj"] = layers.linear_init(keys[-4], cfg.d_frontend,
                                                  cfg.d_model, dtype=dtype)
        params["enc_blocks"] = (_stacked_init(
            keys[-5], cfg.n_encoder_layers,
            partial(_enc_layer_init, cfg=cfg)),)
    return params


def _enc_layer_init(key, cfg: ModelConfig):
    dtype = _pdtype(cfg)
    ks = jax.random.split(key, 2)
    return {"ln1": layers.rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_init(ks[0], cfg, dtype),
            "ln2": layers.rmsnorm_init(cfg.d_model, dtype),
            "mlp": layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)}


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _encode_audio(cfg, params, frames):
    cd = _cdtype(cfg)
    x = layers.linear(params["audio_proj"], frames, cd,
                      site="frontend.audio", backend=cfg.gemm_backend,
                                             interpret=cfg.pallas_interpret)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, p):
        x = carry
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        out, _ = attn_full(p["attn"], h, cfg, positions, causal=False,
                           norm_scale=p["ln1"]["scale"])
        x = x + out
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        x = layers.swiglu(p["mlp"], h, cd, backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret, residual=x,
                          norm_scale=p["ln2"]["scale"])
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["enc_blocks"][0])
    return x


def _logits(cfg, params, x, cd):
    """fp32 logits via the substrate (site "unembed", tied or untied)."""
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x, backend=cfg.gemm_backend,
                                                  interpret=cfg.pallas_interpret)
    return layers.linear(params["lm_head"], x, cd, site="unembed",
                         backend=cfg.gemm_backend,
                         interpret=cfg.pallas_interpret).astype(jnp.float32)


def _context(cfg, params, batch):
    if cfg.family == "vlm":
        return layers.linear(params["img_proj"],
                             batch["image_embeds"].astype(_cdtype(cfg)),
                             _cdtype(cfg), site="frontend.img",
                             backend=cfg.gemm_backend,
                             interpret=cfg.pallas_interpret)
    if cfg.family == "audio":
        return _encode_audio(cfg, params, batch["frames"])
    return None


def forward(cfg: ModelConfig, params, batch, *, return_cache=False):
    """Returns (logits, aux_loss, cache-or-None).  batch['tokens']: (B,S).

    Activates cfg's GEMM-dispatch mesh (``mesh_shape``) for the trace, so
    every substrate dispatch below derives its per-site shard context and
    the planner sees post-partition shapes.
    """
    substrate.check_backend(cfg.gemm_backend)
    with sharding.gemm_mesh_scope(cfg):
        return _forward(cfg, params, batch, return_cache=return_cache)


def _forward(cfg: ModelConfig, params, batch, *, return_cache=False):
    P = period(cfg)
    cd = _cdtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = constrain(layers.embed(params["embed"], tokens, cd), "hidden")
    positions = jnp.arange(S)[None, :]
    ctx = _context(cfg, params, batch)

    def body(carry, p_block):
        x, aux = carry
        caches = []
        for i in range(P):
            x, aux, c = sublayer_full(p_block[i], cfg, i, x, aux,
                                      positions, ctx)
            x = constrain(x, "hidden")
            caches.append(c)
        return (x, aux), tuple(caches) if return_cache else None

    (x, aux), caches = jax.lax.scan(_remat(cfg, body), (x, jnp.float32(0.0)),
                                    params["blocks"])
    x = layers.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = _logits(cfg, params, x, cd)
    return constrain(logits, "logits"), aux, caches


def loss_fn(cfg: ModelConfig, params, batch):
    logits, aux, _ = forward(cfg, params, batch)
    loss = layers.softmax_xent(logits, batch["labels"],
                               batch.get("loss_mask"))
    return loss + aux, {"xent": loss, "aux": aux}


def prefill(cfg: ModelConfig, params, batch):
    """Returns (last-token logits (B,V), cache pytree)."""
    logits, _, caches = forward(cfg, params, batch, return_cache=True)
    return logits[:, -1], caches


def decode_step(cfg: ModelConfig, params, cache, token, pos, ctx=None):
    """token: (B,) int32; pos: scalar int32.  Returns (logits (B,V), cache).

    Activates cfg's GEMM-dispatch mesh (``mesh_shape``), like ``forward``.
    """
    substrate.check_backend(cfg.gemm_backend)
    with sharding.gemm_mesh_scope(cfg):
        return _decode_step(cfg, params, cache, token, pos, ctx)


def _decode_step(cfg: ModelConfig, params, cache, token, pos, ctx=None):
    P = period(cfg)
    cd = _cdtype(cfg)
    x = layers.embed(params["embed"], token[:, None], cd)

    def body(x, xs):
        p_block, cache_block = xs
        new_caches = []
        for i in range(P):
            x, nc = sublayer_decode(p_block[i], cfg, i, x, cache_block[i],
                                    pos, ctx)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = _logits(cfg, params, x, cd)
    return constrain(logits, "logits")[:, 0], new_cache


def supports_batched_prefill(cfg: ModelConfig) -> bool:
    """True when ``prefill_step`` reproduces the decode path bit-for-bit.

    Requires every sub-layer to be a plain causal-attention + dense-MLP
    block with a linear (non-ring) KV cache: mamba state recurrences,
    cross-attention contexts, MoE capacity routing (whose token dropping
    depends on how many tokens share a dispatch) and sliding-window ring
    buffers all break per-row equivalence with single-token decoding.
    """
    if cfg.sliding_window or cfg.family in ("vlm", "audio"):
        return False
    return all(
        k["mixer"] == "attn" and not k["cross"] and k["mlp"] != "moe"
        for k in (sublayer_kind(cfg, i) for i in range(period(cfg))))


def prefill_step(cfg: ModelConfig, params, cache, tokens, pos, lengths):
    """Batched chunked prefill: one jit dispatch for a (B,C) token chunk.

    tokens: (B,C) int32, right-padded; pos: (B,) absolute start position of
    each row's chunk; lengths: (B,) valid tokens per row (0 = row inactive —
    its cache is untouched, fixing the garbage K/V writes the per-token
    prefill path inflicted on co-resident slots).  Returns
    ``(logits (B,V) at each row's last valid chunk token, new_cache)``;
    logits rows with ``lengths == 0`` are meaningless.

    Activates cfg's GEMM-dispatch mesh (``mesh_shape``), like ``forward``.
    """
    substrate.check_backend(cfg.gemm_backend)
    with sharding.gemm_mesh_scope(cfg):
        return _prefill_step(cfg, params, cache, tokens, pos, lengths)


def _prefill_step(cfg: ModelConfig, params, cache, tokens, pos, lengths):
    P = period(cfg)
    cd = _cdtype(cfg)
    tokens = jnp.asarray(tokens, jnp.int32)
    C = tokens.shape[1]
    x = layers.embed(params["embed"], tokens, cd)

    def body(x, xs):
        p_block, cache_block = xs
        new_caches = []
        for i in range(P):
            x, nc = sublayer_prefill(p_block[i], cfg, i, x, cache_block[i],
                                     pos, lengths)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    last = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, C - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)    # (B,1,d)
    logits = _logits(cfg, params, x, cd)
    return constrain(logits, "logits")[:, 0], new_cache


# ---------------------------------------------------------------------------
# pipeline-sharded serving steps (GPipe stages over the 'pod' mesh axis)

def supports_pipeline(cfg: ModelConfig) -> bool:
    """True when the pp step functions reproduce the dense path bit for
    bit: plain causal-attention + dense-MLP stack (the batched-prefill
    gate) whose ``n_super`` super-blocks split evenly over the stages."""
    pp = cfg.pp_stages
    return (pp > 1 and len(cfg.mesh_shape) == 3
            and cfg.mesh_shape[0] == pp and n_super(cfg) % pp == 0
            and supports_batched_prefill(cfg))


def _check_pp(cfg: ModelConfig):
    if not supports_pipeline(cfg):
        raise ValueError(
            "pipeline step needs pp_stages > 1, a 3-axis mesh_shape whose "
            "'pod' axis equals pp_stages, n_super %% pp == 0 and a "
            "batched-prefill-capable (dense causal) architecture; got "
            f"pp_stages={cfg.pp_stages} mesh_shape={cfg.mesh_shape} "
            f"n_super={n_super(cfg)} family={cfg.family}")


def _pp_step(cfg: ModelConfig, params, cache, tokens, pos, lengths):
    """Shared driver for the pipeline-sharded decode/prefill step.

    The whole step runs as ONE ``shard_map`` over cfg's (pod, data, model)
    mesh: ``params['blocks']`` and the dense KV cache shard their leading
    ``n_super`` dim over 'pod' (stage s owns the contiguous super-blocks
    ``[s*NS/pp, (s+1)*NS/pp)``), the embedded chunk enters stage 0, and
    ``parallel.pipeline.staged_step`` clocks it through the stages via
    ``collective_permute``.  Each stage scans its local super-blocks with
    the SAME sublayer functions as the colocated path, so the math is
    bit-identical; only the ``attn.wq`` boundary GEMM plans under the
    active role's transfer pricing (sharding.use_pp_pricing), which is how
    prefill pods and decode pods legitimately hold different ``best_k``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel import pipeline as pipe

    Pd = period(cfg)
    cd = _cdtype(cfg)
    mesh = sharding.mesh_from_config(cfg)
    decode = lengths is None
    other = {k: v for k, v in params.items() if k != "blocks"}

    def body(blocks, cache_l, other, tokens, pos, lengths):
        x0 = layers.embed(other["embed"], tokens, cd)

        def stage_fn(x, cache_c):
            def scan_body(x, xs):
                p_block, cache_block = xs
                ncs = []
                for i in range(Pd):
                    if decode:
                        x, nc = sublayer_decode(p_block[i], cfg, i, x,
                                                cache_block[i], pos, None)
                    else:
                        x, nc = sublayer_prefill(p_block[i], cfg, i, x,
                                                 cache_block[i], pos,
                                                 lengths)
                    ncs.append(nc)
                return x, tuple(ncs)
            return jax.lax.scan(scan_body, x, (blocks, cache_c))

        y, new_cache = pipe.staged_step(stage_fn, x0, cache_l,
                                        axis_name="pod")
        x = layers.rmsnorm(other["final_norm"], y, cfg.rms_eps)
        if not decode:
            C = tokens.shape[1]
            last = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, C - 1)
            x = jnp.take_along_axis(x, last[:, None, None], axis=1)
        logits = _logits(cfg, other, x, cd)
        # only the last stage holds real logits; mask + psum broadcasts
        stage = jax.lax.axis_index("pod")
        n_stages = jax.lax.psum(1, "pod")
        logits = jax.lax.psum(
            logits * (stage == n_stages - 1).astype(logits.dtype), "pod")
        return logits[:, 0], new_cache

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("pod"), P("pod"), P(), P(), P(), P()),
                   out_specs=(P(), P("pod")), check_rep=False)
    return fn(params["blocks"], cache, other, tokens, pos, lengths)


def decode_step_pp(cfg: ModelConfig, params, cache, token, pos):
    """Pipeline-sharded twin of :func:`decode_step` (dense cache only).

    token: (B,) int32; pos: scalar or (B,) int32.  Returns
    (logits (B,V), new_cache) bit-identical to :func:`decode_step`."""
    substrate.check_backend(cfg.gemm_backend)
    _check_pp(cfg)
    with sharding.gemm_mesh_scope(cfg):
        return _pp_step(cfg, params, cache, token[:, None], pos, None)


def prefill_step_pp(cfg: ModelConfig, params, cache, tokens, pos, lengths):
    """Pipeline-sharded twin of :func:`prefill_step` (dense cache only)."""
    substrate.check_backend(cfg.gemm_backend)
    _check_pp(cfg)
    with sharding.gemm_mesh_scope(cfg):
        return _pp_step(cfg, params, cache, jnp.asarray(tokens, jnp.int32),
                        pos, lengths)


def supports_paged_kv(cfg: ModelConfig) -> bool:
    """True when the paged serving path reproduces dense decoding bit for
    bit: same gate as :func:`supports_batched_prefill` (pure causal attn +
    dense MLP, linear cache) — mamba state, MoE routing, cross-attention
    and sliding-window rings have no page-gather equivalence."""
    return supports_batched_prefill(cfg)


def _sublayer_decode_paged(p, cfg, pos_idx, x, cache, pos, bt):
    kind = sublayer_kind(cfg, pos_idx)
    assert kind["mixer"] == "attn" and not kind["cross"] \
        and kind["mlp"] != "moe", "use supports_paged_kv() to gate"
    h = layers.rmsnorm_normalize(x, cfg.rms_eps)
    out, new_cache = attn_decode_paged(p["attn"], h, cfg, cache, pos, bt,
                                       norm_scale=p["ln1"]["scale"])
    x = x + out
    if kind["mlp"] == "dense":
        # ln2 scale fuses into the dual-GEMM swiglu prologue; the
        # residual join fuses into the mlp.wo store
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        x = layers.swiglu(p["mlp"], h, _cdtype(cfg),
                          backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret, residual=x,
                          norm_scale=p["ln2"]["scale"])
    return x, new_cache


def _sublayer_prefill_paged(p, cfg, pos_idx, x, cache, pos, lengths, bt):
    kind = sublayer_kind(cfg, pos_idx)
    assert kind["mixer"] == "attn" and not kind["cross"] \
        and kind["mlp"] != "moe", "use supports_paged_kv() to gate"
    h = layers.rmsnorm_normalize(x, cfg.rms_eps)
    out, new_cache = attn_prefill_paged(p["attn"], h, cfg, cache, pos,
                                        lengths, bt,
                                        norm_scale=p["ln1"]["scale"])
    x = x + out
    if kind["mlp"] == "dense":
        # ln2 scale fuses into the dual-GEMM swiglu prologue; the
        # residual join fuses into the mlp.wo store
        h = layers.rmsnorm_normalize(x, cfg.rms_eps)
        x = layers.swiglu(p["mlp"], h, _cdtype(cfg),
                          backend=cfg.gemm_backend,
                          interpret=cfg.pallas_interpret, residual=x,
                          norm_scale=p["ln2"]["scale"])
    return x, new_cache


def decode_step_paged(cfg: ModelConfig, params, cache, token, pos,
                      block_tables):
    """Paged twin of :func:`decode_step`: token (B,), pos (B,),
    block_tables (B, n_pg) int32.  cache is :func:`init_paged_cache`'s
    pytree.  Returns (logits (B,V), new_cache)."""
    substrate.check_backend(cfg.gemm_backend)
    with sharding.gemm_mesh_scope(cfg):
        return _paged_step(cfg, params, cache, token[:, None], pos,
                           None, block_tables)


def prefill_step_paged(cfg: ModelConfig, params, cache, tokens, pos,
                       lengths, block_tables):
    """Paged twin of :func:`prefill_step`: tokens (B,C) right-padded,
    pos/lengths (B,), block_tables (B, n_pg).  Returns (logits at each
    row's last valid chunk token, new_cache)."""
    substrate.check_backend(cfg.gemm_backend)
    with sharding.gemm_mesh_scope(cfg):
        return _paged_step(cfg, params, cache, tokens, pos, lengths,
                           block_tables)


def _paged_step(cfg, params, cache, tokens, pos, lengths, bt):
    P = period(cfg)
    cd = _cdtype(cfg)
    tokens = jnp.asarray(tokens, jnp.int32)
    C = tokens.shape[1]
    x = layers.embed(params["embed"], tokens, cd)

    def body(x, xs):
        p_block, cache_block = xs
        new_caches = []
        for i in range(P):
            if lengths is None:
                x, nc = _sublayer_decode_paged(p_block[i], cfg, i, x,
                                               cache_block[i], pos, bt)
            else:
                x, nc = _sublayer_prefill_paged(p_block[i], cfg, i, x,
                                                cache_block[i], pos,
                                                lengths, bt)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    if lengths is not None:
        last = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, C - 1)
        x = jnp.take_along_axis(x, last[:, None, None], axis=1)  # (B,1,d)
    logits = _logits(cfg, params, x, cd)
    return constrain(logits, "logits")[:, 0], new_cache


# ---------------------------------------------------------------------------
# pre-quantized parameter trees (load-time weight quantization)

def prequantize_params(cfg: ModelConfig, params):
    """Quantize every GEMM weight leaf once, eagerly, at load time.

    Returns a param tree where each weight the quantizing backend would
    quantize in-trace is replaced by a :class:`substrate.QuantizedTensor`
    (int8 codes + fp32 per-output-channel scales).  The dispatch then
    consumes the codes directly — the AF008 in-trace requantize (XLA
    re-running abs/max/round per compiled step) disappears from the
    jaxpr, and the hot path never touches the fp32 master weights.

    Bitwise contract: quantization is applied to the *compute-dtype cast*
    of each weight — exactly the value ``layers.linear`` hands the
    dispatch — and ``_quantize`` is elementwise + an exact (max) reduction,
    so eager codes equal in-trace codes bit for bit and pre-quantized
    streams match in-trace-quantized streams exactly.

    Skipped leaves mirror the dispatch rules: ``moe.router`` weights
    (:data:`substrate.QUANT_EXEMPT_SITES` — routing must stay fp32),
    biases, norms, mamba conv/state tensors, and the embedding lookup
    table (tied embeddings get an extra pre-transposed ``table_q`` leaf
    that ``layers.unembed`` prefers).  No-op (returns ``params``
    unchanged) when ``cfg.gemm_backend`` does not quantize.
    """
    if not substrate.backend_quantizes(cfg.gemm_backend):
        return params
    cd = _cdtype(cfg)

    def q(w):
        return substrate.prequantize(w.astype(cd))

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, (dict, tuple, list)):
                    out[k] = walk(v)
                elif k == "w" and getattr(v, "ndim", 0) >= 2:
                    out[k] = q(v)                      # linear weights
                elif (k in ("wi_gate", "wi_up", "wo")
                      and getattr(v, "ndim", 0) >= 3):
                    out[k] = q(v)                      # MoE expert banks
                else:
                    out[k] = v                         # router/bias/norm/...
            return out
        if isinstance(node, (tuple, list)):
            return type(node)(walk(v) for v in node)
        return node

    out = walk(params)
    if cfg.tie_embeddings:
        # unembed runs table.T as a GEMM weight: pre-transpose + quantize
        t = params["embed"]["table"].astype(cd)
        out["embed"] = dict(out["embed"],
                            table_q=substrate.prequantize(t.T))
    return out


# ---------------------------------------------------------------------------
# cache construction

def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               dtype=jnp.bfloat16):
    """Zero-initialized decode cache matching decode_step's expectations."""
    P = period(cfg)
    NS = n_super(cfg)
    ssm = cfg.ssm or SSMConfig()
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    cl = cache_len(cfg, max_seq)
    d_in = cfg.d_inner
    G, N = ssm.n_groups, ssm.d_state
    hg = (d_in // ssm.head_dim) // G
    conv_ch = d_in + 2 * G * N
    out = []
    for i in range(P):
        kind = sublayer_kind(cfg, i)
        c = {}
        if kind["mixer"] == "attn":
            c["k"] = jnp.zeros((NS, batch_size, cl, KV, hd), dtype)
            c["v"] = jnp.zeros((NS, batch_size, cl, KV, hd), dtype)
        else:
            c["state"] = jnp.zeros((NS, batch_size, G, hg, ssm.head_dim, N),
                                   jnp.float32)
            c["conv"] = jnp.zeros((NS, batch_size, ssm.d_conv - 1, conv_ch),
                                  dtype)
        if kind["cross"]:
            xl = cross_len(cfg)
            c["xk"] = jnp.zeros((NS, batch_size, xl, KV, hd), dtype)
            c["xv"] = jnp.zeros((NS, batch_size, xl, KV, hd), dtype)
        out.append(c)
    return tuple(out)


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16):
    """Zero-initialized paged K/V pools for ``decode_step_paged`` /
    ``prefill_step_paged``: per layer ``{'kp','vp'}`` of shape
    ``(NS, n_pages, page_size, KV, hd)``.  Unlike :func:`init_cache`
    there is no batch dimension — residency is the engine's block tables,
    so K/V memory scales with the page budget, not ``max_batch * max_seq``
    (page 0 is the engine's scratch page)."""
    if not supports_paged_kv(cfg):
        raise ValueError(f"{cfg.name}: family does not support the paged "
                         f"KV path (see supports_paged_kv)")
    P = period(cfg)
    NS = n_super(cfg)
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    return tuple(
        {"kp": jnp.zeros((NS, n_pages, page_size, KV, hd), dtype),
         "vp": jnp.zeros((NS, n_pages, page_size, KV, hd), dtype)}
        for _ in range(P))
