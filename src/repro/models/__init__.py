from repro.models import lm, api  # noqa: F401
