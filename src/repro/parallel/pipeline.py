"""GPipe-style pipeline parallelism over the 'pod' mesh axis (shard_map).

Inter-pod ICI is the thinnest link in a multi-pod deployment, so the 'pod'
axis runs pipeline stages: each pod holds a contiguous block of layers and
microbatch activations flow pod->pod via collective_permute.  The stage
count is planned by the Eq.(6)/(7)-at-cluster-scale math at the bottom of
this module (see DESIGN.md §Beyond).

``gpipe`` is the generic multi-microbatch schedule: fn is one stage's
forward; stage parameters are sharded over `axis_name` (stage i's params
live on shard i).  ``staged_step`` is the single-microbatch serving
schedule the disaggregated engine pipelines decode/prefill steps with —
one activation flows through the stages while each stage commits its own
slice of the KV cache.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(fn, stage_params, x_micro, *, axis_name: str):
    """Run a P-stage pipeline inside shard_map.

    fn: (params_i, x) -> y, same shape.  stage_params: params of THIS shard's
    stage (shard_map has already split the stage dim).  x_micro: (M, mb, d)
    microbatches (replicated input).  Returns (M, mb, d) outputs (valid on
    every shard after the final broadcast).
    """
    n_stages = jax.lax.psum(1, axis_name)
    stage = jax.lax.axis_index(axis_name)
    M = x_micro.shape[0]
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(t, carry):
        outputs, recv = carry
        # stage 0 injects microbatch t (clamped; masked below)
        t_in = jnp.minimum(t, M - 1)
        inject = (stage == 0) & (t < M)
        x_in = jnp.where(inject, x_micro[t_in], recv)
        y = fn(stage_params, x_in)
        # the last stage commits its result at tick t to slot t-(P-1)
        out_slot = t - (n_stages - 1)
        valid = (stage == n_stages - 1) & (out_slot >= 0)
        outputs = jax.lax.cond(
            valid,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y.astype(o.dtype), jnp.maximum(out_slot, 0), 0),
            lambda o: o, outputs)
        recv = jax.lax.ppermute(y, axis_name, perm)
        return outputs, recv

    outputs = jnp.zeros_like(x_micro)
    recv = jnp.zeros_like(x_micro[0])
    outputs, _ = jax.lax.fori_loop(0, M + n_stages - 1, tick,
                                   (outputs, recv))
    # broadcast final outputs from the last stage to every shard
    mask = (stage == n_stages - 1).astype(outputs.dtype)
    return jax.lax.psum(outputs * mask, axis_name)


def make_pipelined(fn, mesh, *, axis_name: str = "pod",
                   stage_param_spec=P("pod"), x_spec=P()):
    """shard_map wrapper: stage params stacked on axis 0 (one per pod).

    `stage_param_spec` is a prefix spec applied to every stage-param leaf.
    """
    from jax.experimental.shard_map import shard_map

    def inner(stage_params, x_micro):
        sp = jax.tree.map(lambda a: a[0], stage_params)  # this shard's stage
        return gpipe(fn, sp, x_micro, axis_name=axis_name)

    return shard_map(inner, mesh=mesh,
                     in_specs=(stage_param_spec, x_spec),
                     out_specs=x_spec, check_rep=False)


def staged_step(fn, x0, state, *, axis_name: str = "pod"):
    """Single-microbatch pipeline step inside shard_map (serving path).

    ``fn(x, state) -> (y, new_state)`` is one stage's layer block over this
    shard's slice of the model; ``x0`` the stage-0 input (the embedded
    token chunk, replicated); ``state`` this shard's cache slice.  Runs
    ``P`` ticks: stage ``s`` computes its real output at tick ``t == s``
    from the activation `collective_permute`d in by stage ``s-1`` at the
    previous tick, and commits its cache slice only on that tick — other
    ticks recompute on placeholder zeros so the loop body traces ONCE (one
    kernel launch per GEMM site regardless of depth, and every stage stays
    in lockstep for the permute).  Returns ``(y_last, state)`` where
    ``y_last`` holds the model output on the LAST stage (zeros elsewhere —
    mask and ``psum`` to broadcast) and ``state`` is the committed cache.
    """
    n_stages = jax.lax.psum(1, axis_name)
    stage = jax.lax.axis_index(axis_name)
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(t, carry):
        recv, st, y_last = carry
        x_in = jnp.where(t == 0, x0, recv)
        y, new_st = fn(x_in, st)
        active = stage == t
        st = jax.tree.map(lambda a, b: jnp.where(active, a, b), new_st, st)
        y_last = jnp.where(active & (stage == n_stages - 1), y, y_last)
        recv = jax.lax.ppermute(y, axis_name, perm)
        return recv, st, y_last

    recv0 = jnp.zeros_like(x0)
    _, state, y_last = jax.lax.fori_loop(
        0, n_stages, tick, (recv0, state, jnp.zeros_like(x0)))
    return y_last, state


# ---------------------------------------------------------------------------
# ArrayFlex-at-cluster-scale: pipeline-depth planning with Eq.(6)/(7).
#
# Beyond-paper extension (DESIGN.md §Beyond): the paper's tradeoff — merge
# pipeline stages to cut cycle count at the cost of a slower clock — recurs
# one level up in pipeline-parallel training across pods:
#
#   collapse k pods into one pipeline stage
#     -> fewer stages  P(k) = P/k          (shorter fill/drain "skew"),
#     -> slower "clock" per stage: stage time grows with the per-stage layer
#        count, exactly T_clock(k) = d_base + k*d_inc with
#        d_base = per-microbatch dispatch/collective overhead and
#        d_inc  = per-pod layer compute time.
#
# GPipe latency for M microbatches on P/k stages:
#   T = (M + P/k - 1) * T_stage(k)   — isomorphic to Eq.(6) with T<-M, R,C<-P.
# Setting dT/dk = 0 reproduces Eq.(7) with the same structure; the discrete
# argmin below picks the deployed stage count.


@dataclass(frozen=True)
class PipelineCost:
    n_pods: int                 # P: pods available (max pipeline stages)
    microbatches: int           # M: per-step microbatches
    layer_time_ms: float        # per-pod layer-block compute time
    overhead_ms: float          # per-microbatch stage overhead (dispatch+p2p)


def stage_time_ms(c: PipelineCost, k: int) -> float:
    """T_clock analogue: time of one collapsed stage (k pods' layers)."""
    return c.overhead_ms + k * c.layer_time_ms


def pipeline_latency_ms(c: PipelineCost, k: int) -> float:
    """Eq.(6) analogue: (M + P/k - 1) * T_stage(k)."""
    stages = max(1, c.n_pods // k)
    return (c.microbatches + stages - 1) * stage_time_ms(c, k)


def k_hat(c: PipelineCost) -> float:
    """Eq.(7) analogue (continuous optimum)."""
    if c.microbatches <= 1:
        return float(c.n_pods)
    return math.sqrt(c.n_pods * c.overhead_ms
                     / ((c.microbatches - 1) * c.layer_time_ms))


def best_collapse(c: PipelineCost) -> int:
    ks = [k for k in range(1, c.n_pods + 1) if c.n_pods % k == 0]
    return min(ks, key=lambda k: pipeline_latency_ms(c, k))


def plan(c: PipelineCost) -> dict:
    k = best_collapse(c)
    base = pipeline_latency_ms(c, 1)
    bestt = pipeline_latency_ms(c, k)
    return {
        "k": k, "k_hat": k_hat(c), "stages": c.n_pods // k,
        "latency_ms": bestt, "latency_ms_k1": base,
        "saving": 1.0 - bestt / base,
        "bubble_fraction": (c.n_pods // k - 1)
        / (c.microbatches + c.n_pods // k - 1),
    }
