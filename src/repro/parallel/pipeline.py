"""GPipe-style pipeline parallelism over the 'pod' mesh axis (shard_map).

Inter-pod ICI is the thinnest link in a multi-pod deployment, so the 'pod'
axis runs pipeline stages: each pod holds a contiguous block of layers and
microbatch activations flow pod->pod via collective_permute.  The stage
count is planned by core.cluster_pipeline — the paper's Eq.(6)/(7) applied
at cluster scale (see DESIGN.md §Beyond).

``gpipe`` is the generic schedule: fn is one stage's forward; stage
parameters are sharded over `axis_name` (stage i's params live on shard i).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(fn, stage_params, x_micro, *, axis_name: str):
    """Run a P-stage pipeline inside shard_map.

    fn: (params_i, x) -> y, same shape.  stage_params: params of THIS shard's
    stage (shard_map has already split the stage dim).  x_micro: (M, mb, d)
    microbatches (replicated input).  Returns (M, mb, d) outputs (valid on
    every shard after the final broadcast).
    """
    n_stages = jax.lax.psum(1, axis_name)
    stage = jax.lax.axis_index(axis_name)
    M = x_micro.shape[0]
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(t, carry):
        outputs, recv = carry
        # stage 0 injects microbatch t (clamped; masked below)
        t_in = jnp.minimum(t, M - 1)
        inject = (stage == 0) & (t < M)
        x_in = jnp.where(inject, x_micro[t_in], recv)
        y = fn(stage_params, x_in)
        # the last stage commits its result at tick t to slot t-(P-1)
        out_slot = t - (n_stages - 1)
        valid = (stage == n_stages - 1) & (out_slot >= 0)
        outputs = jax.lax.cond(
            valid,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y.astype(o.dtype), jnp.maximum(out_slot, 0), 0),
            lambda o: o, outputs)
        recv = jax.lax.ppermute(y, axis_name, perm)
        return outputs, recv

    outputs = jnp.zeros_like(x_micro)
    recv = jnp.zeros_like(x_micro[0])
    outputs, _ = jax.lax.fori_loop(0, M + n_stages - 1, tick,
                                   (outputs, recv))
    # broadcast final outputs from the last stage to every shard
    mask = (stage == n_stages - 1).astype(outputs.dtype)
    return jax.lax.psum(outputs * mask, axis_name)


def make_pipelined(fn, mesh, *, axis_name: str = "pod",
                   stage_param_spec=P("pod"), x_spec=P()):
    """shard_map wrapper: stage params stacked on axis 0 (one per pod).

    `stage_param_spec` is a prefix spec applied to every stage-param leaf.
    """
    from jax.experimental.shard_map import shard_map

    def inner(stage_params, x_micro):
        sp = jax.tree.map(lambda a: a[0], stage_params)  # this shard's stage
        return gpipe(fn, sp, x_micro, axis_name=axis_name)

    return shard_map(inner, mesh=mesh,
                     in_specs=(stage_param_spec, x_spec),
                     out_specs=x_spec, check_rep=False)
