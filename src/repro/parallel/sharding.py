"""Sharding rules: logical param/activation axes -> mesh PartitionSpecs.

Mesh axes:
  ``pod``   — inter-pod axis (multi-pod mesh only); extra data-parallel dim
              in the baseline config, pipeline dim in parallel.pipeline.
  ``data``  — intra-pod FSDP/data axis (batch + parameter 'in' dims).
  ``model`` — tensor-parallel axis (heads / ff / vocab 'out' dims).

Parameters use FSDP-over-'data' + TP-over-'model' (MaxText-style 2D):
every weight matrix shards its contraction dim over 'data' and its output
dim over 'model', so per-chip parameter bytes scale 1/(data*model).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# helpers

def batch_axes(mesh: Mesh):
    """The axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def mesh_axis_size(mesh, name: str) -> int:
    """Size of a mesh axis; 1 when the mesh doesn't have it.  A rule may
    name an axis this mesh lacks (e.g. 'pod' on a single-pod mesh): an
    absent axis means pure replication.  The single source of truth for
    every shard-count computation (``_divisible``, the ShardCtx
    derivations here, and ``kernels.substrate``'s spec signatures)."""
    return int(dict(mesh.shape).get(name, 1))


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    n = int(np.prod([mesh_axis_size(mesh, a) for a in axes]))
    return dim % n == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """Use `axes` for this dim only if it divides evenly, else replicate."""
    return axes if _divisible(dim, mesh, axes) else None


# ---------------------------------------------------------------------------
# parameter rules, keyed by (parent, leaf) path suffix

# (in, out) 2D GEMM weights: in->data (FSDP), out->model (TP)
_IN_OUT = {"wq", "wk", "wv", "wi_gate", "wi_up", "wi", "z_proj", "xbc_proj",
           "img_proj", "audio_proj"}
# (in, out) with in->model (TP reduce), out->data
_OUT_IN = {"wo", "out_proj"}
# module-level toggle set by param_pspec_tree per call (E % tp == 0)
_MOE_EP = False


def _param_spec_parts(path_names, leaf) -> tuple:
    """PartitionSpec entries for the *trailing* (un-stacked) dims of a leaf."""
    names = [str(n) for n in path_names]
    parent = names[-2] if len(names) >= 2 else ""
    name = names[-1]
    nd = leaf.ndim
    if name == "table":                        # embedding (vocab, d)
        return ("model", "data")
    if name == "b":
        return (_spec_bias(parent),)
    if parent in _IN_OUT and name == "w":
        return ("data", "model")
    if parent in _OUT_IN and name == "w":
        return ("model", "data")
    if parent == "dt_proj" and name == "w":
        return ("data", "model")
    if parent == "lm_head" and name == "w":
        return ("data", "model")
    if name == "router":
        return ("data", None)
    # MoE expert banks are leaves named wi_*/wo under "moe": trailing dims
    # are (E, d, ff) / (E, ff, d); any leading scan-stack dim pads with None.
    # Expert-parallel (E over 'model') when E divides the TP degree —
    # removes the per-expert full-weight gather/grad buffers; falls back to
    # tensor-parallel ff sharding otherwise (param_pspec_tree drops
    # non-dividing axes, so the TP entry survives as the fallback).
    if name in ("wi_gate", "wi_up") and parent == "moe":
        return ("model", "data", None) if _MOE_EP else (None, "data", "model")
    if name == "wo" and parent == "moe":
        return ("model", None, "data") if _MOE_EP else (None, "model", "data")
    if name == "conv_w":
        return (None, "model")
    if name == "conv_b":
        return ("model",)
    return (None,) * nd


def _spec_bias(parent: str):
    if parent in _IN_OUT or parent == "dt_proj" or parent == "lm_head":
        return "model"
    return None


def param_pspec_tree(params, mesh: Mesh, stacked_prefixes=("blocks",
                                                           "enc_blocks"),
                     moe_experts: int = 0):
    """PartitionSpec pytree for a parameter pytree.

    Leaves under a stacked prefix (scan-over-layers stacking) get a leading
    ``None`` for the layer dim.  Any axis that does not divide evenly by its
    mesh axes falls back to replication — configs pad vocab so the big
    tables always shard.  moe_experts (when the model has MoE layers)
    selects expert-parallel vs tensor-parallel expert sharding.
    """
    global _MOE_EP
    _MOE_EP = bool(moe_experts) and _divisible(moe_experts, mesh, "model")

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", p)) for p in path]
        names = [str(n) for n in names]
        stacked = any(n in stacked_prefixes for n in names)
        parts = list(_param_spec_parts(names, leaf))
        offset = leaf.ndim - len(parts)
        if offset < 0:
            parts = parts[-leaf.ndim:] if leaf.ndim else []
            offset = 0
        full = [None] * offset + parts
        if stacked and full and full[0] is None:
            pass  # leading stack dim already None
        # drop shardings that don't divide
        for i, ax in enumerate(full):
            if ax is not None and not _divisible(leaf.shape[i], mesh, ax):
                full[i] = None
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def named(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation / input / cache specs

def token_pspec(mesh: Mesh, global_batch: int):
    ax = batch_axes(mesh)
    return P(_maybe(global_batch, mesh, ax), None)


def activation_pspec(mesh: Mesh, global_batch: int):
    ax = batch_axes(mesh)
    return P(_maybe(global_batch, mesh, ax), None, None)


def kv_cache_pspec(mesh: Mesh, global_batch: int, cache_len: int,
                   *, stacked: bool = True):
    """(n_super, B, S, KV, hd).  Batch over data axes when divisible, else
    shard the sequence dim over everything (long-context decode)."""
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    if bax is not None and global_batch >= int(np.prod(
            [mesh.shape[a] for a in batch_axes(mesh)])):
        seq = _maybe(cache_len, mesh, "model")
        spec = (bax, seq, None, None)
    else:
        seq = _maybe(cache_len, mesh, all_axes(mesh))
        spec = (None, seq, None, None)
    return P(*((None,) + spec if stacked else spec))


def ssm_state_pspec(mesh: Mesh, global_batch: int, heads_per_group: int,
                    *, stacked: bool = True):
    """(n_super, B, G, hg, P, N)."""
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    hax = _maybe(heads_per_group, mesh, "model")
    spec = (bax, None, hax, None, None)
    return P(*((None,) + spec if stacked else spec))


def conv_state_pspec(mesh: Mesh, global_batch: int, channels: int,
                     *, stacked: bool = True):
    """(n_super, B, K-1, ch)."""
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    cax = _maybe(channels, mesh, "model")
    spec = (bax, None, cax)
    return P(*((None,) + spec if stacked else spec))


# ---------------------------------------------------------------------------
# activation sharding constraints (contextvar-scoped so model code stays
# mesh-agnostic; a no-op outside dry-run/launcher contexts)

_ACT_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "activation_rules", default=None)


def activation_rules(mesh: Mesh, global_batch: int, cfg=None,
                     kind: str = "train"):
    """Default activation constraint set for a (mesh, batch[, model cfg]).

    For full-sequence passes (train/prefill) hidden states are
    sequence-sharded over 'model' between blocks (Megatron-SP): activations
    per chip scale 1/(data*model) and XLA inserts the all-gather /
    reduce-scatter pairs around each TP matmul.  Decode (S=1) keeps hidden
    replicated over 'model'.
    """
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    seq = "model" if kind != "decode" else None
    rules = {
        "hidden": P(bax, seq, None),             # (B, S, d)
        "logits": P(bax, None, "model"),         # (B, S, vocab)
        "micro_batch": P(None, bax, None),       # (n_micro, B/n, S)
        # sequence-sharded attention (Megatron-SP style): q rows shard over
        # 'model', KV replicated across it — robust for any H/KV count
        "attn_qkv": P(bax, None, None, None),          # (B, T, KV, hd) k/v
        "attn_q_seq": P(bax, "model", None, None, None),   # (B,S,KV,g,d)
        "attn_stat_seq": P(bax, "model", None, None),      # (B,S,KV,g)
        "attn_scores_seq": P(bax, None, None, "model", None),  # (B,KV,g,S,T)
    }
    if cfg is not None:
        mdl = "model"
        ssm = getattr(cfg, "ssm", None)
        if ssm is not None:
            H = cfg.ssm_heads
            conv_ch = cfg.d_inner + 2 * ssm.n_groups * ssm.d_state
            rules["mamba_xbc"] = P(bax, None, _maybe(conv_ch, mesh, mdl))
            rules["ssm_x"] = P(bax, None, _maybe(H, mesh, mdl), None)
        if getattr(cfg, "moe", None) is not None:
            E = cfg.moe.num_experts
            if _divisible(E, mesh, mdl):
                # expert-parallel: E over 'model'; dispatch gathers become
                # all-to-alls; per-expert grad buffers are E-sharded
                rules["moe_buf4"] = P(bax, mdl, None, None)
                rules["moe_h4"] = P(bax, mdl, None, None)
            else:
                # TP fallback (E doesn't divide the TP degree, e.g. 8 on 16):
                # (G,E,cap,ff) MUST split ff over 'model' or XLA replicates
                # the whole expert GEMM across the TP axis
                rules["moe_buf4"] = P(bax, None, None, None)
                rules["moe_h4"] = P(bax, None, None, "model")
    return rules


class use_activation_rules:
    def __init__(self, rules):
        self.rules = rules
        self._token = None

    def __enter__(self):
        self._token = _ACT_RULES.set(self.rules)
        return self

    def __exit__(self, *exc):
        _ACT_RULES.reset(self._token)
        return False


def constrain(x, name: str):
    """Apply a named activation constraint if rules are active."""
    rules = _ACT_RULES.get()
    if not rules or name not in rules:
        return x
    spec = rules[name]
    if spec is None:
        return x
    parts = list(spec) + [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*parts[:x.ndim]))


# ---------------------------------------------------------------------------
# SPMD GEMM-dispatch shard contexts (the sharded substrate)
#
# The substrate (kernels.substrate) accepts a ShardCtx per dispatch and runs
# the per-shard GEMM under jax.shard_map, planning on post-partition shapes.
# This section derives those contexts from the same logical rules the
# parameter specs above use: _IN_OUT-style weights are column-parallel
# (output dim over 'model'), _OUT_IN-style row-parallel (contraction over
# 'model' + psum at the collapsed-block boundary), and every site may shard
# its streamed rows over 'data' (FSDP/batch).  The mesh is scoped through a
# contextvar — model code stays mesh-agnostic and the lm entry points
# activate it from ModelConfig.mesh_shape.

_GEMM_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "gemm_mesh", default=None)


class use_gemm_mesh:
    """Activate ``mesh`` for substrate shard-context derivation (``None``
    deactivates).  Scoped like :class:`use_activation_rules`."""

    def __init__(self, mesh):
        self.mesh = mesh
        self._token = None

    def __enter__(self):
        self._token = _GEMM_MESH.set(self.mesh)
        return self

    def __exit__(self, *exc):
        _GEMM_MESH.reset(self._token)
        return False


def active_gemm_mesh():
    return _GEMM_MESH.get()


@functools.lru_cache(maxsize=None)
def _host_mesh(data: int, model: int):
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(data, model, strict=True)


@functools.lru_cache(maxsize=None)
def _pod_mesh(pod: int, data: int, model: int, offset: int):
    """A ('pod', 'data', 'model') mesh over the device window
    ``[offset, offset + pod*data*model)`` — a disaggregated role's slice
    of the host (prefill pods at offset 0, decode pods after them)."""
    devs = jax.devices()
    need = offset + pod * data * model
    if len(devs) < need:
        raise ValueError(
            f"pod mesh (pod={pod}, data={data}, model={model}) at "
            f"pod_offset={offset} needs {need} devices, host has "
            f"{len(devs)}; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={need} to fan out CPU devices")
    window = np.asarray(devs[offset:need]).reshape(pod, data, model)
    return Mesh(window, ("pod", "data", "model"))


def mesh_from_config(cfg):
    """The host mesh ``cfg.mesh_shape`` declares, or None.

    A 2-tuple is the (data, model) host mesh; a 3-tuple is a
    (pod, data, model) role mesh windowed at ``cfg.pod_offset`` — the
    'pod' axis carries GPipe pipeline stages (parallel.pipeline).

    Strict: raises (with the ``XLA_FLAGS`` fan-out hint) when the host has
    fewer devices than the mesh needs — sharded plans for a silently
    clamped mesh would be exactly the planned-vs-executed shape divergence
    this substrate exists to close.  ``gemm_sharding="none"`` keeps
    replicated dispatch regardless of ``mesh_shape``.
    """
    shape = tuple(getattr(cfg, "mesh_shape", ()) or ())
    mode = getattr(cfg, "gemm_sharding", "auto")
    if mode not in ("auto", "none"):
        raise ValueError(f"unknown gemm_sharding {mode!r}; use auto|none")
    if not shape or mode == "none":
        return None
    if len(shape) == 3:
        return _pod_mesh(int(shape[0]), int(shape[1]), int(shape[2]),
                         int(getattr(cfg, "pod_offset", 0)))
    if len(shape) != 2:
        raise ValueError(f"mesh_shape must be (data, model) or "
                         f"(pod, data, model), got {shape}")
    return _host_mesh(int(shape[0]), int(shape[1]))


# ---------------------------------------------------------------------------
# pipeline-stage transfer pricing (disaggregated prefill/decode roles)
#
# When layers pipeline over the 'pod' axis, every stage boundary moves the
# (rows, d_model) activation over ICI.  That cost enters the plan exactly
# the way the TP psum already does — through the shard signature — but
# with a per-role sign: a compute-bound prefill stage hides the send
# behind its deep schedule (an Eq.(5') boundary op per ppermute hop,
# which grows the conventional baseline too and pushes best_k DEEPER),
# while a latency-bound decode stage serializes the ingress in front of
# the systolic schedule (Eq.(6'') extra cycles paid at the k-collapsed
# period, pushing best_k SHALLOWER).  The terms attach to ONE site per
# block — PP_BOUNDARY_SITE, the first GEMM a stage runs per layer — so
# the transfer is priced once, not once per GEMM.

PP_BOUNDARY_SITE = "attn.wq"

_PP_PRICING: contextvars.ContextVar = contextvars.ContextVar(
    "pp_pricing", default=None)


def pp_transfer_terms(role: str, pp_stages: int, rows: int, K: int):
    """(transfer_ops, transfer_cycles) for a role's stage boundary.

    prefill: ``ceil(log2(pp))`` boundary ops — the send pipelines like a
    reduction hop and prices into the per-step period.  decode:
    ``ceil(rows * K / SA_C)`` serialized cycles — the (rows, K)
    activation enters the array at C lanes per cycle before the schedule
    starts.
    """
    if pp_stages <= 1 or not role:
        return (0, 0)
    if role == "prefill":
        return (max(1, math.ceil(math.log2(pp_stages))), 0)
    if role == "decode":
        from repro.kernels.ops import SA_C
        return (0, -(-(rows * K) // SA_C))
    raise ValueError(f"unknown pp_role {role!r}; use prefill|decode")


class use_pp_pricing:
    """Activate per-role pipeline transfer pricing: inside this scope,
    :func:`gemm_shard_ctx` hands the boundary site a pricing-only
    ShardCtx carrying the role's transfer terms.  Inert unless both a
    role and ``pp_stages > 1`` are given."""

    def __init__(self, role: str, pp_stages: int):
        self.value = ((role, int(pp_stages))
                      if role and pp_stages and pp_stages > 1 else None)
        self._token = None

    def __enter__(self):
        self._token = _PP_PRICING.set(self.value)
        return self

    def __exit__(self, *exc):
        _PP_PRICING.reset(self._token)
        return False


def active_pp_pricing():
    return _PP_PRICING.get()


def pricing_shard_ctx(transfer_ops: int = 0, transfer_cycles: int = 0):
    """A pricing-only ShardCtx (``mesh=None``): the plan is keyed and
    priced with the transfer terms — ``best_k`` re-picks under them and
    the plan cache separates the roles — but the dispatch itself executes
    unsharded (the ppermute in parallel.pipeline pays the actual
    transfer, not the GEMM)."""
    from repro.kernels.substrate import ShardCtx
    return ShardCtx(None, P(None, None), P(None, None), P(None, None),
                    transfer_ops=transfer_ops,
                    transfer_cycles=transfer_cycles)


@contextlib.contextmanager
def gemm_mesh_scope(cfg):
    """Mesh + pipeline-pricing scope for a ModelConfig — the lm entry
    points wrap themselves in this, so every consumer (tests, the serving
    engine, benches) gets sharded dispatch and per-role plan objectives
    from config alone."""
    with use_gemm_mesh(mesh_from_config(cfg)), \
         use_pp_pricing(getattr(cfg, "pp_role", ""),
                        getattr(cfg, "pp_stages", 0)):
        yield


# dispatch-site (planner.model_gemms label) -> TP decomposition, mirroring
# the parameter rules: _IN_OUT weights column-parallel, _OUT_IN row-parallel
_COL_SITES = {"attn.wq", "attn.wk", "attn.wv", "xattn.wq", "xattn.kv",
              "mlp.wi_gate", "mlp.wi_up", "mlp.wi",
              "mamba.z", "mamba.xbc", "mamba.dt", "unembed", "lm_head"}
_ROW_SITES = {"attn.wo", "xattn.wo", "mlp.wo", "mamba.out"}


def gemm_shard_ctx(site: str, rows: int, K: int, N_out: int, mesh=None):
    """ShardCtx for a 2-D substrate GEMM dispatched at ``site`` (or None).

    Column-parallel sites shard ``N_out`` over 'model'; row-parallel sites
    shard the contraction ``K`` over 'model' (psum at the collapsed-block
    boundary); every site shards the streamed ``rows`` over 'data'.  Any
    axis that does not divide its dim falls back to replication (the
    :func:`_maybe` rule); all-replicated returns None (unsharded
    dispatch).  A fused label like ``"mlp.wi_gate+mlp.wi_up"`` takes its
    kind from the first component.

    Under an active :class:`use_pp_pricing` scope the boundary site
    (:data:`PP_BOUNDARY_SITE`) instead gets a pricing-only context with
    the role's stage-transfer terms — a role submesh runs data=model=1
    (the pipeline shard_map owns the 'pod' axis), so pp pricing and TP
    sharding never need to merge.
    """
    pp = _PP_PRICING.get()
    if pp is not None and site == PP_BOUNDARY_SITE:
        t_ops, t_cyc = pp_transfer_terms(pp[0], pp[1], rows, K)
        return pricing_shard_ctx(transfer_ops=t_ops,
                                 transfer_cycles=t_cyc)
    mesh = mesh if mesh is not None else _GEMM_MESH.get()
    if mesh is None or not site:
        return None
    from repro.kernels.substrate import ShardCtx
    head = site.split("+")[0]
    kind = ("col" if head in _COL_SITES
            else "row" if head in _ROW_SITES else "rep")
    dsize = mesh_axis_size(mesh, "data")
    dax = "data" if dsize > 1 and rows and rows % dsize == 0 else None
    tp = mesh_axis_size(mesh, "model")
    if kind == "col" and tp > 1 and N_out % tp == 0:
        return ShardCtx(mesh, P(dax, None), P(None, "model"),
                        P(dax, "model"))
    if kind == "row" and tp > 1 and K % tp == 0:
        return ShardCtx(mesh, P(dax, "model"), P("model", None),
                        P(dax, None), reduce_axes=("model",))
    if dax is None:
        return None
    return ShardCtx(mesh, P(dax, None), P(None, None), P(dax, None))


def batched_shard_count(batch: int, dp: int, tp: int) -> int:
    """Shard count of a batched dispatch's leading axis: the
    ('data','model') -> 'model' -> 'data' divisibility chain.  The ONE
    definition shared by :func:`batched_shard_ctx` (runtime dispatch) and
    ``core.planner._postshard`` (analytic table), so the two can never
    drift: both must divide the same runtime batch (B*KV for attention)
    by the same factor."""
    if dp > 1 and tp > 1 and batch % (dp * tp) == 0:
        return dp * tp
    if tp > 1 and batch % tp == 0:
        return tp
    if dp > 1 and batch % dp == 0:
        return dp
    return 1


def batched_shard_ctx(batch: int, mesh=None):
    """ShardCtx splitting the leading batch dim of a batched GEMM (the
    attention QK/PV products' ``B*KV`` head axis) over the mesh: prefers
    the full ('data', 'model') split, then 'model' (TP over heads), then
    'data'.  None when nothing divides.  Batch sharding never changes the
    per-element plan shape — only which device runs which heads."""
    mesh = mesh if mesh is not None else _GEMM_MESH.get()
    if mesh is None:
        return None
    from repro.kernels.substrate import ShardCtx
    d, m = mesh_axis_size(mesh, "data"), mesh_axis_size(mesh, "model")
    s = batched_shard_count(batch, d, m)
    if s == 1:
        return None
    if d > 1 and m > 1 and s == d * m:
        ax = ("data", "model")
    elif m > 1 and s == m:
        ax = "model"
    else:
        ax = "data"
    spec = P(ax, None, None)
    return ShardCtx(mesh, spec, spec, spec)


def expert_shard_ctx(num_experts: int, mesh=None):
    """Expert-parallel ShardCtx for ``substrate.expert_gemm``: the expert
    axis splits over 'model' when E divides the TP degree — the same
    ``E % tp == 0`` condition as the ``_MOE_EP`` parameter toggle — else
    None (replicated dispatch, the TP-fallback expert sharding)."""
    mesh = mesh if mesh is not None else _GEMM_MESH.get()
    if mesh is None:
        return None
    tp = mesh_axis_size(mesh, "model")
    if tp <= 1 or num_experts % tp:
        return None
    from repro.kernels.substrate import ShardCtx
    return ShardCtx(mesh, P(None, "model", None, None),
                    P("model", None, None),
                    P(None, "model", None, None))
