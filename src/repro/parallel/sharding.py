"""Sharding rules: logical param/activation axes -> mesh PartitionSpecs.

Mesh axes:
  ``pod``   — inter-pod axis (multi-pod mesh only); extra data-parallel dim
              in the baseline config, pipeline dim in parallel.pipeline.
  ``data``  — intra-pod FSDP/data axis (batch + parameter 'in' dims).
  ``model`` — tensor-parallel axis (heads / ff / vocab 'out' dims).

Parameters use FSDP-over-'data' + TP-over-'model' (MaxText-style 2D):
every weight matrix shards its contraction dim over 'data' and its output
dim over 'model', so per-chip parameter bytes scale 1/(data*model).
"""
from __future__ import annotations

import contextvars
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# helpers

def batch_axes(mesh: Mesh):
    """The axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % n == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """Use `axes` for this dim only if it divides evenly, else replicate."""
    return axes if _divisible(dim, mesh, axes) else None


# ---------------------------------------------------------------------------
# parameter rules, keyed by (parent, leaf) path suffix

# (in, out) 2D GEMM weights: in->data (FSDP), out->model (TP)
_IN_OUT = {"wq", "wk", "wv", "wi_gate", "wi_up", "wi", "z_proj", "xbc_proj",
           "img_proj", "audio_proj"}
# (in, out) with in->model (TP reduce), out->data
_OUT_IN = {"wo", "out_proj"}
# module-level toggle set by param_pspec_tree per call (E % tp == 0)
_MOE_EP = False


def _param_spec_parts(path_names, leaf) -> tuple:
    """PartitionSpec entries for the *trailing* (un-stacked) dims of a leaf."""
    names = [str(n) for n in path_names]
    parent = names[-2] if len(names) >= 2 else ""
    name = names[-1]
    nd = leaf.ndim
    if name == "table":                        # embedding (vocab, d)
        return ("model", "data")
    if name == "b":
        return (_spec_bias(parent),)
    if parent in _IN_OUT and name == "w":
        return ("data", "model")
    if parent in _OUT_IN and name == "w":
        return ("model", "data")
    if parent == "dt_proj" and name == "w":
        return ("data", "model")
    if parent == "lm_head" and name == "w":
        return ("data", "model")
    if name == "router":
        return ("data", None)
    # MoE expert banks are leaves named wi_*/wo under "moe": trailing dims
    # are (E, d, ff) / (E, ff, d); any leading scan-stack dim pads with None.
    # Expert-parallel (E over 'model') when E divides the TP degree —
    # removes the per-expert full-weight gather/grad buffers; falls back to
    # tensor-parallel ff sharding otherwise (param_pspec_tree drops
    # non-dividing axes, so the TP entry survives as the fallback).
    if name in ("wi_gate", "wi_up") and parent == "moe":
        return ("model", "data", None) if _MOE_EP else (None, "data", "model")
    if name == "wo" and parent == "moe":
        return ("model", None, "data") if _MOE_EP else (None, "model", "data")
    if name == "conv_w":
        return (None, "model")
    if name == "conv_b":
        return ("model",)
    return (None,) * nd


def _spec_bias(parent: str):
    if parent in _IN_OUT or parent == "dt_proj" or parent == "lm_head":
        return "model"
    return None


def param_pspec_tree(params, mesh: Mesh, stacked_prefixes=("blocks",
                                                           "enc_blocks"),
                     moe_experts: int = 0):
    """PartitionSpec pytree for a parameter pytree.

    Leaves under a stacked prefix (scan-over-layers stacking) get a leading
    ``None`` for the layer dim.  Any axis that does not divide evenly by its
    mesh axes falls back to replication — configs pad vocab so the big
    tables always shard.  moe_experts (when the model has MoE layers)
    selects expert-parallel vs tensor-parallel expert sharding.
    """
    global _MOE_EP
    _MOE_EP = bool(moe_experts) and _divisible(moe_experts, mesh, "model")

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", p)) for p in path]
        names = [str(n) for n in names]
        stacked = any(n in stacked_prefixes for n in names)
        parts = list(_param_spec_parts(names, leaf))
        offset = leaf.ndim - len(parts)
        if offset < 0:
            parts = parts[-leaf.ndim:] if leaf.ndim else []
            offset = 0
        full = [None] * offset + parts
        if stacked and full and full[0] is None:
            pass  # leading stack dim already None
        # drop shardings that don't divide
        for i, ax in enumerate(full):
            if ax is not None and not _divisible(leaf.shape[i], mesh, ax):
                full[i] = None
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def named(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation / input / cache specs

def token_pspec(mesh: Mesh, global_batch: int):
    ax = batch_axes(mesh)
    return P(_maybe(global_batch, mesh, ax), None)


def activation_pspec(mesh: Mesh, global_batch: int):
    ax = batch_axes(mesh)
    return P(_maybe(global_batch, mesh, ax), None, None)


def kv_cache_pspec(mesh: Mesh, global_batch: int, cache_len: int,
                   *, stacked: bool = True):
    """(n_super, B, S, KV, hd).  Batch over data axes when divisible, else
    shard the sequence dim over everything (long-context decode)."""
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    if bax is not None and global_batch >= int(np.prod(
            [mesh.shape[a] for a in batch_axes(mesh)])):
        seq = _maybe(cache_len, mesh, "model")
        spec = (bax, seq, None, None)
    else:
        seq = _maybe(cache_len, mesh, all_axes(mesh))
        spec = (None, seq, None, None)
    return P(*((None,) + spec if stacked else spec))


def ssm_state_pspec(mesh: Mesh, global_batch: int, heads_per_group: int,
                    *, stacked: bool = True):
    """(n_super, B, G, hg, P, N)."""
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    hax = _maybe(heads_per_group, mesh, "model")
    spec = (bax, None, hax, None, None)
    return P(*((None,) + spec if stacked else spec))


def conv_state_pspec(mesh: Mesh, global_batch: int, channels: int,
                     *, stacked: bool = True):
    """(n_super, B, K-1, ch)."""
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    cax = _maybe(channels, mesh, "model")
    spec = (bax, None, cax)
    return P(*((None,) + spec if stacked else spec))


# ---------------------------------------------------------------------------
# activation sharding constraints (contextvar-scoped so model code stays
# mesh-agnostic; a no-op outside dry-run/launcher contexts)

_ACT_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "activation_rules", default=None)


def activation_rules(mesh: Mesh, global_batch: int, cfg=None,
                     kind: str = "train"):
    """Default activation constraint set for a (mesh, batch[, model cfg]).

    For full-sequence passes (train/prefill) hidden states are
    sequence-sharded over 'model' between blocks (Megatron-SP): activations
    per chip scale 1/(data*model) and XLA inserts the all-gather /
    reduce-scatter pairs around each TP matmul.  Decode (S=1) keeps hidden
    replicated over 'model'.
    """
    bax = _maybe(global_batch, mesh, batch_axes(mesh))
    seq = "model" if kind != "decode" else None
    rules = {
        "hidden": P(bax, seq, None),             # (B, S, d)
        "logits": P(bax, None, "model"),         # (B, S, vocab)
        "micro_batch": P(None, bax, None),       # (n_micro, B/n, S)
        # sequence-sharded attention (Megatron-SP style): q rows shard over
        # 'model', KV replicated across it — robust for any H/KV count
        "attn_qkv": P(bax, None, None, None),          # (B, T, KV, hd) k/v
        "attn_q_seq": P(bax, "model", None, None, None),   # (B,S,KV,g,d)
        "attn_stat_seq": P(bax, "model", None, None),      # (B,S,KV,g)
        "attn_scores_seq": P(bax, None, None, "model", None),  # (B,KV,g,S,T)
    }
    if cfg is not None:
        mdl = "model"
        ssm = getattr(cfg, "ssm", None)
        if ssm is not None:
            H = cfg.ssm_heads
            conv_ch = cfg.d_inner + 2 * ssm.n_groups * ssm.d_state
            rules["mamba_xbc"] = P(bax, None, _maybe(conv_ch, mesh, mdl))
            rules["ssm_x"] = P(bax, None, _maybe(H, mesh, mdl), None)
        if getattr(cfg, "moe", None) is not None:
            E = cfg.moe.num_experts
            if _divisible(E, mesh, mdl):
                # expert-parallel: E over 'model'; dispatch gathers become
                # all-to-alls; per-expert grad buffers are E-sharded
                rules["moe_buf4"] = P(bax, mdl, None, None)
                rules["moe_h4"] = P(bax, mdl, None, None)
            else:
                # TP fallback (E doesn't divide the TP degree, e.g. 8 on 16):
                # (G,E,cap,ff) MUST split ff over 'model' or XLA replicates
                # the whole expert GEMM across the TP axis
                rules["moe_buf4"] = P(bax, None, None, None)
                rules["moe_h4"] = P(bax, None, None, "model")
    return rules


class use_activation_rules:
    def __init__(self, rules):
        self.rules = rules
        self._token = None

    def __enter__(self):
        self._token = _ACT_RULES.set(self.rules)
        return self

    def __exit__(self, *exc):
        _ACT_RULES.reset(self._token)
        return False


def constrain(x, name: str):
    """Apply a named activation constraint if rules are active."""
    rules = _ACT_RULES.get()
    if not rules or name not in rules:
        return x
    spec = rules[name]
    if spec is None:
        return x
    parts = list(spec) + [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*parts[:x.ndim]))
