"""Int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick for 1000+-node scale: data-parallel gradient
traffic dominates the inter-pod links (the 'pod' axis of the multi-pod
mesh), and int8 quantization cuts it 4x vs fp32 (2x vs bf16).  Per-tensor
symmetric scales; the quantization residual is carried to the next step
(error feedback), which keeps SGD/Adam convergence unbiased in practice
(1-bit Adam / EF-SGD lineage).

Usage inside a train step:
    q, scales, new_err = compress(grads, err)
    q = jax.lax.pmean(q, axis)        # 4x cheaper collective
    grads = decompress(q, scales)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def _compress_leaf(g, e):
    g = g.astype(jnp.float32) + e.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    err = (g - q.astype(jnp.float32) * scale).astype(jnp.bfloat16)
    return q, scale, err


def compress(grads, error):
    """-> (int8 tree, scale tree, new error tree)."""
    out = jax.tree.map(_compress_leaf, grads, error)
    struct = jax.tree.structure(grads)
    q, s, e = jax.tree_util.tree_transpose(
        struct, jax.tree.structure((0, 0, 0)), out)
    return q, s, e


def decompress(q, scales, dtype=jnp.float32):
    return jax.tree.map(
        lambda qq, ss: (qq.astype(jnp.float32) * ss).astype(dtype),
        q, scales)


def compressed_allreduce(grads, error, axis_name: str):
    """Error-feedback int8 all-reduce over `axis_name` (inside shard_map)."""
    q, s, e = compress(grads, error)
    summed = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(
        lambda qq, ss: (qq.astype(jnp.float32) * ss / n), summed, s)
    return mean, e
