"""Batched serving engine: chunked batched prefill + continuous batching.

A fixed pool of ``max_batch`` sequence :class:`Slot`\\ s, each with an
explicit lifecycle::

    FREE --admit--> PREFILL --(chunks exhausted)--> DECODE --EOS/limit--> FREE

*Admission* pops queued requests into free slots.  *Prefill* runs the
prompt (all but its final token) through ``lm.prefill_step`` in fixed-size
chunks — one jit dispatch per chunk covering **every** prefilling slot at
once, writing K/V only for the target rows.  A P-token prompt therefore
costs ``ceil(P/chunk)`` dispatches instead of the P full-batch decode
steps the per-token path paid (and no longer sprays garbage K/V into
co-resident slots).  *Decode* is the seed's fused per-slot-position step:
one dispatch advances every DECODE slot by one token.

Each engine tick interleaves at most one prefill-chunk dispatch with one
decode dispatch, so decode latency stays bounded while long prompts are
admitted (chunked prefill).  The chunk size defaults to
``core.planner.attention_plan`` — the paper's Eq.(6) steps-vs-per-step-cost
tradeoff, applied here to the serving layer: serving is the third consumer
of the collapse-depth planner after the SA timing model and the flash
kernel.

``prefill_mode``:
  * ``"batched"`` — chunked ``lm.prefill_step`` path (requires
    ``lm.supports_batched_prefill(cfg)``).
  * ``"token"``   — the seed's token-by-token decode-path prefill, kept as
    the bit-exact baseline for equivalence tests and benchmarks.
  * ``"auto"``    — batched when the model supports it, else token.

**Paged mode** (``ServeConfig.kv_pages > 0``): the dense per-slot K/V
region is replaced by a global page pool + per-sequence block tables
(``serving/paged.py``) and admission reserves *pages*, not slots —
concurrency is bounded by the memory budget (``kv_pages``) instead of
``max_batch``, which only caps how many sequences share one dispatch (the
engine round-robins resident sequences over the ``max_batch`` rows).  The
page size comes from ``planner.page_plan`` — the same Eq.(6) cost model
that picks the prefill chunk — and must divide ``max_seq`` so the gathered
logical view has the dense cache length: paged greedy streams are
bit-identical to the dense path's.  ``prefix_cache=True`` adds the radix
prefix cache: requests sharing a prompt prefix map their leading block
-table entries to the same physical pages and skip the shared pages'
prefill work entirely.

A quantizing ``cfg.gemm_backend`` is served from a **pre-quantized param
tree** (``lm.prequantize_params``): weights are quantized once at engine
construction, so the jit'd steps consume int8 codes directly instead of
re-running the in-trace quantize (the AF008 path) every step.  A W8A8
backend (``substrate.backend_act_quantizes``) needs nothing extra staged
here: activation tiles are data-dependent, so their int8 codes + per-tile
scales are computed in the kernel prologue on every dispatch — the served
tree is identical to the weight-only backend's, and greedy streams stay
bit-identical run-to-run because the quantize is deterministic.

Sampling: greedy or temperature; logits come back fp32 from the model.
Greedy token streams are bit-identical across prefill modes and across
batch compositions (per-row cache evolution is independent).  Exception:
a W8A8 backend's per-tile activation scales make tile geometry part of
the numerics — which tokens/rows share a quantization tile depends on
prefill chunking and batch composition — so its streams are bit-identical
run-to-run for a fixed serving configuration, not across prefill modes
(same rationale as the documented TP2 re-tiling drift; see
docs/substrate.md W8A8 tolerance policy).

**Resilience** (PR 8 — see docs/resilience.md): every request terminates
with a typed :class:`~repro.serving.errors.Outcome`, counted in
``stats["outcome_*"]``.  The hardened lifecycle adds

* a bounded queue (``max_queue``) with typed overload rejection at
  ``submit`` (:class:`~repro.serving.errors.AdmissionError`),
* per-request TTFT/total deadlines (``ttft_deadline_ms``/``deadline_ms``)
  expired at tick boundaries,
* non-finite-logit detection at sample time with one bounded retry
  (``max_retries``) — a persistent NaN/Inf fails the affected requests
  instead of streaming garbage tokens,
* :class:`~repro.serving.errors.KernelFault` retry at the trace/launch
  boundary (the substrate's ``substrate.dispatch`` chaos point),
* a per-tick heartbeat into :class:`~repro.runtime.fault.HeartbeatMonitor`
  plus a stuck-tick watchdog (``watchdog_ticks``) that deterministically
  fails the head-of-line request instead of spinning forever,
* graceful degradation under ``preempt_policy="youngest"``: pages are
  reserved lazily and on mid-decode pool exhaustion the youngest resident
  sequence is preempted (pages released, request re-queued at the front,
  K/V recomputed on re-admission — through the radix prefix cache when
  warm) rather than deadlocking; preempted streams are bit-identical to
  un-preempted runs by the prefill == decode equivalence contract,
* crash recovery: ``snapshot()``/``ServingEngine.restore`` round-trip the
  full scheduling state (queue, slot/sequence metadata, block tables,
  pool refcounts, radix tree, PRNG key, chaos draw counters, K/V cache)
  so an :class:`~repro.serving.errors.EngineCrash` mid-stream resumes
  with bit-identical continuations.

Fault injection is driven by :mod:`repro.runtime.chaos` (seeded,
deterministic, replayable); ``ServeConfig.chaos`` activates it and the
engine scopes the chaos engine around each tick.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm
from repro.parallel import sharding
from repro.runtime import chaos as chaos_mod
from repro.runtime.fault import HeartbeatMonitor
from repro.serving.errors import (AdmissionError, DeadlineExceeded,
                                  EngineCrash, KernelFault, Outcome,
                                  PagePoolExhausted)
from repro.serving.paged import PagePool, PagedSeq, RadixCache

PREFILL_CHUNK_CHOICES = (16, 32, 64, 128, 256, 512, 1024, 2048)


@dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    out_tokens: list = field(default_factory=list)
    done: bool = False
    ttft_s: Optional[float] = None     # admission -> first generated token
    # --- resilience (PR 8) ----------------------------------------------
    outcome: Optional[str] = None      # Outcome.value once done
    error: str = ""                    # human-readable failure detail
    preemptions: int = 0               # times preempted + re-queued
    t_submit: float = 0.0              # engine clock at submit
    resume_prompt: Optional[list] = None  # prompt + generated, for re-admit


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 256
    eos_id: int = -1           # -1: never stops early
    seed: int = 0
    prefill_mode: str = "auto"  # auto | batched | token
    prefill_chunk: int = 0      # 0 -> planner-chosen (attention_plan)
    # --- paged K/V (0 = dense slot mode) ---------------------------------
    kv_pages: int = 0           # physical pages in the pool (incl. scratch)
    page_size: int = 0          # tokens per page; 0 -> planner.page_plan
    prefix_cache: bool = False  # radix shared-prefix page reuse
    # --- resilience (PR 8) -----------------------------------------------
    max_queue: int = 0          # bounded queue; 0 = unbounded (no shedding)
    deadline_ms: float = 0.0    # total per-request deadline; 0 = off
    ttft_deadline_ms: float = 0.0  # submit -> first token deadline; 0 = off
    max_retries: int = 1        # bounded retry of a faulted/NaN dispatch
    watchdog_ticks: int = 64    # consecutive no-progress ticks before the
    #                             stuck-tick watchdog fires; 0 = off
    snapshot_every_ticks: int = 0  # crash-recovery snapshot cadence; 0 = off
    preempt_policy: str = "none"   # none | youngest (paged lazy reservation)
    chaos: Optional[chaos_mod.ChaosConfig] = None  # fault injection


class Slot:
    """One sequence slot: FREE -> PREFILL -> DECODE -> FREE."""

    FREE, PREFILL, DECODE = "free", "prefill", "decode"

    def __init__(self, index: int):
        self.index = index
        self.state = Slot.FREE
        self.req: Optional[Request] = None
        self.pos = 0              # decode: position of the token in flight
        self.prefill_len = 0      # tokens to prefill (len(prompt) - 1)
        self.prefill_done = 0
        self.next_token = 0
        self.t_admit = 0.0

    @property
    def tokens(self) -> list:
        """The token sequence this residency must make resident: a
        preempted request re-admits with prompt + already-generated tokens
        (recompute-on-re-admission), mirroring the paged path's
        ``_effective_prompt``."""
        return self.req.resume_prompt or self.req.prompt

    def assign(self, req: Request, now: float):
        self.req = req
        self.t_admit = now
        self.prefill_len = len(self.tokens) - 1
        self.prefill_done = 0
        if self.prefill_len == 0:
            self._to_decode()
        else:
            self.state = Slot.PREFILL
            self.pos = 0

    def _to_decode(self):
        self.state = Slot.DECODE
        self.pos = self.prefill_len
        self.next_token = self.tokens[-1]

    def finish_chunk(self, n_tokens: int):
        self.prefill_done += n_tokens
        if self.prefill_done >= self.prefill_len:
            self._to_decode()

    def release(self):
        self.req = None
        self.state = Slot.FREE

    @property
    def write_pos(self) -> int:
        """Next cache position this row writes (where a fused-decode
        dispatch may harmlessly deposit garbage: the row's next real write
        lands on the same position before it is ever attended)."""
        return self.prefill_done if self.state == Slot.PREFILL else self.pos


def _req_state(req: Request) -> dict:
    """Pure-python deep copy of a request for crash-recovery snapshots."""
    return {"prompt": list(req.prompt),
            "max_new_tokens": req.max_new_tokens,
            "temperature": req.temperature, "rid": req.rid,
            "out_tokens": list(req.out_tokens), "done": req.done,
            "ttft_s": req.ttft_s, "outcome": req.outcome,
            "error": req.error, "preemptions": req.preemptions,
            "t_submit": req.t_submit,
            "resume_prompt": (None if req.resume_prompt is None
                              else list(req.resume_prompt))}


def _req_from_state(d: dict) -> Request:
    req = Request(prompt=list(d["prompt"]),
                  max_new_tokens=d["max_new_tokens"],
                  temperature=d["temperature"], rid=d["rid"],
                  out_tokens=list(d["out_tokens"]), done=d["done"],
                  ttft_s=d["ttft_s"])
    req.outcome = d["outcome"]
    req.error = d["error"]
    req.preemptions = d["preemptions"]
    req.t_submit = d["t_submit"]
    req.resume_prompt = (None if d["resume_prompt"] is None
                         else list(d["resume_prompt"]))
    return req


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 *, clock=time.perf_counter):
        # config-resolve-time backend validation: an unknown gemm_backend
        # fails here with the registered list, not deep inside a traced
        # dispatch mid-serve
        substrate.check_backend(cfg.gemm_backend)
        if serve_cfg.preempt_policy not in ("none", "youngest"):
            raise ValueError(
                f"unknown preempt_policy {serve_cfg.preempt_policy!r} "
                f"(known: none, youngest)")
        self.cfg = cfg
        # Quantizing backends serve from a pre-quantized tree: weights
        # quantize ONCE here, never inside the compiled steps (no AF008
        # in-trace requantize; bitwise-identical streams — see
        # lm.prequantize_params).  Non-quantizing backends pass through.
        self.params = (lm.prequantize_params(cfg, params)
                       if substrate.backend_quantizes(cfg.gemm_backend)
                       else params)
        self.sc = serve_cfg
        self.clock = clock
        # SPMD serving: cfg.mesh_shape activates sharded GEMM dispatch
        # inside the jit'd lm steps (the lm entry points scope the mesh
        # themselves).  Resolve the mesh eagerly so a config that needs
        # more devices than the host has fails at engine construction
        # with the XLA_FLAGS hint, not mid-serve.
        self.mesh = sharding.mesh_from_config(cfg)
        B, S = serve_cfg.max_batch, serve_cfg.max_seq
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(serve_cfg.seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))

        mode = serve_cfg.prefill_mode
        if mode == "auto":
            mode = ("batched" if lm.supports_batched_prefill(cfg)
                    else "token")
        if mode == "batched" and not lm.supports_batched_prefill(cfg):
            raise ValueError(
                f"{cfg.name}: model family does not support batched "
                f"prefill (mamba/MoE/cross-attn/sliding-window state); "
                f"use prefill_mode='token' or 'auto'")
        if mode not in ("batched", "token"):
            raise ValueError(f"unknown prefill_mode {mode!r}")
        self.prefill_mode = mode
        # Eq.(6) at the serving layer: steps = ceil(prompt/chunk), per-step
        # cost affine in chunk * cache_len -> attention_plan picks the chunk.
        self.prefill_chunk = serve_cfg.prefill_chunk or min(S, max(
            1, planner.attention_plan(S, S, choices=PREFILL_CHUNK_CHOICES)))
        if mode == "batched":
            self._prefill = jax.jit(
                lambda p, c, t, pos, lens: lm.prefill_step(
                    cfg, p, c, t, pos, lens))

        self.paged = serve_cfg.kv_pages > 0
        if self.paged:
            if not lm.supports_paged_kv(cfg):
                raise ValueError(
                    f"{cfg.name}: model family does not support the paged "
                    f"KV path (see lm.supports_paged_kv); use kv_pages=0")
            if mode != "batched":
                raise ValueError("paged serving requires the batched "
                                 "prefill path (prefill_mode='batched' or "
                                 "'auto' on a supporting family)")
            # Eq.(6) again, applied to page geometry: block-table walk
            # overhead vs trailing-page waste (planner.page_plan).
            page = serve_cfg.page_size or planner.page_plan(S)
            if page <= 0 or S % page:
                raise ValueError(
                    f"page_size={page} must divide max_seq={S}: the "
                    f"gathered view must have the dense cache length "
                    f"(the paged/dense bit-exactness contract)")
            self.page_size = page
            self.pages_per_seq = S // page
            if (serve_cfg.preempt_policy == "none"
                    and serve_cfg.kv_pages < self.pages_per_seq + 1):
                # worst-case reservation needs a full sequence's pages up
                # front; lazy reservation (preempt_policy="youngest") can
                # run a tighter pool and degrade by preempting instead
                raise ValueError(
                    f"kv_pages={serve_cfg.kv_pages}: need at least "
                    f"{self.pages_per_seq + 1} (max_seq/page_size pages "
                    f"for one worst-case sequence + the scratch page), "
                    f"or set preempt_policy='youngest' for lazy "
                    f"reservation over a smaller pool")
            self.pool = PagePool(serve_cfg.kv_pages, page)
            self.radix = (RadixCache(page) if serve_cfg.prefix_cache
                          else None)
            self.cache = lm.init_paged_cache(cfg, serve_cfg.kv_pages, page)
            self.active: List[PagedSeq] = []
            self.slots: List[Slot] = []
            self._rr = 0                  # decode round-robin cursor
            self._decode_paged = jax.jit(
                lambda p, c, t, pos, bt: lm.decode_step_paged(
                    cfg, p, c, t, pos, bt))
            self._prefill_paged = jax.jit(
                lambda p, c, t, pos, lens, bt: lm.prefill_step_paged(
                    cfg, p, c, t, pos, lens, bt))
        else:
            self.cache = lm.init_cache(cfg, B, S)
            self.slots = [Slot(i) for i in range(B)]
            self.active = []
            self._rr = 0

        # --- resilience state (PR 8) ------------------------------------
        self._chaos = (chaos_mod.ChaosEngine(serve_cfg.chaos)
                       if serve_cfg.chaos is not None else None)
        # single-host serving: the engine heartbeats host 0 once per tick;
        # an external supervisor (or test) reads dead_hosts()/stragglers()
        self.monitor = HeartbeatMonitor(1, dead_after_s=60.0)
        self._tick = 0
        self._no_progress = 0
        self._admit_seq = 0        # monotonic admission number (preemption)
        self._admitted = 0
        self._terminated = 0
        self._snapshots: List[dict] = []   # latest crash-recovery snapshot
        self.restored_requests: List[Request] = []  # set by restore()

        self._prefill_launches = 0   # per-trace GEMM launches of one chunk
        self.stats = dict(prefill_dispatches=0, decode_dispatches=0,
                          prefill_tokens=0, decode_tokens=0,
                          prefill_time_s=0.0, decode_time_s=0.0,
                          prefill_gemm_dispatches=0,
                          pages_used_peak=0, concurrency_peak=0,
                          prefix_hit_tokens=0,
                          # resilience counters (flat ints: benches reset
                          # stats wholesale by scalar type)
                          sample_retries=0, kernel_fault_retries=0,
                          preemptions=0, watchdog_fired=0,
                          snapshots_taken=0,
                          **{f"outcome_{o.value}": 0 for o in Outcome})

    def kv_cache_bytes(self) -> int:
        """Resident K/V bytes (pool pages in paged mode, the dense
        (max_batch, max_seq) region otherwise)."""
        return int(sum(leaf.nbytes
                       for leaf in jax.tree_util.tree_leaves(self.cache)))

    # ------------------------------------------------------------- intake
    def _finish(self, req: Request, outcome: Outcome, error: str = ""):
        """Terminate ``req`` with its typed outcome (idempotent)."""
        if req.done and req.outcome is not None:
            return
        req.done = True
        req.outcome = outcome.value
        req.error = error
        self._terminated += 1
        self.stats[f"outcome_{outcome.value}"] += 1

    def submit(self, req: Request):
        if not req.prompt:
            msg = f"request {req.rid}: empty prompt"
            self._finish(req, Outcome.FAILED, msg)
            raise AdmissionError(msg)
        if len(req.prompt) > self.sc.max_seq:
            msg = (f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                   f"exceeds max_seq={self.sc.max_seq} (positions past the "
                   f"cache would be silently dropped)")
            self._finish(req, Outcome.FAILED, msg)
            raise AdmissionError(msg)
        if self.sc.max_queue and len(self.queue) >= self.sc.max_queue:
            # bounded queue: shed load with a typed rejection instead of
            # growing without bound (backpressure the caller can act on)
            msg = (f"request {req.rid}: queue full "
                   f"({len(self.queue)}/{self.sc.max_queue}) — overload, "
                   f"retry later")
            self._finish(req, Outcome.REJECTED_OVERLOAD, msg)
            raise AdmissionError(msg, Outcome.REJECTED_OVERLOAD)
        req.t_submit = self.clock()
        self.queue.append(req)

    def _admit(self):
        if self.paged:
            self._admit_paged()
            return
        now = self.clock()
        for slot in self.slots:
            if slot.state == Slot.FREE and self.queue:
                slot.assign(self.queue.pop(0), now)
                self._admitted += 1

    def _effective_prompt(self, req: Request) -> list:
        """The token sequence this admission must make resident: a
        preempted request re-admits with prompt + already-generated tokens
        (recompute-on-re-admission; prefix-cache hits make it cheap)."""
        return req.resume_prompt if req.resume_prompt else req.prompt

    def _admit_paged(self):
        """Memory-bounded admission: FIFO-pop the queue while the pool can
        reserve each request's page span — worst-case (prompt + max_new,
        clipped to max_seq) under ``preempt_policy="none"``, lazy (prompt
        only, grown page-by-page in decode) under ``"youngest"`` — minus
        whatever the radix prefix cache already holds.  Concurrency is
        whatever the page budget sustains, not ``max_batch``."""
        now = self.clock()
        while self.queue:
            req = self.queue[0]
            eff = self._effective_prompt(req)
            if self.sc.preempt_policy == "youngest":
                # lazy: cover the prompt (prefill writes 0..len-2, first
                # decode write at len-1); decode growth allocates the rest
                need = -(-len(eff) // self.page_size)
            else:
                target = min(len(eff) + req.max_new_tokens
                             - len(req.out_tokens), self.sc.max_seq)
                need = -(-target // self.page_size)
            shared: List[int] = []
            if self.radix is not None and len(eff) > 1:
                # only K/V of prompt[:-1] may be borrowed: the final
                # prompt token must run through this request's own decode
                # to produce its first logits
                shared = self.radix.match(eff[:len(eff) - 1])
                shared = shared[:need]
                for pg in shared:
                    self.pool.incref(pg)   # pin before any eviction below
            fresh = need - len(shared)
            if fresh > self.pool.n_free and self.radix is not None:
                self.radix.evict(fresh - self.pool.n_free, self.pool)
            pages = self.pool.alloc(fresh)
            if pages is None:
                for pg in shared:          # head-of-line: retry next tick
                    self.pool.decref(pg)
                break
            self.queue.pop(0)
            seq = PagedSeq(req, self.pages_per_seq, prompt=eff)
            m = len(shared)
            seq.block_table[:m] = shared
            seq.block_table[m:m + len(pages)] = pages
            seq.n_shared = m
            seq.t_admit = now
            seq.admit_idx = self._admit_seq
            self._admit_seq += 1
            self._admitted += 1
            seq.prefill_done = m * self.page_size
            self.stats["prefix_hit_tokens"] += m * self.page_size
            if seq.prefill_done >= seq.prefill_len:
                seq.to_decode()
            self.active.append(seq)
            self.stats["concurrency_peak"] = max(
                self.stats["concurrency_peak"], len(self.active))
            self.stats["pages_used_peak"] = max(
                self.stats["pages_used_peak"], self.pool.n_used)

    def _publish_prefix(self, seq: PagedSeq):
        """Hand the sequence's full prompt pages to the radix tree once
        its prefill completes (K/V of prompt[:-1] is then resident)."""
        if self.radix is None or seq.published:
            return
        seq.published = True
        m = (len(seq.prompt) - 1) // self.page_size
        if m:
            self.radix.insert(seq.prompt[:m * self.page_size],
                              seq.block_table[:m], self.pool)

    def _release_paged(self, seq: PagedSeq):
        for pg in seq.block_table:
            if pg != PagePool.SCRATCH:
                self.pool.decref(pg)
        self.active.remove(seq)

    def _count_prefill_launches(self, before: int):
        """Per-execution GEMM launch tally: substrate.DISPATCH_COUNTS is
        populated at jit-trace time, so the first dispatch's delta IS the
        launch count one compiled prefill step replays per execution
        (read-only access — the counters stay substrate-owned)."""
        delta = sum(substrate.DISPATCH_COUNTS.values()) - before
        if delta > 0:
            self._prefill_launches = delta
        self.stats["prefill_gemm_dispatches"] += self._prefill_launches

    def _pos_vector(self) -> np.ndarray:
        return np.asarray([s.write_pos for s in self.slots], np.int32)

    # -------------------------------------------------- guarded dispatch
    def _guarded_dispatch(self, dispatch, rows):
        """Run one jit'd step under the fault guards: retry (at most
        ``max_retries`` times) on a :class:`KernelFault` at the
        trace/launch boundary, and on non-finite logits in the active
        ``rows`` (the ``engine.sample`` corruption point — also catches a
        *real* kernel producing NaN/Inf).  Returns ``(logits, new_cache,
        bad_rows)``; ``bad_rows`` non-empty means the retry budget is
        spent and the caller must fail those rows' requests instead of
        sampling garbage.  A persistent KernelFault re-raises.

        ``self.cache`` is only assigned by the caller after this returns:
        the retry re-dispatches from the same pre-tick cache, so a
        recovered tick is bit-identical to a clean one (and the PRNG key
        is untouched — sampling happens after validation)."""
        retries = max(0, self.sc.max_retries)
        for attempt in range(retries + 1):
            try:
                logits, new_cache = dispatch()
            except KernelFault:
                if attempt < retries:
                    self.stats["kernel_fault_retries"] += 1
                    continue
                raise
            if logits is None:           # prefill: nothing to sample
                return None, new_cache, ()
            if self._chaos is not None and self._chaos.fire("engine.sample"):
                # corrupt to NaN on even draws, +Inf on odd (both must be
                # caught by the same finiteness check)
                n = self._chaos.chaos_draws["engine.sample"] - 1
                logits = jnp.full_like(logits,
                                       jnp.nan if n % 2 == 0 else jnp.inf)
            finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
            bad = tuple(r for r in rows if not bool(finite[r]))
            if bad and attempt < retries:
                self.stats["sample_retries"] += 1
                continue
            return logits, new_cache, bad
        raise AssertionError("unreachable")

    # ------------------------------------------------------------ prefill
    def _prefill_tick(self):
        if self.paged:
            self._prefill_tick_paged()
            return
        pre = [s for s in self.slots if s.state == Slot.PREFILL]
        if not pre:
            return
        if self.prefill_mode == "token":
            for slot in pre:
                self._prefill_token_by_token(slot)
            return
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = self._pos_vector()
        lens = np.zeros(B, np.int32)
        for s in pre:
            c = min(C, s.prefill_len - s.prefill_done)
            toks[s.index, :c] = s.tokens[s.prefill_done:
                                         s.prefill_done + c]
            lens[s.index] = c
        t0 = self.clock()
        d0 = sum(substrate.DISPATCH_COUNTS.values())
        try:
            _, self.cache, _ = self._guarded_dispatch(
                lambda: (None, self._prefill(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(lens))[1]),
                rows=())
        except KernelFault as exc:
            for s in pre:
                self._finish(s.req, Outcome.FAILED,
                             f"KernelFault during prefill: {exc}")
                s.release()
            return
        jax.block_until_ready(self.cache)
        self.stats["prefill_time_s"] += self.clock() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        self._count_prefill_launches(d0)
        for s in pre:
            s.finish_chunk(int(lens[s.index]))

    def _prefill_tick_paged(self):
        pre = [s for s in self.active if s.state == PagedSeq.PREFILL]
        if not pre:
            return
        sel = pre[:self.sc.max_batch]
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = np.zeros(B, np.int32)
        lens = np.zeros(B, np.int32)
        bt = np.zeros((B, self.pages_per_seq), np.int32)
        for r, s in enumerate(sel):
            c = min(C, s.prefill_len - s.prefill_done)
            toks[r, :c] = s.prompt[s.prefill_done:s.prefill_done + c]
            pos[r] = s.prefill_done
            lens[r] = c
            bt[r] = s.block_table
        t0 = self.clock()
        d0 = sum(substrate.DISPATCH_COUNTS.values())
        try:
            _, self.cache, _ = self._guarded_dispatch(
                lambda: (None, self._prefill_paged(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(lens),
                    jnp.asarray(bt))[1]),
                rows=())
        except KernelFault as exc:
            for s in sel:
                self._finish(s.req, Outcome.FAILED,
                             f"KernelFault during prefill: {exc}")
                self._release_paged(s)
            return
        jax.block_until_ready(self.cache)
        self.stats["prefill_time_s"] += self.clock() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        self._count_prefill_launches(d0)
        for r, s in enumerate(sel):
            s.prefill_done += int(lens[r])
            if s.prefill_done >= s.prefill_len:
                s.to_decode()
                self._publish_prefix(s)

    def _prefill_token_by_token(self, slot: Slot):
        """Seed path: one full-batch decode dispatch per prompt token.
        Other slots' rows write garbage at their own next position, which
        their next real write overwrites before it is ever attended to."""
        req = slot.req
        for i, t in enumerate(slot.tokens[:-1]):
            toks = np.zeros(self.sc.max_batch, np.int32)
            toks[slot.index] = t
            pos_v = self._pos_vector()
            pos_v[slot.index] = i
            t0 = self.clock()
            try:
                _, self.cache, _ = self._guarded_dispatch(
                    lambda tk=toks, pv=pos_v: (None, self._decode(
                        self.params, self.cache, jnp.asarray(tk),
                        jnp.asarray(pv))[1]),
                    rows=())
            except KernelFault as exc:
                self._finish(req, Outcome.FAILED,
                             f"KernelFault during prefill: {exc}")
                slot.release()
                return
            jax.block_until_ready(self.cache)
            self.stats["prefill_time_s"] += self.clock() - t0
            self.stats["prefill_dispatches"] += 1
            self.stats["prefill_tokens"] += 1
            slot.prefill_done = i + 1
        slot._to_decode()

    # ------------------------------------------------- preemption (paged)
    def _youngest_other(self, s: PagedSeq) -> Optional[PagedSeq]:
        if self.sc.preempt_policy != "youngest":
            return None
        cands = [q for q in self.active if q is not s]
        return max(cands, key=lambda q: q.admit_idx) if cands else None

    def _preempt(self, victim: PagedSeq):
        """Release the victim's pages and re-queue it at the front; on
        re-admission the effective prompt (original + generated so far)
        is recomputed — through the radix prefix cache when warm — which
        reproduces the K/V state exactly (prefill == decode equivalence),
        so the continued stream is bit-identical."""
        req = victim.req
        req.preemptions += 1
        self.stats["preemptions"] += 1
        req.resume_prompt = list(req.prompt) + list(req.out_tokens)
        self._release_paged(victim)
        self.queue.insert(0, req)

    def _ensure_write_page(self, s: PagedSeq) -> bool:
        """Make sure the page backing ``s.pos`` exists before this tick's
        decode write (lazy reservation under ``preempt_policy="youngest"``).
        Escalation on exhaustion: radix eviction -> preempt the youngest
        *other* resident -> fail ``s`` itself (PagePoolExhausted).  Under
        ``"none"`` the worst-case reservation made this a no-op."""
        idx = s.pos // self.page_size
        if s.block_table[idx] != PagePool.SCRATCH:
            return True
        pages = self.pool.alloc(1)
        if pages is None and self.radix is not None:
            self.radix.evict(1, self.pool)
            pages = self.pool.alloc(1)
        while pages is None:
            victim = self._youngest_other(s)
            if victim is None:
                break
            self._preempt(victim)
            pages = self.pool.alloc(1)
        if pages is None:
            err = PagePoolExhausted(
                f"request {s.req.rid}: no page for decode growth at pos "
                f"{s.pos} after eviction and preemption")
            self._finish(s.req, Outcome.FAILED,
                         f"{type(err).__name__}: {err}")
            self._release_paged(s)
            return False
        s.block_table[idx] = pages[0]
        self.stats["pages_used_peak"] = max(
            self.stats["pages_used_peak"], self.pool.n_used)
        return True

    # ------------------------------------------------------------- decode
    def _sample(self, logits, temps):
        greedy = jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps[:, None], 1e-6))
        return np.asarray(jnp.where(temps > 0, sampled, greedy))

    def _finish_stream(self, req: Request):
        """Normal terminal: OK, or PREEMPTED_RETRIED if the stream was
        ever preempted and recomputed on the way."""
        self._finish(req, Outcome.PREEMPTED_RETRIED if req.preemptions
                     else Outcome.OK)

    def _decode_tick_paged(self):
        dec = [s for s in self.active if s.state == PagedSeq.DECODE]
        if not dec:
            return
        B = self.sc.max_batch
        # round-robin: when more sequences are resident than dispatch rows,
        # rotate so every sequence makes progress (no starvation)
        start = self._rr % len(dec)
        order = dec[start:] + dec[:start]
        sel: List[PagedSeq] = []
        for s in order:
            if len(sel) >= B:
                break
            if s not in self.active:   # preempted/failed by earlier growth
                continue
            if self._ensure_write_page(s):
                sel.append(s)
        # growth may have preempted a sequence selected earlier this loop
        sel = [s for s in sel if s in self.active]
        if not sel:
            return
        self._rr += len(sel)
        toks = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        pos = np.zeros(B, np.int32)
        bt = np.zeros((B, self.pages_per_seq), np.int32)
        for r, s in enumerate(sel):
            toks[r] = s.next_token
            temps[r] = s.req.temperature
            pos[r] = s.pos
            bt[r] = s.block_table
        t0 = self.clock()
        try:
            logits, new_cache, bad = self._guarded_dispatch(
                lambda: self._decode_paged(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(bt)),
                rows=range(len(sel)))
        except KernelFault as exc:
            for s in sel:
                self._finish(s.req, Outcome.FAILED, f"KernelFault: {exc}")
                self._release_paged(s)
            return
        self.cache = new_cache
        nxt = self._sample(logits, jnp.asarray(temps))
        self.stats["decode_time_s"] += self.clock() - t0
        self.stats["decode_dispatches"] += 1
        self.stats["decode_tokens"] += len(sel)
        now = self.clock()
        for r, s in enumerate(sel):
            req = s.req
            if r in bad:
                self._finish(req, Outcome.FAILED,
                             "non-finite logits at sample time "
                             "(retry budget spent)")
                self._release_paged(s)
                continue
            tok = int(nxt[r])
            if not req.out_tokens:
                req.ttft_s = now - s.t_admit
            req.out_tokens.append(tok)
            s.next_token = tok
            s.pos += 1
            if (tok == self.sc.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or s.pos >= self.sc.max_seq - 1):
                self._finish_stream(req)
                self._release_paged(s)

    def _decode_tick(self):
        if self.paged:
            self._decode_tick_paged()
            return
        dec = [s for s in self.slots if s.state == Slot.DECODE]
        if not dec:
            return
        toks = np.zeros(self.sc.max_batch, np.int32)
        temps = np.zeros(self.sc.max_batch, np.float32)
        for s in dec:
            toks[s.index] = s.next_token
            temps[s.index] = s.req.temperature
        t0 = self.clock()
        try:
            logits, new_cache, bad = self._guarded_dispatch(
                lambda: self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(self._pos_vector())),
                rows=[s.index for s in dec])
        except KernelFault as exc:
            for s in dec:
                self._finish(s.req, Outcome.FAILED, f"KernelFault: {exc}")
                s.release()
            return
        self.cache = new_cache
        nxt = self._sample(logits, jnp.asarray(temps))
        self.stats["decode_time_s"] += self.clock() - t0
        self.stats["decode_dispatches"] += 1
        self.stats["decode_tokens"] += len(dec)
        now = self.clock()
        for s in dec:
            req = s.req
            if s.index in bad:
                self._finish(req, Outcome.FAILED,
                             "non-finite logits at sample time "
                             "(retry budget spent)")
                s.release()
                continue
            tok = int(nxt[s.index])
            if not req.out_tokens:
                req.ttft_s = now - s.t_admit
            req.out_tokens.append(tok)
            s.next_token = tok
            s.pos += 1
            if (tok == self.sc.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or s.pos >= self.sc.max_seq - 1):
                self._finish_stream(req)
                s.release()

    # ---------------------------------------------------------- deadlines
    def _deadline_reason(self, req: Request, now: float) -> str:
        waited_ms = (now - req.t_submit) * 1e3
        if self.sc.deadline_ms and waited_ms > self.sc.deadline_ms:
            return (f"total deadline {self.sc.deadline_ms:g}ms passed "
                    f"({waited_ms:.1f}ms since submit)")
        if (self.sc.ttft_deadline_ms and not req.out_tokens
                and waited_ms > self.sc.ttft_deadline_ms):
            return (f"TTFT deadline {self.sc.ttft_deadline_ms:g}ms passed "
                    f"({waited_ms:.1f}ms since submit, no token yet)")
        return ""

    def _expire_deadlines(self):
        if not (self.sc.deadline_ms or self.sc.ttft_deadline_ms):
            return
        now = self.clock()
        for req in list(self.queue):
            why = self._deadline_reason(req, now)
            if why:
                self.queue.remove(req)
                self._finish(req, Outcome.DEADLINE_EXPIRED,
                             f"{DeadlineExceeded.__name__}: {why}")
        if self.paged:
            for s in list(self.active):
                why = self._deadline_reason(s.req, now)
                if why:
                    self._finish(s.req, Outcome.DEADLINE_EXPIRED,
                                 f"{DeadlineExceeded.__name__}: {why}")
                    self._release_paged(s)
        else:
            for slot in self.slots:
                if slot.state == Slot.FREE:
                    continue
                why = self._deadline_reason(slot.req, now)
                if why:
                    self._finish(slot.req, Outcome.DEADLINE_EXPIRED,
                                 f"{DeadlineExceeded.__name__}: {why}")
                    slot.release()

    # ----------------------------------------------------------- watchdog
    def _watchdog_fire(self):
        """Deterministically break a stuck engine: no admission, dispatch
        or termination for ``watchdog_ticks`` consecutive ticks means the
        head-of-line request can never be paid for — fail it (typed) and
        move on instead of spinning to max_ticks."""
        self.stats["watchdog_fired"] += 1
        self._no_progress = 0
        msg = (f"stuck-tick watchdog: no engine progress for "
               f"{self.sc.watchdog_ticks} ticks")
        if self.queue:
            req = self.queue.pop(0)
            self._finish(req, Outcome.FAILED,
                         f"{msg} — failing head-of-line request")
            return
        if self.paged and self.active:
            s = min(self.active, key=lambda q: q.admit_idx)
            self._finish(s.req, Outcome.FAILED, msg)
            self._release_paged(s)
        elif not self.paged:
            occ = [s for s in self.slots if s.state != Slot.FREE]
            if occ:
                s = min(occ, key=lambda q: q.t_admit)
                self._finish(s.req, Outcome.FAILED, msg)
                s.release()

    # --------------------------------------------------------------- run
    def _resident(self) -> bool:
        if self.paged:
            return bool(self.active)
        return any(s.state != Slot.FREE for s in self.slots)

    def _progress_sig(self):
        return (self.stats["prefill_dispatches"],
                self.stats["decode_dispatches"],
                self._admitted, self._terminated)

    def step(self):
        """One engine tick: expire deadlines, admit, at most one prefill
        chunk dispatch, one fused decode dispatch.  Returns True when the
        tick made progress (an admission, a dispatch, or a termination).

        Chaos point ``engine.tick``: an injected :class:`EngineCrash`
        raises out of here mid-stream; recover via ``restore()`` from
        ``latest_snapshot()``."""
        self._tick += 1
        if self._chaos is not None and self._chaos.fire(
                "engine.tick", f"tick={self._tick}"):
            raise EngineCrash(
                f"[chaos] engine killed at tick {self._tick} — restore "
                f"from latest_snapshot() and rerun run_to_completion()")
        with chaos_mod.scope(self._chaos):
            return self._step_inner()

    def _step_inner(self):
        sig0 = self._progress_sig()
        self._expire_deadlines()
        self._admit()
        if self._resident():
            self._prefill_tick()
            self._decode_tick()
        return self._progress_sig() != sig0

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        if self.sc.snapshot_every_ticks and not self._snapshots:
            self._take_snapshot()
        while (self.queue or self._resident()) and ticks < max_ticks:
            t0 = self.clock()
            progress = self.step()
            # per-tick heartbeat: host 0's liveness + step time feed the
            # monitor an external supervisor would watch
            self.monitor.beat(0, self._tick, self.clock() - t0)
            ticks += 1
            if (self.sc.snapshot_every_ticks
                    and self._tick % self.sc.snapshot_every_ticks == 0):
                self._take_snapshot()
            if progress:
                self._no_progress = 0
            else:
                self._no_progress += 1
                if (self.sc.watchdog_ticks
                        and self._no_progress >= self.sc.watchdog_ticks):
                    self._watchdog_fire()
        if substrate.strict_audit_enabled():
            # post-run routing cross-check: every site label the jit'd
            # steps recorded must be known to planner.model_gemms ([AF007]
            # RuntimeError otherwise) — the runtime twin of the
            # analysis.jaxpr_audit pass
            substrate.check_dispatch_sites()
        return ticks

    # ------------------------------------------------- snapshot / restore
    def _take_snapshot(self):
        self._snapshots[:] = [self.snapshot()]
        self.stats["snapshots_taken"] += 1

    def latest_snapshot(self) -> Optional[dict]:
        return self._snapshots[-1] if self._snapshots else None

    def snapshot(self) -> dict:
        """Deep copy of the scheduling state at a tick boundary: queue,
        slot/sequence metadata, block tables, pool refcounts, radix tree,
        PRNG key, stats, chaos draw counters and the K/V cache (as host
        numpy).  ``restore()`` rebuilds an engine that continues with
        bit-identical streams."""
        snap = {
            "paged": self.paged,
            "tick": self._tick,
            "admit_seq": self._admit_seq,
            "admitted": self._admitted,
            "terminated": self._terminated,
            "rr": self._rr,
            "key": np.asarray(self.key),
            "stats": dict(self.stats),
            "queue": [_req_state(r) for r in self.queue],
            "cache": jax.tree_util.tree_map(np.asarray, self.cache),
            "chaos": (self._chaos.state_snapshot()
                      if self._chaos is not None else None),
        }
        if self.paged:
            snap["seqs"] = [
                {"req": _req_state(s.req), "prompt": list(s.prompt),
                 "block_table": list(s.block_table),
                 "n_shared": s.n_shared, "published": s.published,
                 "state": s.state, "pos": s.pos,
                 "prefill_len": s.prefill_len,
                 "prefill_done": s.prefill_done,
                 "next_token": s.next_token, "t_admit": s.t_admit,
                 "admit_idx": s.admit_idx}
                for s in self.active]
            snap["pool"] = {"free_pages": list(self.pool.free_pages),
                            "refcounts": list(self.pool.refcounts)}
            snap["radix"] = (self.radix.to_snapshot()
                             if self.radix is not None else None)
        else:
            snap["slots"] = [
                {"state": s.state, "pos": s.pos,
                 "prefill_len": s.prefill_len,
                 "prefill_done": s.prefill_done,
                 "next_token": s.next_token, "t_admit": s.t_admit,
                 "req": _req_state(s.req) if s.req is not None else None}
                for s in self.slots]
        return snap

    def _load_snapshot(self, snap: dict):
        if bool(snap["paged"]) != self.paged:
            raise ValueError("snapshot/config mode mismatch: snapshot is "
                             f"{'paged' if snap['paged'] else 'dense'}, "
                             f"engine is "
                             f"{'paged' if self.paged else 'dense'}")
        self._tick = snap["tick"]
        self._admit_seq = snap["admit_seq"]
        self._admitted = snap["admitted"]
        self._terminated = snap["terminated"]
        self._rr = snap["rr"]
        self.key = jnp.asarray(snap["key"])
        self.stats.update(snap["stats"])
        self.cache = jax.tree_util.tree_map(jnp.asarray, snap["cache"])
        self.queue = [_req_from_state(d) for d in snap["queue"]]
        restored: List[Request] = list(self.queue)
        if self.paged:
            self.pool.free_pages[:] = list(snap["pool"]["free_pages"])
            self.pool.refcounts[:] = list(snap["pool"]["refcounts"])
            if snap.get("radix") is not None:
                if self.radix is None:
                    raise ValueError("snapshot carries a radix tree but "
                                     "prefix_cache is off in this config")
                self.radix = RadixCache.from_snapshot(snap["radix"])
            self.active = []
            for d in snap["seqs"]:
                req = _req_from_state(d["req"])
                seq = PagedSeq(req, len(d["block_table"]),
                               prompt=d["prompt"])
                seq.block_table[:] = list(d["block_table"])
                seq.n_shared = d["n_shared"]
                seq.published = d["published"]
                seq.state = d["state"]
                seq.pos = d["pos"]
                seq.prefill_len = d["prefill_len"]
                seq.prefill_done = d["prefill_done"]
                seq.next_token = d["next_token"]
                seq.t_admit = d["t_admit"]
                seq.admit_idx = d["admit_idx"]
                self.active.append(seq)
                restored.append(req)
        else:
            for slot, d in zip(self.slots, snap["slots"]):
                slot.state = d["state"]
                slot.pos = d["pos"]
                slot.prefill_len = d["prefill_len"]
                slot.prefill_done = d["prefill_done"]
                slot.next_token = d["next_token"]
                slot.t_admit = d["t_admit"]
                slot.req = (_req_from_state(d["req"])
                            if d["req"] is not None else None)
                if slot.req is not None:
                    restored.append(slot.req)
        if self._chaos is not None and snap.get("chaos") is not None:
            self._chaos.load_state(snap["chaos"])
        self.restored_requests = restored

    @classmethod
    def restore(cls, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                snap: dict, *, clock=time.perf_counter,
                reinject_crash: bool = False) -> "ServingEngine":
        """Rebuild an engine from ``snapshot()`` state after a crash.

        In-flight requests are rebuilt as fresh :class:`Request` objects
        (exposed on ``restored_requests``) and continue bit-identically.
        By default the inherited chaos config drops its ``crash``
        triggers (:meth:`ChaosConfig.without_crash`): replaying the same
        seed would otherwise re-kill the engine at the same draw forever.
        Pass ``reinject_crash=True`` to keep them."""
        if serve_cfg.chaos is not None and not reinject_crash:
            serve_cfg = dataclasses.replace(
                serve_cfg, chaos=serve_cfg.chaos.without_crash())
        eng = cls(cfg, params, serve_cfg, clock=clock)
        eng._load_snapshot(snap)
        return eng
