"""Batched serving engine: chunked batched prefill + continuous batching.

A fixed pool of ``max_batch`` sequence :class:`Slot`\\ s, each with an
explicit lifecycle::

    FREE --admit--> PREFILL --(chunks exhausted)--> DECODE --EOS/limit--> FREE

*Admission* pops queued requests into free slots.  *Prefill* runs the
prompt (all but its final token) through ``lm.prefill_step`` in fixed-size
chunks — one jit dispatch per chunk covering **every** prefilling slot at
once, writing K/V only for the target rows.  A P-token prompt therefore
costs ``ceil(P/chunk)`` dispatches instead of the P full-batch decode
steps the per-token path paid (and no longer sprays garbage K/V into
co-resident slots).  *Decode* is the seed's fused per-slot-position step:
one dispatch advances every DECODE slot by one token.

Each engine tick interleaves at most one prefill-chunk dispatch with one
decode dispatch, so decode latency stays bounded while long prompts are
admitted (chunked prefill).  The chunk size defaults to
``core.planner.attention_plan`` — the paper's Eq.(6) steps-vs-per-step-cost
tradeoff, applied here to the serving layer: serving is the third consumer
of the collapse-depth planner after the SA timing model and the flash
kernel.

``prefill_mode``:
  * ``"batched"`` — chunked ``lm.prefill_step`` path (requires
    ``lm.supports_batched_prefill(cfg)``).
  * ``"token"``   — the seed's token-by-token decode-path prefill, kept as
    the bit-exact baseline for equivalence tests and benchmarks.
  * ``"auto"``    — batched when the model supports it, else token.

**Paged mode** (``ServeConfig.kv_pages > 0``): the dense per-slot K/V
region is replaced by a global page pool + per-sequence block tables
(``serving/paged.py``) and admission reserves *pages*, not slots —
concurrency is bounded by the memory budget (``kv_pages``) instead of
``max_batch``, which only caps how many sequences share one dispatch (the
engine round-robins resident sequences over the ``max_batch`` rows).  The
page size comes from ``planner.page_plan`` — the same Eq.(6) cost model
that picks the prefill chunk — and must divide ``max_seq`` so the gathered
logical view has the dense cache length: paged greedy streams are
bit-identical to the dense path's.  ``prefix_cache=True`` adds the radix
prefix cache: requests sharing a prompt prefix map their leading block
-table entries to the same physical pages and skip the shared pages'
prefill work entirely.

A quantizing ``cfg.gemm_backend`` is served from a **pre-quantized param
tree** (``lm.prequantize_params``): weights are quantized once at engine
construction, so the jit'd steps consume int8 codes directly instead of
re-running the in-trace quantize (the AF008 path) every step.

Sampling: greedy or temperature; logits come back fp32 from the model.
Greedy token streams are bit-identical across prefill modes and across
batch compositions (per-row cache evolution is independent).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm
from repro.parallel import sharding
from repro.serving.paged import PagePool, PagedSeq, RadixCache

PREFILL_CHUNK_CHOICES = (16, 32, 64, 128, 256, 512, 1024, 2048)


@dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    out_tokens: list = field(default_factory=list)
    done: bool = False
    ttft_s: Optional[float] = None     # admission -> first generated token


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 256
    eos_id: int = -1           # -1: never stops early
    seed: int = 0
    prefill_mode: str = "auto"  # auto | batched | token
    prefill_chunk: int = 0      # 0 -> planner-chosen (attention_plan)
    # --- paged K/V (0 = dense slot mode) ---------------------------------
    kv_pages: int = 0           # physical pages in the pool (incl. scratch)
    page_size: int = 0          # tokens per page; 0 -> planner.page_plan
    prefix_cache: bool = False  # radix shared-prefix page reuse


class Slot:
    """One sequence slot: FREE -> PREFILL -> DECODE -> FREE."""

    FREE, PREFILL, DECODE = "free", "prefill", "decode"

    def __init__(self, index: int):
        self.index = index
        self.state = Slot.FREE
        self.req: Optional[Request] = None
        self.pos = 0              # decode: position of the token in flight
        self.prefill_len = 0      # tokens to prefill (len(prompt) - 1)
        self.prefill_done = 0
        self.next_token = 0
        self.t_admit = 0.0

    def assign(self, req: Request, now: float):
        self.req = req
        self.t_admit = now
        self.prefill_len = len(req.prompt) - 1
        self.prefill_done = 0
        if self.prefill_len == 0:
            self._to_decode()
        else:
            self.state = Slot.PREFILL
            self.pos = 0

    def _to_decode(self):
        self.state = Slot.DECODE
        self.pos = self.prefill_len
        self.next_token = self.req.prompt[-1]

    def finish_chunk(self, n_tokens: int):
        self.prefill_done += n_tokens
        if self.prefill_done >= self.prefill_len:
            self._to_decode()

    def release(self):
        self.req = None
        self.state = Slot.FREE

    @property
    def write_pos(self) -> int:
        """Next cache position this row writes (where a fused-decode
        dispatch may harmlessly deposit garbage: the row's next real write
        lands on the same position before it is ever attended)."""
        return self.prefill_done if self.state == Slot.PREFILL else self.pos


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        # config-resolve-time backend validation: an unknown gemm_backend
        # fails here with the registered list, not deep inside a traced
        # dispatch mid-serve
        substrate.check_backend(cfg.gemm_backend)
        self.cfg = cfg
        # Quantizing backends serve from a pre-quantized tree: weights
        # quantize ONCE here, never inside the compiled steps (no AF008
        # in-trace requantize; bitwise-identical streams — see
        # lm.prequantize_params).  Non-quantizing backends pass through.
        self.params = (lm.prequantize_params(cfg, params)
                       if substrate.backend_quantizes(cfg.gemm_backend)
                       else params)
        self.sc = serve_cfg
        # SPMD serving: cfg.mesh_shape activates sharded GEMM dispatch
        # inside the jit'd lm steps (the lm entry points scope the mesh
        # themselves).  Resolve the mesh eagerly so a config that needs
        # more devices than the host has fails at engine construction
        # with the XLA_FLAGS hint, not mid-serve.
        self.mesh = sharding.mesh_from_config(cfg)
        B, S = serve_cfg.max_batch, serve_cfg.max_seq
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(serve_cfg.seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))

        mode = serve_cfg.prefill_mode
        if mode == "auto":
            mode = ("batched" if lm.supports_batched_prefill(cfg)
                    else "token")
        if mode == "batched" and not lm.supports_batched_prefill(cfg):
            raise ValueError(
                f"{cfg.name}: model family does not support batched "
                f"prefill (mamba/MoE/cross-attn/sliding-window state); "
                f"use prefill_mode='token' or 'auto'")
        if mode not in ("batched", "token"):
            raise ValueError(f"unknown prefill_mode {mode!r}")
        self.prefill_mode = mode
        # Eq.(6) at the serving layer: steps = ceil(prompt/chunk), per-step
        # cost affine in chunk * cache_len -> attention_plan picks the chunk.
        self.prefill_chunk = serve_cfg.prefill_chunk or min(S, max(
            1, planner.attention_plan(S, S, choices=PREFILL_CHUNK_CHOICES)))
        if mode == "batched":
            self._prefill = jax.jit(
                lambda p, c, t, pos, lens: lm.prefill_step(
                    cfg, p, c, t, pos, lens))

        self.paged = serve_cfg.kv_pages > 0
        if self.paged:
            if not lm.supports_paged_kv(cfg):
                raise ValueError(
                    f"{cfg.name}: model family does not support the paged "
                    f"KV path (see lm.supports_paged_kv); use kv_pages=0")
            if mode != "batched":
                raise ValueError("paged serving requires the batched "
                                 "prefill path (prefill_mode='batched' or "
                                 "'auto' on a supporting family)")
            # Eq.(6) again, applied to page geometry: block-table walk
            # overhead vs trailing-page waste (planner.page_plan).
            page = serve_cfg.page_size or planner.page_plan(S)
            if page <= 0 or S % page:
                raise ValueError(
                    f"page_size={page} must divide max_seq={S}: the "
                    f"gathered view must have the dense cache length "
                    f"(the paged/dense bit-exactness contract)")
            self.page_size = page
            self.pages_per_seq = S // page
            if serve_cfg.kv_pages < self.pages_per_seq + 1:
                raise ValueError(
                    f"kv_pages={serve_cfg.kv_pages}: need at least "
                    f"{self.pages_per_seq + 1} (max_seq/page_size pages "
                    f"for one worst-case sequence + the scratch page)")
            self.pool = PagePool(serve_cfg.kv_pages, page)
            self.radix = (RadixCache(page) if serve_cfg.prefix_cache
                          else None)
            self.cache = lm.init_paged_cache(cfg, serve_cfg.kv_pages, page)
            self.active: List[PagedSeq] = []
            self.slots: List[Slot] = []
            self._rr = 0                  # decode round-robin cursor
            self._decode_paged = jax.jit(
                lambda p, c, t, pos, bt: lm.decode_step_paged(
                    cfg, p, c, t, pos, bt))
            self._prefill_paged = jax.jit(
                lambda p, c, t, pos, lens, bt: lm.prefill_step_paged(
                    cfg, p, c, t, pos, lens, bt))
        else:
            self.cache = lm.init_cache(cfg, B, S)
            self.slots = [Slot(i) for i in range(B)]
            self.active = []

        self._prefill_launches = 0   # per-trace GEMM launches of one chunk
        self.stats = dict(prefill_dispatches=0, decode_dispatches=0,
                          prefill_tokens=0, decode_tokens=0,
                          prefill_time_s=0.0, decode_time_s=0.0,
                          prefill_gemm_dispatches=0,
                          pages_used_peak=0, concurrency_peak=0,
                          prefix_hit_tokens=0)

    def kv_cache_bytes(self) -> int:
        """Resident K/V bytes (pool pages in paged mode, the dense
        (max_batch, max_seq) region otherwise)."""
        return int(sum(leaf.nbytes
                       for leaf in jax.tree_util.tree_leaves(self.cache)))

    # ------------------------------------------------------------- intake
    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) > self.sc.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"exceeds max_seq={self.sc.max_seq} (positions past the "
                f"cache would be silently dropped)")
        self.queue.append(req)

    def _admit(self):
        if self.paged:
            self._admit_paged()
            return
        now = time.perf_counter()
        for slot in self.slots:
            if slot.state == Slot.FREE and self.queue:
                slot.assign(self.queue.pop(0), now)

    def _admit_paged(self):
        """Memory-bounded admission: FIFO-pop the queue while the pool can
        reserve each request's worst-case page span (prompt + max_new,
        clipped to max_seq) — minus whatever the radix prefix cache
        already holds.  Concurrency is whatever the page budget sustains,
        not ``max_batch``."""
        now = time.perf_counter()
        while self.queue:
            req = self.queue[0]
            target = min(len(req.prompt) + req.max_new_tokens,
                         self.sc.max_seq)
            need = -(-target // self.page_size)
            shared: List[int] = []
            if self.radix is not None and len(req.prompt) > 1:
                # only K/V of prompt[:-1] may be borrowed: the final
                # prompt token must run through this request's own decode
                # to produce its first logits
                shared = self.radix.match(req.prompt[:len(req.prompt) - 1])
                shared = shared[:need]
                for pg in shared:
                    self.pool.incref(pg)   # pin before any eviction below
            fresh = need - len(shared)
            if fresh > self.pool.n_free and self.radix is not None:
                self.radix.evict(fresh - self.pool.n_free, self.pool)
            pages = self.pool.alloc(fresh)
            if pages is None:
                for pg in shared:          # head-of-line: retry next tick
                    self.pool.decref(pg)
                break
            self.queue.pop(0)
            seq = PagedSeq(req, self.pages_per_seq)
            m = len(shared)
            seq.block_table[:m] = shared
            seq.block_table[m:m + len(pages)] = pages
            seq.n_shared = m
            seq.t_admit = now
            seq.prefill_done = m * self.page_size
            self.stats["prefix_hit_tokens"] += m * self.page_size
            if seq.prefill_done >= seq.prefill_len:
                seq.to_decode()
            self.active.append(seq)
            self.stats["concurrency_peak"] = max(
                self.stats["concurrency_peak"], len(self.active))
            self.stats["pages_used_peak"] = max(
                self.stats["pages_used_peak"], self.pool.n_used)

    def _publish_prefix(self, seq: PagedSeq):
        """Hand the sequence's full prompt pages to the radix tree once
        its prefill completes (K/V of prompt[:-1] is then resident)."""
        if self.radix is None or seq.published:
            return
        seq.published = True
        m = (len(seq.req.prompt) - 1) // self.page_size
        if m:
            self.radix.insert(seq.req.prompt[:m * self.page_size],
                              seq.block_table[:m], self.pool)

    def _release_paged(self, seq: PagedSeq):
        for pg in seq.block_table:
            if pg != PagePool.SCRATCH:
                self.pool.decref(pg)
        self.active.remove(seq)

    def _count_prefill_launches(self, before: int):
        """Per-execution GEMM launch tally: substrate.DISPATCH_COUNTS is
        populated at jit-trace time, so the first dispatch's delta IS the
        launch count one compiled prefill step replays per execution
        (read-only access — the counters stay substrate-owned)."""
        delta = sum(substrate.DISPATCH_COUNTS.values()) - before
        if delta > 0:
            self._prefill_launches = delta
        self.stats["prefill_gemm_dispatches"] += self._prefill_launches

    def _pos_vector(self) -> np.ndarray:
        return np.asarray([s.write_pos for s in self.slots], np.int32)

    # ------------------------------------------------------------ prefill
    def _prefill_tick(self):
        if self.paged:
            self._prefill_tick_paged()
            return
        pre = [s for s in self.slots if s.state == Slot.PREFILL]
        if not pre:
            return
        if self.prefill_mode == "token":
            for slot in pre:
                self._prefill_token_by_token(slot)
            return
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = self._pos_vector()
        lens = np.zeros(B, np.int32)
        for s in pre:
            c = min(C, s.prefill_len - s.prefill_done)
            toks[s.index, :c] = s.req.prompt[s.prefill_done:
                                             s.prefill_done + c]
            lens[s.index] = c
        t0 = time.perf_counter()
        d0 = sum(substrate.DISPATCH_COUNTS.values())
        _, self.cache = self._prefill(self.params, self.cache,
                                      jnp.asarray(toks), jnp.asarray(pos),
                                      jnp.asarray(lens))
        jax.block_until_ready(self.cache)
        self.stats["prefill_time_s"] += time.perf_counter() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        self._count_prefill_launches(d0)
        for s in pre:
            s.finish_chunk(int(lens[s.index]))

    def _prefill_tick_paged(self):
        pre = [s for s in self.active if s.state == PagedSeq.PREFILL]
        if not pre:
            return
        sel = pre[:self.sc.max_batch]
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = np.zeros(B, np.int32)
        lens = np.zeros(B, np.int32)
        bt = np.zeros((B, self.pages_per_seq), np.int32)
        for r, s in enumerate(sel):
            c = min(C, s.prefill_len - s.prefill_done)
            toks[r, :c] = s.req.prompt[s.prefill_done:s.prefill_done + c]
            pos[r] = s.prefill_done
            lens[r] = c
            bt[r] = s.block_table
        t0 = time.perf_counter()
        d0 = sum(substrate.DISPATCH_COUNTS.values())
        _, self.cache = self._prefill_paged(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(lens), jnp.asarray(bt))
        jax.block_until_ready(self.cache)
        self.stats["prefill_time_s"] += time.perf_counter() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        self._count_prefill_launches(d0)
        for r, s in enumerate(sel):
            s.prefill_done += int(lens[r])
            if s.prefill_done >= s.prefill_len:
                s.to_decode()
                self._publish_prefix(s)

    def _prefill_token_by_token(self, slot: Slot):
        """Seed path: one full-batch decode dispatch per prompt token.
        Other slots' rows write garbage at their own next position, which
        their next real write overwrites before it is ever attended to."""
        req = slot.req
        for i, t in enumerate(req.prompt[:-1]):
            toks = np.zeros(self.sc.max_batch, np.int32)
            toks[slot.index] = t
            pos_v = self._pos_vector()
            pos_v[slot.index] = i
            t0 = time.perf_counter()
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(toks),
                                         jnp.asarray(pos_v))
            jax.block_until_ready(self.cache)
            self.stats["prefill_time_s"] += time.perf_counter() - t0
            self.stats["prefill_dispatches"] += 1
            self.stats["prefill_tokens"] += 1
            slot.prefill_done = i + 1
        slot._to_decode()

    # ------------------------------------------------------------- decode
    def _sample(self, logits, temps):
        greedy = jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps[:, None], 1e-6))
        return np.asarray(jnp.where(temps > 0, sampled, greedy))

    def _decode_tick_paged(self):
        dec = [s for s in self.active if s.state == PagedSeq.DECODE]
        if not dec:
            return
        B = self.sc.max_batch
        # round-robin: when more sequences are resident than dispatch rows,
        # rotate so every sequence makes progress (no starvation)
        start = self._rr % len(dec)
        sel = (dec[start:] + dec[:start])[:B]
        self._rr += len(sel)
        toks = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        pos = np.zeros(B, np.int32)
        bt = np.zeros((B, self.pages_per_seq), np.int32)
        for r, s in enumerate(sel):
            toks[r] = s.next_token
            temps[r] = s.req.temperature
            pos[r] = s.pos
            bt[r] = s.block_table
        t0 = time.perf_counter()
        logits, self.cache = self._decode_paged(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bt))
        nxt = self._sample(logits, jnp.asarray(temps))
        self.stats["decode_time_s"] += time.perf_counter() - t0
        self.stats["decode_dispatches"] += 1
        self.stats["decode_tokens"] += len(sel)
        now = time.perf_counter()
        for r, s in enumerate(sel):
            req = s.req
            tok = int(nxt[r])
            if not req.out_tokens:
                req.ttft_s = now - s.t_admit
            req.out_tokens.append(tok)
            s.next_token = tok
            s.pos += 1
            if (tok == self.sc.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or s.pos >= self.sc.max_seq - 1):
                req.done = True
                self._release_paged(s)

    def _decode_tick(self):
        if self.paged:
            self._decode_tick_paged()
            return
        dec = [s for s in self.slots if s.state == Slot.DECODE]
        if not dec:
            return
        toks = np.zeros(self.sc.max_batch, np.int32)
        temps = np.zeros(self.sc.max_batch, np.float32)
        for s in dec:
            toks[s.index] = s.next_token
            temps[s.index] = s.req.temperature
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self._pos_vector()))
        nxt = self._sample(logits, jnp.asarray(temps))
        self.stats["decode_time_s"] += time.perf_counter() - t0
        self.stats["decode_dispatches"] += 1
        self.stats["decode_tokens"] += len(dec)
        now = time.perf_counter()
        for s in dec:
            req = s.req
            tok = int(nxt[s.index])
            if not req.out_tokens:
                req.ttft_s = now - s.t_admit
            req.out_tokens.append(tok)
            s.next_token = tok
            s.pos += 1
            if (tok == self.sc.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or s.pos >= self.sc.max_seq - 1):
                req.done = True
                s.release()

    # --------------------------------------------------------------- run
    def _resident(self) -> bool:
        if self.paged:
            return bool(self.active)
        return any(s.state != Slot.FREE for s in self.slots)

    def step(self):
        """One engine tick: admit, at most one prefill chunk dispatch,
        one fused decode dispatch."""
        self._admit()
        if not self._resident():
            return False
        self._prefill_tick()
        self._decode_tick()
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while (self.queue or self._resident()) and ticks < max_ticks:
            self.step()
            ticks += 1
        if substrate.strict_audit_enabled():
            # post-run routing cross-check: every site label the jit'd
            # steps recorded must be known to planner.model_gemms ([AF007]
            # RuntimeError otherwise) — the runtime twin of the
            # analysis.jaxpr_audit pass
            substrate.check_dispatch_sites()
        return ticks
