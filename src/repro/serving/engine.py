"""Batched serving engine: chunked batched prefill + continuous batching.

A fixed pool of ``max_batch`` sequence :class:`Slot`\\ s, each with an
explicit lifecycle::

    FREE --admit--> PREFILL --(chunks exhausted)--> DECODE --EOS/limit--> FREE

*Admission* pops queued requests into free slots.  *Prefill* runs the
prompt (all but its final token) through ``lm.prefill_step`` in fixed-size
chunks — one jit dispatch per chunk covering **every** prefilling slot at
once, writing K/V only for the target rows.  A P-token prompt therefore
costs ``ceil(P/chunk)`` dispatches instead of the P full-batch decode
steps the per-token path paid (and no longer sprays garbage K/V into
co-resident slots).  *Decode* is the seed's fused per-slot-position step:
one dispatch advances every DECODE slot by one token.

Each engine tick interleaves at most one prefill-chunk dispatch with one
decode dispatch, so decode latency stays bounded while long prompts are
admitted (chunked prefill).  The chunk size defaults to
``core.planner.attention_plan`` — the paper's Eq.(6) steps-vs-per-step-cost
tradeoff, applied here to the serving layer: serving is the third consumer
of the collapse-depth planner after the SA timing model and the flash
kernel.

``prefill_mode``:
  * ``"batched"`` — chunked ``lm.prefill_step`` path (requires
    ``lm.supports_batched_prefill(cfg)``).
  * ``"token"``   — the seed's token-by-token decode-path prefill, kept as
    the bit-exact baseline for equivalence tests and benchmarks.
  * ``"auto"``    — batched when the model supports it, else token.

Sampling: greedy or temperature; logits come back fp32 from the model.
Greedy token streams are bit-identical across prefill modes and across
batch compositions (per-row cache evolution is independent).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm
from repro.parallel import sharding

PREFILL_CHUNK_CHOICES = (16, 32, 64, 128, 256, 512, 1024, 2048)


@dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    out_tokens: list = field(default_factory=list)
    done: bool = False
    ttft_s: Optional[float] = None     # admission -> first generated token


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 256
    eos_id: int = -1           # -1: never stops early
    seed: int = 0
    prefill_mode: str = "auto"  # auto | batched | token
    prefill_chunk: int = 0      # 0 -> planner-chosen (attention_plan)


class Slot:
    """One sequence slot: FREE -> PREFILL -> DECODE -> FREE."""

    FREE, PREFILL, DECODE = "free", "prefill", "decode"

    def __init__(self, index: int):
        self.index = index
        self.state = Slot.FREE
        self.req: Optional[Request] = None
        self.pos = 0              # decode: position of the token in flight
        self.prefill_len = 0      # tokens to prefill (len(prompt) - 1)
        self.prefill_done = 0
        self.next_token = 0
        self.t_admit = 0.0

    def assign(self, req: Request, now: float):
        self.req = req
        self.t_admit = now
        self.prefill_len = len(req.prompt) - 1
        self.prefill_done = 0
        if self.prefill_len == 0:
            self._to_decode()
        else:
            self.state = Slot.PREFILL
            self.pos = 0

    def _to_decode(self):
        self.state = Slot.DECODE
        self.pos = self.prefill_len
        self.next_token = self.req.prompt[-1]

    def finish_chunk(self, n_tokens: int):
        self.prefill_done += n_tokens
        if self.prefill_done >= self.prefill_len:
            self._to_decode()

    def release(self):
        self.req = None
        self.state = Slot.FREE

    @property
    def write_pos(self) -> int:
        """Next cache position this row writes (where a fused-decode
        dispatch may harmlessly deposit garbage: the row's next real write
        lands on the same position before it is ever attended)."""
        return self.prefill_done if self.state == Slot.PREFILL else self.pos


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        # config-resolve-time backend validation: an unknown gemm_backend
        # fails here with the registered list, not deep inside a traced
        # dispatch mid-serve
        substrate.check_backend(cfg.gemm_backend)
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        # SPMD serving: cfg.mesh_shape activates sharded GEMM dispatch
        # inside the jit'd lm steps (the lm entry points scope the mesh
        # themselves).  Resolve the mesh eagerly so a config that needs
        # more devices than the host has fails at engine construction
        # with the XLA_FLAGS hint, not mid-serve.
        self.mesh = sharding.mesh_from_config(cfg)
        B, S = serve_cfg.max_batch, serve_cfg.max_seq
        self.cache = lm.init_cache(cfg, B, S)
        self.slots = [Slot(i) for i in range(B)]
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(serve_cfg.seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))

        mode = serve_cfg.prefill_mode
        if mode == "auto":
            mode = ("batched" if lm.supports_batched_prefill(cfg)
                    else "token")
        if mode == "batched" and not lm.supports_batched_prefill(cfg):
            raise ValueError(
                f"{cfg.name}: model family does not support batched "
                f"prefill (mamba/MoE/cross-attn/sliding-window state); "
                f"use prefill_mode='token' or 'auto'")
        if mode not in ("batched", "token"):
            raise ValueError(f"unknown prefill_mode {mode!r}")
        self.prefill_mode = mode
        # Eq.(6) at the serving layer: steps = ceil(prompt/chunk), per-step
        # cost affine in chunk * cache_len -> attention_plan picks the chunk.
        self.prefill_chunk = serve_cfg.prefill_chunk or min(S, max(
            1, planner.attention_plan(S, S, choices=PREFILL_CHUNK_CHOICES)))
        if mode == "batched":
            self._prefill = jax.jit(
                lambda p, c, t, pos, lens: lm.prefill_step(
                    cfg, p, c, t, pos, lens))
        self.stats = dict(prefill_dispatches=0, decode_dispatches=0,
                          prefill_tokens=0, decode_tokens=0,
                          prefill_time_s=0.0, decode_time_s=0.0)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) > self.sc.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"exceeds max_seq={self.sc.max_seq} (positions past the "
                f"cache would be silently dropped)")
        self.queue.append(req)

    def _admit(self):
        now = time.perf_counter()
        for slot in self.slots:
            if slot.state == Slot.FREE and self.queue:
                slot.assign(self.queue.pop(0), now)

    def _pos_vector(self) -> np.ndarray:
        return np.asarray([s.write_pos for s in self.slots], np.int32)

    # ------------------------------------------------------------ prefill
    def _prefill_tick(self):
        pre = [s for s in self.slots if s.state == Slot.PREFILL]
        if not pre:
            return
        if self.prefill_mode == "token":
            for slot in pre:
                self._prefill_token_by_token(slot)
            return
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = self._pos_vector()
        lens = np.zeros(B, np.int32)
        for s in pre:
            c = min(C, s.prefill_len - s.prefill_done)
            toks[s.index, :c] = s.req.prompt[s.prefill_done:
                                             s.prefill_done + c]
            lens[s.index] = c
        t0 = time.perf_counter()
        _, self.cache = self._prefill(self.params, self.cache,
                                      jnp.asarray(toks), jnp.asarray(pos),
                                      jnp.asarray(lens))
        jax.block_until_ready(self.cache)
        self.stats["prefill_time_s"] += time.perf_counter() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        for s in pre:
            s.finish_chunk(int(lens[s.index]))

    def _prefill_token_by_token(self, slot: Slot):
        """Seed path: one full-batch decode dispatch per prompt token.
        Other slots' rows write garbage at their own next position, which
        their next real write overwrites before it is ever attended to."""
        req = slot.req
        for i, t in enumerate(req.prompt[:-1]):
            toks = np.zeros(self.sc.max_batch, np.int32)
            toks[slot.index] = t
            pos_v = self._pos_vector()
            pos_v[slot.index] = i
            t0 = time.perf_counter()
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(toks),
                                         jnp.asarray(pos_v))
            jax.block_until_ready(self.cache)
            self.stats["prefill_time_s"] += time.perf_counter() - t0
            self.stats["prefill_dispatches"] += 1
            self.stats["prefill_tokens"] += 1
            slot.prefill_done = i + 1
        slot._to_decode()

    # ------------------------------------------------------------- decode
    def _sample(self, logits, temps):
        greedy = jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps[:, None], 1e-6))
        return np.asarray(jnp.where(temps > 0, sampled, greedy))

    def _decode_tick(self):
        dec = [s for s in self.slots if s.state == Slot.DECODE]
        if not dec:
            return
        toks = np.zeros(self.sc.max_batch, np.int32)
        temps = np.zeros(self.sc.max_batch, np.float32)
        for s in dec:
            toks[s.index] = s.next_token
            temps[s.index] = s.req.temperature
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self._pos_vector()))
        nxt = self._sample(logits, jnp.asarray(temps))
        self.stats["decode_time_s"] += time.perf_counter() - t0
        self.stats["decode_dispatches"] += 1
        self.stats["decode_tokens"] += len(dec)
        now = time.perf_counter()
        for s in dec:
            req = s.req
            tok = int(nxt[s.index])
            if not req.out_tokens:
                req.ttft_s = now - s.t_admit
            req.out_tokens.append(tok)
            s.next_token = tok
            s.pos += 1
            if (tok == self.sc.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or s.pos >= self.sc.max_seq - 1):
                req.done = True
                s.release()

    # --------------------------------------------------------------- run
    def step(self):
        """One engine tick: admit, at most one prefill chunk dispatch,
        one fused decode dispatch."""
        self._admit()
        if all(s.state == Slot.FREE for s in self.slots):
            return False
        self._prefill_tick()
        self._decode_tick()
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while (self.queue
               or any(s.state != Slot.FREE for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        if substrate.strict_audit_enabled():
            # post-run routing cross-check: every site label the jit'd
            # steps recorded must be known to planner.model_gemms ([AF007]
            # RuntimeError otherwise) — the runtime twin of the
            # analysis.jaxpr_audit pass
            substrate.check_dispatch_sites()
        return ticks
