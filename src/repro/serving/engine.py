"""Batched serving engine: continuous-batching prefill + decode.

A fixed pool of `max_batch` sequence slots; requests occupy a free slot,
prefill fills the slot's KV cache (per-slot, via the model's prefill path
on a right-padded batch), and a single fused decode step advances every
active slot each tick.  Slots free on EOS/max-tokens and are immediately
refilled from the queue (continuous batching).

Sampling: greedy or temperature; logits come back fp32 from the model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 256
    eos_id: int = -1           # -1: never stops early
    seed: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        B, S = serve_cfg.max_batch, serve_cfg.max_seq
        self.cache = lm.init_cache(cfg, B, S)
        self.pos = np.zeros(B, np.int32)        # next position per slot
        self.active: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(serve_cfg.seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))

    # ------------------------------------------------------------- intake
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.sc.max_batch):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                # prefill token-by-token through the decode path: exact and
                # cache-layout-identical.  Other slots' rows write garbage
                # at their own NEXT position, which their next real decode
                # overwrites before it is ever attended to (masked by pos).
                for i, t in enumerate(req.prompt[:-1]):
                    self._step_slot(slot, t, i)
                self.pos[slot] = len(req.prompt) - 1
                req._next_token = req.prompt[-1]

    def _step_slot(self, slot, token, pos):
        toks = np.zeros(self.sc.max_batch, np.int32)
        toks[slot] = token
        pos_v = self.pos.copy()
        pos_v[slot] = pos
        _, self.cache = self._decode(self.params, self.cache,
                                     jnp.asarray(toks), jnp.asarray(pos_v))

    # ------------------------------------------------------------- decode
    def _sample(self, logits, temps):
        greedy = jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps[:, None], 1e-6))
        return np.asarray(jnp.where(temps > 0, sampled, greedy))

    def step(self):
        """One decode tick for all active slots (per-slot positions)."""
        self._admit()
        if not any(self.active):
            return False
        toks = np.zeros(self.sc.max_batch, np.int32)
        temps = np.zeros(self.sc.max_batch, np.float32)
        for slot, req in enumerate(self.active):
            if req is not None:
                toks[slot] = req._next_token
                temps[slot] = req.temperature
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        nxt = self._sample(logits, jnp.asarray(temps))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            req._next_token = tok
            self.pos[slot] += 1
            if (tok == self.sc.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or self.pos[slot] >= self.sc.max_seq - 1):
                req.done = True
                self.active[slot] = None
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
