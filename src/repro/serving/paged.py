"""Paged K/V state for the serving engine: page pool, radix prefix cache.

The dense engine pre-allocates one ``(max_batch, max_seq)`` K/V region and
binds every request to a fixed :class:`~repro.serving.engine.Slot`, so
memory scales with the worst case and concurrency is hard-capped at
``max_batch``.  The paged engine instead owns a global **page pool** per
layer — ``(n_pages, page_size, KV, hd)`` — and gives every admitted
sequence a **block table** mapping its logical cache positions to physical
pages.  Admission tracks *free pages*, not free slots: a short request
reserves ``ceil(target_len / page_size)`` pages, so many short sequences
can be resident at once even though at most ``max_batch`` of them are
bound to dispatch rows per tick (the engine round-robins the rest).

Page 0 is the **scratch page**: never allocated, it backs every
not-yet-reserved block-table entry, so fused dispatches with partially
idle rows have a harmless place to read from and write to (the paged
analogue of the dense path's garbage-write invariant).

The **radix prefix cache** (:class:`RadixCache`) is a page-granular trie
over prompt tokens: a node's edge is labelled by ``page_size``-token
chunks, so two prompts sharing a system prefix map their leading block
-table entries to the *same physical pages*.  Sharing is refcounted
copy-on-write at page granularity: only fully-matched pages are shared
(the tree holds one reference, every borrowing sequence one more); at the
divergence point the borrower gets a *fresh* page and recomputes from the
page-aligned boundary, so a shared page is never written after
publication — which is what keeps paged streams bit-identical to the
dense path (a shared page's K/V is a pure function of (token prefix,
positions, params), independent of who computed it).

All mutable page/block-table state lives in this module and
``serving/engine.py`` — the AFL03 lint flags mutation anywhere else.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class PagePool:
    """Refcounted free-list allocator over the physical K/V pages.

    Page 0 is reserved as the scratch page (see module docstring); the
    allocatable pool is pages ``1..n_pages-1``.  Allocation order is
    deterministic (lowest-numbered free page first) so engine runs are
    reproducible.
    """

    SCRATCH = 0

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"PagePool needs >= 2 pages (one is scratch), "
                             f"got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        # pop() takes from the end: keep the list descending so the lowest
        # free page id is handed out first.
        self.free_pages = list(range(n_pages - 1, 0, -1))
        self.refcounts = [0] * n_pages

    @property
    def n_free(self) -> int:
        return len(self.free_pages)

    @property
    def n_used(self) -> int:
        return (self.n_pages - 1) - len(self.free_pages)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Reserve ``n`` pages (refcount 1 each), or None if short.

        Chaos injection point ``pool.alloc``: the ambient
        :mod:`repro.runtime.chaos` engine may report exhaustion even when
        pages are free — exercising the caller's head-of-line-retry /
        evict / preempt paths without actually shrinking the pool (lazy
        import; no-op contextvar read when chaos is inactive)."""
        if n > 0:
            from repro.runtime import chaos
            if chaos.fire("pool.alloc", f"n={n} free={len(self.free_pages)}"):
                return None
        if n > len(self.free_pages):
            return None
        pages = [self.free_pages.pop() for _ in range(n)]
        for pg in pages:
            self.refcounts[pg] = 1
        return pages

    def incref(self, page: int):
        if page == self.SCRATCH:
            raise ValueError("scratch page is not refcounted")
        if self.refcounts[page] <= 0:
            raise ValueError(f"incref on free page {page}")
        self.refcounts[page] += 1

    def decref(self, page: int):
        if page == self.SCRATCH:
            raise ValueError("scratch page is not refcounted")
        rc = self.refcounts[page] - 1
        if rc < 0:
            raise ValueError(f"decref on free page {page}")
        self.refcounts[page] = rc
        if rc == 0:
            self.free_pages.append(page)


class _Node:
    """One radix-trie node: a run of page-granular (key, page) pairs."""

    __slots__ = ("keys", "pages", "children", "parent", "last_used")

    def __init__(self, keys=(), pages=(), parent=None):
        self.keys: List[Tuple[int, ...]] = list(keys)
        self.pages: List[int] = list(pages)
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent: Optional["_Node"] = parent
        self.last_used = 0


class RadixCache:
    """Page-granular radix trie mapping token prefixes to physical pages.

    Keys are ``page_size``-token tuples; a node holds a run of consecutive
    pages (path compression), children branch on the next page's key.  The
    tree itself holds one pool reference per published page, so published
    pages survive their producer; :meth:`evict` drops LRU leaves whose
    pages nobody else holds (refcount 1 = tree-only) to refill the pool.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _Node()
        self._clock = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0

    # ------------------------------------------------------------ helpers
    def _keys(self, tokens) -> List[Tuple[int, ...]]:
        p = self.page_size
        return [tuple(tokens[i * p:(i + 1) * p])
                for i in range(len(tokens) // p)]

    def _split(self, node: _Node, j: int):
        """Split ``node`` after its first ``j`` (key, page) pairs."""
        tail = _Node(node.keys[j:], node.pages[j:], parent=node)
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        tail.last_used = node.last_used
        node.keys = node.keys[:j]
        node.pages = node.pages[:j]
        node.children = {tail.keys[0]: tail}

    def n_nodes(self) -> int:
        out, stack = 0, [self.root]
        while stack:
            nd = stack.pop()
            out += 1
            stack.extend(nd.children.values())
        return out - 1                                  # root not counted

    def n_pages(self) -> int:
        out, stack = 0, [self.root]
        while stack:
            nd = stack.pop()
            out += len(nd.pages)
            stack.extend(nd.children.values())
        return out

    # ------------------------------------------------------------- match
    def match(self, tokens) -> List[int]:
        """Physical pages of the longest fully-paged cached prefix of
        ``tokens``.  Only whole pages match — the caller prefills from the
        page-aligned divergence point (recompute-on-divergence COW)."""
        self._clock += 1
        keys = self._keys(tokens)
        self.lookup_tokens += len(tokens)
        pages: List[int] = []
        node, i = self.root, 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                break
            child.last_used = self._clock
            j = 0
            while (j < len(child.keys) and i < len(keys)
                   and child.keys[j] == keys[i]):
                pages.append(child.pages[j])
                i += 1
                j += 1
            if j < len(child.keys):                     # diverged mid-node
                break
            node = child
        self.hit_tokens += len(pages) * self.page_size
        return pages

    # ------------------------------------------------------------ insert
    def insert(self, tokens, pages: List[int], pool: PagePool) -> int:
        """Publish ``tokens``' full pages into the tree.  ``pages[i]`` is
        the physical page of tokens ``[i*p, (i+1)*p)``.  Pages already
        published (same key path) are left alone; each newly-published
        page gets one tree-owned pool reference.  Returns the number of
        newly published pages."""
        self._clock += 1
        keys = self._keys(tokens)
        node, i = self.root, 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                new = _Node(keys[i:], pages[i:len(keys)], parent=node)
                new.last_used = self._clock
                for pg in new.pages:
                    pool.incref(pg)
                node.children[keys[i]] = new
                return len(new.pages)
            child.last_used = self._clock
            j = 0
            while (j < len(child.keys) and i < len(keys)
                   and child.keys[j] == keys[i]):
                i += 1
                j += 1
            if j < len(child.keys):
                if i == len(keys):                      # prefix of the node
                    return 0
                self._split(child, j)                   # diverged mid-node
            node = child
        return 0

    # --------------------------------------------------------- snapshot
    def to_snapshot(self) -> dict:
        """Pure-python serialization of the trie (engine crash-recovery
        snapshots).  Pool refcounts are snapshotted by the engine; the
        tree carries only its structure and LRU clocks."""
        def ser(node: _Node) -> dict:
            return {"keys": [list(k) for k in node.keys],
                    "pages": list(node.pages),
                    "last_used": node.last_used,
                    "children": [ser(c) for c in node.children.values()]}
        return {"page_size": self.page_size, "clock": self._clock,
                "hit_tokens": self.hit_tokens,
                "lookup_tokens": self.lookup_tokens,
                "root": ser(self.root)}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "RadixCache":
        out = cls(snap["page_size"])
        out._clock = snap["clock"]
        out.hit_tokens = snap["hit_tokens"]
        out.lookup_tokens = snap["lookup_tokens"]

        def de(d: dict, parent: Optional[_Node]) -> _Node:
            node = _Node([tuple(k) for k in d["keys"]], d["pages"],
                         parent=parent)
            node.last_used = d["last_used"]
            for cd in d["children"]:
                child = de(cd, node)
                node.children[child.keys[0]] = child
            return node

        out.root = de(snap["root"], None)
        return out

    # ------------------------------------------------------------- evict
    def evict(self, n_needed: int, pool: PagePool) -> int:
        """Drop least-recently-used leaves whose pages only the tree still
        references (refcount 1), until >= ``n_needed`` pages return to the
        pool or no evictable leaf remains.  Returns pages freed."""
        freed = 0
        while freed < n_needed:
            victim, stack = None, [self.root]
            while stack:
                nd = stack.pop()
                stack.extend(nd.children.values())
                if (nd is not self.root and not nd.children
                        and all(pool.refcounts[pg] == 1 for pg in nd.pages)
                        and (victim is None
                             or nd.last_used < victim.last_used)):
                    victim = nd
            if victim is None:
                break
            for pg in victim.pages:
                pool.decref(pg)
            freed += len(victim.pages)
            del victim.parent.children[victim.keys[0]]
        return freed


class PagedSeq:
    """A resident sequence: request + block table (no fixed slot).

    Unlike :class:`~repro.serving.engine.Slot`, a PagedSeq is created per
    admitted request and holds the request's page reservations; the engine
    binds at most ``max_batch`` of them to dispatch rows each tick.
    """

    PREFILL, DECODE = "prefill", "decode"

    def __init__(self, req, n_table_entries: int, prompt=None):
        self.req = req
        # effective prompt: a preempted request re-admits with its
        # original prompt + already-generated tokens, so recompute (via
        # the prefix cache when warm) reproduces the K/V state exactly
        self.prompt: List[int] = (list(prompt) if prompt is not None
                                  else list(req.prompt))
        self.block_table = [PagePool.SCRATCH] * n_table_entries
        self.n_shared = 0          # leading block_table entries borrowed
        self.published = False     # prefix pages handed to the radix tree
        self.state = PagedSeq.PREFILL
        self.pos = 0
        self.prefill_len = len(self.prompt) - 1
        self.prefill_done = 0
        self.next_token = 0
        self.t_admit = 0.0
        self.admit_idx = 0         # monotonic admission number (preemption
        #                            picks the youngest deterministically)

    def to_decode(self):
        self.state = PagedSeq.DECODE
        self.pos = self.prefill_len
        self.next_token = self.prompt[-1]

    @property
    def write_pos(self) -> int:
        return (self.prefill_done if self.state == PagedSeq.PREFILL
                else self.pos)
