"""Typed serving errors + the request-outcome taxonomy.

Every request the engine touches terminates with exactly one
:class:`Outcome`; the engine counts them in ``stats["outcome_*"]`` and
``benchmarks/serving_bench.py``'s resilience section gates the counters.
The exception hierarchy replaces the bare ``ValueError``s the engine and
``launch/serve.py`` used to raise, so callers can distinguish "you sent a
bad request" from "the system is shedding load" from "a kernel fault ate
your stream" without string matching.

:class:`AdmissionError` deliberately subclasses ``ValueError``: pre-PR 8
callers catching ``ValueError`` around ``submit`` keep working.
"""
from __future__ import annotations

import enum


class Outcome(str, enum.Enum):
    """Terminal state of a request.  ``str`` values are the stats keys
    (``stats[f"outcome_{o.value}"]``) and the bench/report vocabulary."""

    OK = "ok"                              # full stream delivered
    REJECTED_OVERLOAD = "rejected_overload"  # bounded queue shed it at submit
    DEADLINE_EXPIRED = "deadline_expired"  # TTFT/total deadline passed
    PREEMPTED_RETRIED = "preempted_retried"  # finished, but was preempted
    FAILED = "failed"                      # invalid, kernel fault, watchdog


OUTCOMES = tuple(o.value for o in Outcome)


class ServingError(Exception):
    """Base of every typed serving failure."""


class AdmissionError(ServingError, ValueError):
    """``submit`` refused the request (invalid prompt or queue overload).
    The request is finished with its outcome before this raises."""

    def __init__(self, msg: str, outcome: Outcome = Outcome.FAILED):
        super().__init__(msg)
        self.outcome = outcome


class DeadlineExceeded(ServingError):
    """A per-request TTFT or total deadline passed before completion."""


class KernelFault(ServingError):
    """A substrate GEMM launch failed (injected or real).  The engine
    retries the dispatch once; a persistent fault fails the requests
    bound to it with :attr:`Outcome.FAILED`."""


class TransferFault(KernelFault):
    """A pod->pod K/V handoff failed in the disaggregated engine
    (injected via the ``transfer.kv`` chaos point or real).  The engine
    retries the transfer up to its retry budget; a persistent fault fails
    the sequence with :attr:`Outcome.FAILED`."""


class PagePoolExhausted(ServingError):
    """No page could be obtained even after radix eviction and (under
    ``preempt_policy='youngest'``) preempting every other sequence."""


class EngineCrash(ServingError):
    """The engine was killed mid-stream (chaos ``crash`` point).  Recover
    with ``ServingEngine.restore(...)`` from ``engine.latest_snapshot()``;
    continuations are bit-identical to an uncrashed run."""
