from repro.serving.engine import (  # noqa: F401
    Request, ServeConfig, ServingEngine, Slot)
from repro.serving.errors import (  # noqa: F401
    AdmissionError, DeadlineExceeded, EngineCrash, KernelFault, Outcome,
    PagePoolExhausted, ServingError)
