from repro.serving.engine import (  # noqa: F401
    Request, ServeConfig, ServingEngine, Slot)
