from repro.serving.engine import (  # noqa: F401
    Request, ServeConfig, ServingEngine, Slot)
from repro.serving.disagg import (  # noqa: F401
    DisaggServeConfig, DisaggServingEngine)
from repro.serving.errors import (  # noqa: F401
    AdmissionError, DeadlineExceeded, EngineCrash, KernelFault, Outcome,
    PagePoolExhausted, ServingError, TransferFault)
