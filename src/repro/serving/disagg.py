"""Disaggregated prefill/decode serving over the ``pod`` mesh axis.

The colocated :class:`~repro.serving.engine.ServingEngine` interleaves at
most one prefill-chunk dispatch with one decode dispatch per tick, on one
K/V cache: chunked prefill exists to bound decode latency while long
prompts are admitted, and every prefill chunk a request needs is paid for
*between* the decode steps of everyone else's streams.  Disaggregation
splits the two phases onto disjoint submeshes of the ``pod`` axis —
prefill pods at device window ``[0, prefill_pods)``, decode pods after
them — so each phase runs under its own objective:

* **prefill pods** run chunked prefill into their own cache
  (``pcache``), with the chunk re-picked under the prefill objective
  (:data:`PREFILL_STEP_OVERHEAD`): with no decode stream to protect,
  per-dispatch overhead is the only thing the chunk trades against, so
  the planner leans large.
* **decode pods** run the round-robin fused decode step on the decode
  cache, under decode-role plans (shallow ``best_k`` when layers
  pipeline: the stage-ingress transfer serializes in front of the
  systolic schedule — ``sharding.pp_transfer_terms``).
* a finished prefill **hands off** the request's K/V pod->pod as a
  priced transfer: dense mode moves the slot's cache row, paged mode
  moves exactly the live pages named by the block table (only resident
  K/V crosses the ICI).  The transfer is a failure domain: the
  ``transfer.kv`` chaos point drops it, the engine retries up to
  ``max_retries``, and a persistent fault fails the sequence with a
  typed :class:`~repro.serving.errors.TransferFault` ->
  ``Outcome.FAILED`` — never a silent stall.
* ``pp_stages > 1`` additionally pipelines layers over the ``pod`` axis
  *within* each role (``parallel.pipeline.staged_step`` GPipe stages via
  ``collective_permute``), through ``lm.prefill_step_pp`` /
  ``lm.decode_step_pp``.  Each role's plans then price the stage
  boundary with the role's sign — prefill hides the send behind its
  deep schedule (boundary op, deeper ``best_k``), decode pays it as
  serialized ingress cycles (shallower ``best_k``) — so the same site
  legitimately collapses to different depths on the two submeshes.

**Equivalence contract.**  Greedy streams are bit-identical to the
colocated engine's: K/V writes are per-position projections (chunking
never changes them), the handoff copies bits, and the decode step runs
the same math — pipeline pricing moves plan *depth*, never values.  The
W8A8 exception applies unchanged (per-tile activation scales make tile
geometry part of the numerics), so a quantizing backend keeps the
colocated chunk instead of re-picking.

**Measurement model.**  One process simulates both roles, dispatching
them sequentially, but the engine keeps per-role busy clocks
(``stats["prefill_time_s"]`` / ``stats["decode_time_s"]``) — in a real
deployment the roles run concurrently on disjoint pods, so a request's
disaggregated TTFT excludes the *other* role's work.
``ttft_virtual[rid]`` records exactly that: prefill-pod busy time spent
on the request (admission -> handoff, transfer included) plus decode-pod
busy time to its first token.  The colocated comparator is the wall
TTFT, which pays every interleaved decode dispatch; the disagg makespan
is ``max`` of the role clocks where colocated pays their sum.  The
``disagg`` bench section reports both.

**Pod loss** (``disagg.pod`` chaos point): a decode pod dies mid-stream.
Dense mode preempts every DECODE-resident request (PR 8 recompute-on-
re-admission: ``resume_prompt`` = prompt + generated, re-queued at the
front, re-prefilled on the prefill pods, handed off again) and cold-
starts the decode cache; paged mode routes each decode-resident sequence
through the engine's standard ``_preempt``.  Recovered streams finish
``PREEMPTED_RETRIED`` and are bit-identical to undisturbed runs.

Out of scope: the radix prefix cache (``prefix_cache=True``) assumes one
cache owns the shared pages — cross-pod page ownership is rejected at
construction; paged mode with ``pp_stages > 1`` likewise (the paged
gather/scatter steps have no pipeline variant).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm
from repro.parallel import sharding
from repro.serving.engine import (PREFILL_CHUNK_CHOICES, Request, ServeConfig,
                                  ServingEngine, Slot)
from repro.serving.errors import KernelFault, Outcome, TransferFault
from repro.serving.paged import PagePool, PagedSeq

# Prefill-role chunk objective: with no decode stream to protect, the
# chunk only trades dispatch count against per-step cost, so the fixed
# per-dispatch overhead weighs heavier than in the colocated engine's
# default attention_plan call (which must also keep decode latency
# bounded between chunks).
PREFILL_STEP_OVERHEAD = 4.0


@dataclass(frozen=True)
class DisaggServeConfig(ServeConfig):
    """:class:`ServeConfig` plus the disaggregation geometry.

    ``prefill_pods`` / ``decode_pods`` size the two role submeshes;
    ``pp_stages`` pipelines layers over the ``pod`` axis within each role
    (``1`` = no pipeline; ``> 1`` requires ``prefill_pods == decode_pods
    == pp_stages`` and dense K/V)."""

    prefill_pods: int = 1
    decode_pods: int = 1
    pp_stages: int = 1


class DisaggServingEngine(ServingEngine):
    """Prefill/decode-disaggregated serving engine (see module docstring).

    Scheduling stays the base engine's (admission, deadlines, watchdog,
    snapshots, chaos scope); only the prefill path is re-routed onto the
    prefill role's cache + compiled steps, with the pod->pod K/V handoff
    bridging into the untouched decode path."""

    def __init__(self, cfg: ModelConfig, params,
                 serve_cfg: DisaggServeConfig, *, clock=time.perf_counter):
        if not isinstance(serve_cfg, DisaggServeConfig):
            raise TypeError("DisaggServingEngine needs a DisaggServeConfig "
                            f"(got {type(serve_cfg).__name__})")
        if serve_cfg.prefill_pods < 1 or serve_cfg.decode_pods < 1:
            raise ValueError(
                f"prefill_pods={serve_cfg.prefill_pods} / "
                f"decode_pods={serve_cfg.decode_pods}: each role needs at "
                f"least one pod")
        if serve_cfg.prefix_cache:
            raise ValueError(
                "prefix_cache=True is colocated-only: radix-shared pages "
                "assume one cache owns them, and the disaggregated handoff "
                "would either move shared pages twice or leave the decode "
                "pods reading pages they don't hold")
        pp = max(1, int(serve_cfg.pp_stages))
        if pp > 1:
            if serve_cfg.kv_pages:
                raise ValueError(
                    "pp_stages > 1 requires dense K/V (kv_pages=0): the "
                    "paged gather/scatter steps have no pipeline variant")
            if serve_cfg.prefill_pods != pp or serve_cfg.decode_pods != pp:
                raise ValueError(
                    f"pp_stages={pp} pipelines layers over each role's "
                    f"whole submesh: need prefill_pods == decode_pods == "
                    f"{pp}, got {serve_cfg.prefill_pods}+"
                    f"{serve_cfg.decode_pods}")

        super().__init__(cfg, params, serve_cfg, clock=clock)

        if pp > 1 and self.prefill_mode != "batched":
            raise ValueError("pp_stages > 1 requires the batched prefill "
                             "path (prefill_mode='batched' or 'auto' on a "
                             "supporting family)")

        # Role configs: same model, opposite plan objectives.  pp_role
        # engages sharding.use_pp_pricing inside the lm entry points (the
        # boundary site's plans re-pick under the role's transfer terms);
        # with pp_stages <= 1 the pricing scope is inert and role plans
        # are bit-for-bit the colocated ones.
        self.pp = pp
        if pp > 1:
            self.pcfg = dataclasses.replace(
                cfg, pp_role="prefill", pp_stages=pp, mesh_shape=(pp, 1, 1),
                pod_offset=0)
            self.dcfg = dataclasses.replace(
                cfg, pp_role="decode", pp_stages=pp, mesh_shape=(pp, 1, 1),
                pod_offset=serve_cfg.prefill_pods)
            # fail at construction, not mid-serve: the role windows need
            # prefill_pods + decode_pods devices, and the model must
            # support the pipeline (stage-divisible layers, batched
            # prefill)
            lm._check_pp(self.pcfg)
            lm._check_pp(self.dcfg)
            sharding.mesh_from_config(self.pcfg)
            sharding.mesh_from_config(self.dcfg)
            self._decode = jax.jit(
                lambda p, c, t, pos: lm.decode_step_pp(
                    self.dcfg, p, c, t, pos))
            self._prefill = jax.jit(
                lambda p, c, t, pos, lens: lm.prefill_step_pp(
                    self.pcfg, p, c, t, pos, lens))
        else:
            self.pcfg = dataclasses.replace(cfg, pp_role="prefill")
            self.dcfg = dataclasses.replace(cfg, pp_role="decode")
            self._decode = jax.jit(
                lambda p, c, t, pos: lm.decode_step(self.dcfg, p, c, t, pos))
            if self.prefill_mode == "batched":
                self._prefill = jax.jit(
                    lambda p, c, t, pos, lens: lm.prefill_step(
                        self.pcfg, p, c, t, pos, lens))
            else:
                # token-mode prefill runs the decode-path step, but on the
                # PREFILL pods (prefill cache, prefill-role plans)
                self._decode_p = jax.jit(
                    lambda p, c, t, pos: lm.decode_step(
                        self.pcfg, p, c, t, pos))
            if self.paged:
                self._decode_paged = jax.jit(
                    lambda p, c, t, pos, bt: lm.decode_step_paged(
                        self.dcfg, p, c, t, pos, bt))
                self._prefill_paged = jax.jit(
                    lambda p, c, t, pos, lens, bt: lm.prefill_step_paged(
                        self.pcfg, p, c, t, pos, lens, bt))

        # Prefill-role chunk re-pick (see PREFILL_STEP_OVERHEAD).  An
        # explicit serve_cfg.prefill_chunk still wins, and a W8A8 backend
        # keeps the colocated pick: its per-tile activation scales make
        # chunk geometry part of the numerics, and the equivalence
        # contract outranks the chunk objective there.
        if (self.prefill_mode == "batched" and not serve_cfg.prefill_chunk
                and not substrate.backend_act_quantizes(cfg.gemm_backend)):
            S = serve_cfg.max_seq
            self.prefill_chunk = min(S, max(1, planner.attention_plan(
                S, S, choices=PREFILL_CHUNK_CHOICES,
                step_overhead=PREFILL_STEP_OVERHEAD)))

        # The prefill pods' own K/V cache; self.cache stays the decode
        # pods'.  Paged mode mirrors the page payload arrays with a
        # shared PagePool/block-table numbering, so the handoff is a pure
        # payload copy at the live page indices.
        if self.paged:
            self.pcache = lm.init_paged_cache(
                cfg, serve_cfg.kv_pages, self.page_size)
        else:
            self.pcache = lm.init_cache(
                cfg, serve_cfg.max_batch, serve_cfg.max_seq)
        if pp > 1:
            # commit each cache to its role's device window up front (the
            # pipeline shard_map stages the n_super dim over 'pod'); the
            # handoff device_put below is then a real cross-window move
            self._pmesh = sharding.mesh_from_config(self.pcfg)
            self._dmesh = sharding.mesh_from_config(self.dcfg)
            self.pcache = jax.device_put(
                self.pcache, NamedSharding(self._pmesh, P("pod")))
            self.cache = jax.device_put(
                self.cache, NamedSharding(self._dmesh, P("pod")))

        self.stats.update(kv_transfer_pages=0, kv_transfer_bytes=0,
                          transfer_retries=0, pod_losses=0)
        # virtual role-clock marks per rid (see module docstring):
        # p0 = prefill busy at admission; pused = prefill busy spent on
        # the request (handoff inclusive); d0 = decode busy at handoff
        self._vt: Dict[int, dict] = {}
        self.ttft_virtual: Dict[int, float] = {}

    # ---------------------------------------------------------- handoff
    def _transfer_ok(self, detail: str) -> bool:
        """One ``transfer.kv`` chaos draw per attempt, retried up to the
        engine's retry budget.  True = the K/V move may proceed."""
        if self._chaos is None:
            return True
        retries = max(0, self.sc.max_retries)
        for attempt in range(retries + 1):
            if not self._chaos.fire("transfer.kv", detail):
                return True
            if attempt < retries:
                self.stats["transfer_retries"] += 1
        return False

    def _mark_handoff(self, req: Request):
        m = self._vt.get(req.rid)
        if m is not None:
            m["pused"] = self.stats["prefill_time_s"] - m["p0"]
            m["d0"] = self.stats["decode_time_s"]

    def _handoff_dense(self, slot: Slot) -> bool:
        """Move slot's prefilled cache row pod->pod.  The full row is
        copied: positions past ``prefill_len`` hold garbage, but decode
        writes each position before it is ever attended (the same
        write-before-read argument the fused decode step already relies
        on).  False = persistent transfer fault, request failed."""
        b = slot.index
        req = slot.req
        if not self._transfer_ok(f"rid={req.rid} slot={b}"):
            err = TransferFault(
                f"request {req.rid}: pod->pod K/V handoff dropped "
                f"{self.sc.max_retries + 1} times (retry budget spent)")
            self._finish(req, Outcome.FAILED,
                         f"{type(err).__name__}: {err}")
            slot.release()
            return False
        t0 = self.clock()
        row = jax.tree_util.tree_map(lambda p: p[:, b], self.pcache)
        if self.pp > 1:
            # the ICI hop: pull the row off the prefill window onto the
            # decode window before splicing it into the decode cache
            row = jax.device_put(row, NamedSharding(self._dmesh, P("pod")))
        self.cache = jax.tree_util.tree_map(
            lambda r, d: d.at[:, b].set(r), row, self.cache)
        jax.block_until_ready(self.cache)
        # transfer cost is prefill-pod egress: it gates the handoff, not
        # the decode pods' in-flight streams
        self.stats["prefill_time_s"] += self.clock() - t0
        self.stats["kv_transfer_bytes"] += int(sum(
            leaf[:, b].nbytes
            for leaf in jax.tree_util.tree_leaves(self.pcache)))
        self._mark_handoff(req)
        return True

    def _handoff_paged(self, seq: PagedSeq) -> bool:
        """Move exactly the live pages the block table names — positions
        ``[0, prefill_len)`` span the first ``ceil(prefill_len/page)``
        table entries — not the pool.  False = persistent fault."""
        req = seq.req
        n_pg = -(-seq.prefill_len // self.page_size) if seq.prefill_len \
            else 0
        idx = sorted({int(pg) for pg in seq.block_table[:n_pg]
                      if pg != PagePool.SCRATCH})
        if not idx:
            self._mark_handoff(req)
            return True
        if not self._transfer_ok(f"rid={req.rid} pages={len(idx)}"):
            err = TransferFault(
                f"request {req.rid}: pod->pod K/V handoff of {len(idx)} "
                f"pages dropped {self.sc.max_retries + 1} times "
                f"(retry budget spent)")
            self._finish(req, Outcome.FAILED,
                         f"{type(err).__name__}: {err}")
            self._release_paged(seq)
            return False
        ix = jnp.asarray(idx, jnp.int32)
        t0 = self.clock()
        self.cache = jax.tree_util.tree_map(
            lambda p, d: d.at[:, ix].set(p[:, ix]), self.pcache, self.cache)
        jax.block_until_ready(self.cache)
        self.stats["prefill_time_s"] += self.clock() - t0
        self.stats["kv_transfer_pages"] += len(idx)
        self.stats["kv_transfer_bytes"] += int(sum(
            leaf[:, ix].nbytes
            for leaf in jax.tree_util.tree_leaves(self.pcache)))
        self._mark_handoff(req)
        return True

    # ------------------------------------------------------------ prefill
    def _prefill_tick(self):
        if self.paged:
            self._prefill_tick_paged()
            return
        pre = [s for s in self.slots if s.state == Slot.PREFILL]
        if not pre:
            return
        if self.prefill_mode == "token":
            for slot in pre:
                self._prefill_token_by_token(slot)
            return
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = self._pos_vector()
        lens = np.zeros(B, np.int32)
        for s in pre:
            c = min(C, s.prefill_len - s.prefill_done)
            toks[s.index, :c] = s.tokens[s.prefill_done:
                                         s.prefill_done + c]
            lens[s.index] = c
        t0 = self.clock()
        d0 = sum(substrate.DISPATCH_COUNTS.values())
        try:
            _, self.pcache, _ = self._guarded_dispatch(
                lambda: (None, self._prefill(
                    self.params, self.pcache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(lens))[1]),
                rows=())
        except KernelFault as exc:
            for s in pre:
                self._finish(s.req, Outcome.FAILED,
                             f"KernelFault during prefill: {exc}")
                s.release()
            return
        jax.block_until_ready(self.pcache)
        self.stats["prefill_time_s"] += self.clock() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        self._count_prefill_launches(d0)
        for s in pre:
            s.finish_chunk(int(lens[s.index]))
            if s.state == Slot.DECODE:
                self._handoff_dense(s)

    def _prefill_tick_paged(self):
        pre = [s for s in self.active if s.state == PagedSeq.PREFILL]
        if not pre:
            return
        sel = pre[:self.sc.max_batch]
        B, C = self.sc.max_batch, self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        pos = np.zeros(B, np.int32)
        lens = np.zeros(B, np.int32)
        bt = np.zeros((B, self.pages_per_seq), np.int32)
        for r, s in enumerate(sel):
            c = min(C, s.prefill_len - s.prefill_done)
            toks[r, :c] = s.prompt[s.prefill_done:s.prefill_done + c]
            pos[r] = s.prefill_done
            lens[r] = c
            bt[r] = s.block_table
        t0 = self.clock()
        d0 = sum(substrate.DISPATCH_COUNTS.values())
        try:
            _, self.pcache, _ = self._guarded_dispatch(
                lambda: (None, self._prefill_paged(
                    self.params, self.pcache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(lens),
                    jnp.asarray(bt))[1]),
                rows=())
        except KernelFault as exc:
            for s in sel:
                self._finish(s.req, Outcome.FAILED,
                             f"KernelFault during prefill: {exc}")
                self._release_paged(s)
            return
        jax.block_until_ready(self.pcache)
        self.stats["prefill_time_s"] += self.clock() - t0
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += int(lens.sum())
        self._count_prefill_launches(d0)
        for r, s in enumerate(sel):
            s.prefill_done += int(lens[r])
            if s.prefill_done >= s.prefill_len:
                s.to_decode()
                self._handoff_paged(s)

    def _prefill_token_by_token(self, slot: Slot):
        req = slot.req
        for i, t in enumerate(slot.tokens[:-1]):
            toks = np.zeros(self.sc.max_batch, np.int32)
            toks[slot.index] = t
            pos_v = self._pos_vector()
            pos_v[slot.index] = i
            t0 = self.clock()
            try:
                _, self.pcache, _ = self._guarded_dispatch(
                    lambda tk=toks, pv=pos_v: (None, self._decode_p(
                        self.params, self.pcache, jnp.asarray(tk),
                        jnp.asarray(pv))[1]),
                    rows=())
            except KernelFault as exc:
                self._finish(req, Outcome.FAILED,
                             f"KernelFault during prefill: {exc}")
                slot.release()
                return
            jax.block_until_ready(self.pcache)
            self.stats["prefill_time_s"] += self.clock() - t0
            self.stats["prefill_dispatches"] += 1
            self.stats["prefill_tokens"] += 1
            slot.prefill_done = i + 1
        slot._to_decode()
        self._handoff_dense(slot)

    # ----------------------------------------------- virtual role clocks
    def _residents(self):
        if self.paged:
            return list(self.active)
        return [s for s in self.slots if s.req is not None]

    def _admit(self):
        before = {s.req.rid for s in self._residents()}
        super()._admit()
        pnow = self.stats["prefill_time_s"]
        decode_state = PagedSeq.DECODE if self.paged else Slot.DECODE
        for s in self._residents():
            if s.req.rid in before:
                continue
            self._vt[s.req.rid] = {"p0": pnow}
            if s.state == decode_state:
                # single-token prompt: nothing to prefill or hand off
                self._mark_handoff(s.req)

    def _decode_tick(self):
        decode_state = PagedSeq.DECODE if self.paged else Slot.DECODE
        pending = [s.req for s in self._residents()
                   if s.state == decode_state and not s.req.out_tokens]
        super()._decode_tick()
        dnow = self.stats["decode_time_s"]
        for req in pending:
            if req.out_tokens and req.rid not in self.ttft_virtual:
                m = self._vt.get(req.rid)
                if m is not None and "d0" in m:
                    self.ttft_virtual[req.rid] = \
                        m["pused"] + (dnow - m["d0"])

    # ----------------------------------------------------------- pod loss
    def _pod_loss(self):
        """A decode pod died: every decode-resident stream preempts and
        re-admits through the PR 8 recompute path (prefilled again on the
        prefill pods, handed off again); PREFILL residents live on the
        surviving role and continue untouched."""
        self.stats["pod_losses"] += 1
        if self.paged:
            for s in [q for q in self.active
                      if q.state == PagedSeq.DECODE]:
                self._preempt(s)
            return
        lost = False
        for slot in self.slots:
            if slot.state != Slot.DECODE:
                continue
            req = slot.req
            req.preemptions += 1
            self.stats["preemptions"] += 1
            req.resume_prompt = list(req.prompt) + list(req.out_tokens)
            slot.release()
            self.queue.insert(0, req)
            lost = True
        if lost:
            # the replacement decode pod starts cold; every re-admitted
            # stream rebuilds its row through prefill + handoff
            self.cache = jax.tree_util.tree_map(jnp.zeros_like, self.cache)

    def _step_inner(self):
        if self._chaos is not None and self._chaos.fire(
                "disagg.pod", f"tick={self._tick}"):
            self._pod_loss()
        return super()._step_inner()

    # ------------------------------------------------- snapshot / restore
    def snapshot(self) -> dict:
        snap = super().snapshot()
        snap["pcache"] = jax.tree_util.tree_map(np.asarray, self.pcache)
        return snap

    def _load_snapshot(self, snap: dict):
        super()._load_snapshot(snap)
        if "pcache" in snap:
            self.pcache = jax.tree_util.tree_map(jnp.asarray,
                                                 snap["pcache"])
