from repro.data.pipeline import (  # noqa: F401
    DataConfig, SyntheticLM, MemmapCorpus, make_pipeline, Prefetcher,
)
