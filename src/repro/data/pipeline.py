"""Deterministic, host-sharded data pipeline.

Two sources behind one iterator interface:
  * SyntheticLM  — seeded Zipf-ish token stream (CI / dry-runs / perf work);
  * MemmapCorpus — np.memmap-backed token file (production path).

Sharding contract: every host draws only its slice of the global batch
(``host_index``/``host_count``); step -> sample mapping is a pure function of
(seed, step), so restarts resume exactly and elastic re-sharding (a host
count change) re-partitions the same global stream without duplication.
"""
from __future__ import annotations

import threading
import queue
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32000
    seed: int = 1234
    path: str = ""             # memmap token file ("" -> synthetic)
    dtype: str = "int32"


class SyntheticLM:
    """Deterministic Zipf-distributed tokens with structure (repeats) so a
    model can actually reduce loss on it."""

    def __init__(self, cfg: DataConfig, host_index=0, host_count=1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        out_tok = np.empty((self.local_batch, cfg.seq_len + 1), np.int32)
        for b in range(self.local_batch):
            g = self.host_index * self.local_batch + b
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, g]))
            z = rng.zipf(1.3, size=cfg.seq_len + 1)
            toks = (z % (cfg.vocab_size - 2)) + 2
            # inject copy structure: second half repeats the first quarter
            q = (cfg.seq_len + 1) // 4
            toks[2 * q:3 * q] = toks[:q]
            out_tok[b] = toks
        return {"tokens": out_tok[:, :-1],
                "labels": out_tok[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapCorpus:
    """Token file of shape (n_tokens,) read as strided windows."""

    def __init__(self, cfg: DataConfig, host_index=0, host_count=1):
        assert cfg.path
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.dtype(cfg.dtype),
                                mode="r")
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        # one global permutation draw per step; hosts take disjoint slices
        idx = rng.integers(0, self.n_windows, size=cfg.global_batch)
        lo = self.host_index * self.local_batch
        windows = idx[lo:lo + self.local_batch]
        toks = np.stack([
            self.tokens[w * cfg.seq_len:w * cfg.seq_len + cfg.seq_len + 1]
            for w in windows]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of the next N batches."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()


def make_pipeline(cfg: DataConfig, host_index=0, host_count=1,
                  start_step: int = 0, prefetch: int = 2):
    src = (MemmapCorpus(cfg, host_index, host_count) if cfg.path
           else SyntheticLM(cfg, host_index, host_count))
    if prefetch:
        return Prefetcher(src, start_step=start_step, depth=prefetch)
    return src
