"""Checkpointing: async, atomic, keep-N, resume.

Pytrees are flattened to path-keyed arrays in one .npz per (step, host).
Writes go to a temp name then rename (atomic on POSIX) and a manifest.json
records the latest durable step — a half-written checkpoint is never
visible.  ``save_async`` snapshots to host memory synchronously (cheap) and
writes on a background thread so the train loop never blocks on disk; this
is the restart story for the fault-tolerance manager (runtime.fault).
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree, path: str):
    arrays = _flatten(tree)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path)


def load_pytree(template, path: str):
    """Restore arrays into the structure of `template`."""
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    flat = jax.tree_util.tree_flatten_with_path(template)[0]
    out = []
    for (p, leaf) in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        if arr.dtype.kind == "V":      # ml_dtypes (bf16/f8) round-trip raw
            arr = arr.view(np.dtype(leaf.dtype))
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.host = host_index
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -------------------------------------------------- paths & manifest
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}_h{self.host}.npz")

    def _manifest(self) -> str:
        return os.path.join(self.dir, f"manifest_h{self.host}.json")

    def latest_step(self):
        try:
            return json.load(open(self._manifest()))["step"]
        except Exception:
            steps = self.all_steps()
            return steps[-1] if steps else None

    def all_steps(self):
        pat = re.compile(rf"ckpt_(\d+)_h{self.host}\.npz$")
        steps = sorted(int(m.group(1)) for f in os.listdir(self.dir)
                       if (m := pat.match(f)))
        return steps

    # -------------------------------------------------- save / restore
    def save(self, step: int, tree):
        save_pytree(tree, self._path(step))
        with open(self._manifest() + ".tmp", "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(self._manifest() + ".tmp", self._manifest())
        self._gc()

    def save_async(self, step: int, tree):
        """Snapshot to host memory now; write in the background."""
        self.wait()
        snapshot = _flatten(tree)         # device->host copy happens here

        def _write():
            tmp = self._path(step) + ".tmp.npz"
            np.savez(tmp, **snapshot)
            os.replace(tmp, self._path(step))
            with open(self._manifest() + ".tmp", "w") as f:
                json.dump({"step": step, "time": time.time()}, f)
            os.replace(self._manifest() + ".tmp", self._manifest())
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template, step=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return load_pytree(template, self._path(step)), step

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
