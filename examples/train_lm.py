"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on the synthetic pipeline, with checkpoint/restart and the ArrayFlex GEMM
plan report.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

(~100M params: 8 layers x d_model 768 x vocab 32k.  On the CPU container
this takes a while at full size; --small trains a 10M model instead.)
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    args = ap.parse_args()
    if args.small:
        argv = ["--arch", "qwen2-0.5b", "--reduced",
                "--d-model", "256", "--n-layers", "4", "--vocab", "8192",
                "--steps", str(args.steps), "--batch", "8", "--seq", "256",
                "--ckpt-dir", "results/ckpt_example",
                "--arrayflex-report"]
    else:
        argv = ["--arch", "qwen2-0.5b", "--reduced",
                "--d-model", "768", "--n-layers", "8", "--d-ff", "3072",
                "--vocab", "32768",
                "--steps", str(args.steps), "--batch", "8", "--seq", "512",
                "--ckpt-dir", "results/ckpt_example",
                "--arrayflex-report"]
    losses = train.main(argv)
    assert losses[-1] < losses[0], "loss should decrease"
    print("example complete: loss decreased "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
