"""Fault-tolerant training demo: the FaultToleranceManager drives a train
loop through an injected node failure; training resumes from the last async
checkpoint and reaches the target step with no lost or duplicated batches
(the data pipeline is a pure function of the step index).

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import api, lm
from repro.optim import OptConfig, adamw_init
from repro.runtime import FaultToleranceManager, HeartbeatMonitor


def main():
    shutil.rmtree("results/ckpt_ft_example", ignore_errors=True)
    cfg = reduced(ARCHS["qwen2-0.5b"])
    oc = OptConfig(total_steps=60)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, oc)
    dc = DataConfig(seq_len=64, global_batch=4, vocab_size=cfg.vocab_size)
    src = SyntheticLM(dc)
    train_step = jax.jit(api.make_train_step(cfg, oc))

    state = {"params": params, "opt": opt}

    def step_fn(st, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = train_step(st["params"], st["opt"], batch)
        step_fn.last_loss = float(m["loss"])
        return {"params": p, "opt": o}

    crashed = {"done": False}

    def inject(step):
        if step == 25 and not crashed["done"]:
            crashed["done"] = True
            print(f"  !! injected node failure at step {step}")
            raise RuntimeError("simulated hardware failure")

    mgr = CheckpointManager("results/ckpt_ft_example", keep=3)
    ft = FaultToleranceManager(mgr, HeartbeatMonitor(1), ckpt_every=10)
    state, steps, restarts = ft.run(state, step_fn, src, 40,
                                    inject_failure=inject)
    print(f"reached step {steps} with {restarts} restart(s); "
          f"final loss {step_fn.last_loss:.4f}")
    assert steps == 40 and restarts == 1
    print("example complete")


if __name__ == "__main__":
    main()
