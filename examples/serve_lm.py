"""Serve a small model with batched requests through the continuous-batching
engine (prefill + fused decode ticks), reporting ArrayFlex's decode-regime
plan for the same model.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.configs import ARCHS, SHAPES
from repro.core import planner
from repro.launch import serve


def main():
    # the decode-regime ArrayFlex plan (small-T: where the paper's
    # technique pays off for LLMs — see benchmarks/paper_figs.py)
    cfg_full = ARCHS["qwen2-0.5b"]
    plan = planner.plan_model(cfg_full, SHAPES["decode_32k"])
    print(f"ArrayFlex decode plan for {cfg_full.name}: "
          f"latency -{plan['latency_saving']*100:.1f}%, "
          f"EDP {plan['edp_gain']:.2f}x vs fixed-pipeline SA")
    ks = {}
    for p in plan["plans"]:
        ks.setdefault(p.k, []).append(p.gemm.name)
    for k, names in sorted(ks.items()):
        print(f"  k={k}: {len(names)} GEMM kinds e.g. {names[:3]}")

    reqs = serve.main(["--arch", "qwen2-0.5b", "--requests", "6",
                       "--max-new", "16"])
    assert all(len(r.out_tokens) == 16 for r in reqs)
    print("example complete")


if __name__ == "__main__":
    main()
