"""Quickstart: the ArrayFlex core in five minutes.

1. Reproduce the paper's headline numbers (latency/power/EDP vs a fixed
   pipeline SA) on the three evaluated CNNs.
2. Run the cycle-accurate simulator (bit-exact carry-save datapath).
3. Plan + execute a GEMM through the Pallas kernel with the planner's k.
4. Run a whole transformer with every GEMM dispatched through the
   ArrayFlex substrate (gemm_backend="arrayflex").
5. Quantize to int8 weights (gemm_backend="arrayflex_int8"): the int8
   datapath re-picks the collapse depth per layer and the weight memo
   quantizes each weight exactly once.
6. Audit the substrate contract: one command proves every GEMM in the
   traced model routes through the planner (and shows what a violation
   looks like).
7. Serve with a paged K/V cache (`--kv-pages` on repro.launch.serve):
   block-table paged attention with planner-picked page geometry and
   radix prefix reuse — more resident sequences than max_batch, shared
   system prompts prefilled once, streams bit-identical to the dense
   cache.
8. Go W8A8 (gemm_backend="arrayflex_w8a8"): dynamic per-tile activation
   quantization in the kernel prologue engages the int8 x int8 -> int32
   MAC path, and the Eq.(5') activation-quantize boundary term alone
   re-picks the collapse depth at the pinned decode shape.
9. Disaggregate prefill from decode (DisaggServingEngine): the two
   phases run on disjoint pod submeshes with opposite plan objectives —
   the stage-boundary transfer deepens prefill's collapse depth and
   shallows decode's — while the pod->pod K/V handoff keeps greedy
   streams bit-identical to the colocated engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cnn_shapes, planner, simulator, timing
from repro.kernels import ops, ref, substrate


def main():
    # -- 1. the paper's evaluation ---------------------------------------
    print("=== ArrayFlex vs conventional SA (paper Figs. 8/9) ===")
    for net in ("resnet34", "mobilenet", "convnext"):
        gemms = [planner.GEMM(f"l{i}", *mnt)
                 for i, mnt in enumerate(cnn_shapes.network_mnt(net))]
        res = planner.plan_network(gemms, 128, 128)
        print(f"  {net:10s}: latency -{res['latency_saving']*100:4.1f}%  "
              f"power -{res['power_saving']*100:4.1f}%  "
              f"EDP {res['edp_gain']:.2f}x")

    # -- 2. cycle-accurate simulation ------------------------------------
    print("\n=== Simulator: ResNet-34 layer 28 tile on a 16x16 array ===")
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.randint(-128, 127, (12, 16)), jnp.int32)
    B = jnp.asarray(rng.randint(-128, 127, (16, 16)), jnp.int32)
    for k in (1, 2, 4):
        X, cycles = simulator.simulate_tile(A, B, k)
        ok = np.array_equal(np.asarray(X), np.asarray(A) @ np.asarray(B))
        period = timing.DEFAULT_TIMING.clock_period_ps(k)
        print(f"  k={k}: {cycles:3d} cycles x {period:5.1f} ps = "
              f"{cycles*period/1000:6.2f} ns   exact={ok}")

    # -- 3. planner-driven Pallas GEMM -----------------------------------
    print("\n=== Pallas kernel with planned collapse ===")
    x = jnp.asarray(rng.randn(256, 1024), jnp.bfloat16)
    w = jnp.asarray(rng.randn(1024, 512), jnp.bfloat16)
    k = ops.plan_collapse(512, 1024, 256)
    y = ops.arrayflex_matmul(x, w, k_collapse=k)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - ref.gemm_ref(x, w).astype(jnp.float32))))
    print(f"  planned k={k}; kernel vs oracle max err {err:.3e}")

    # -- 4. whole model through the substrate ----------------------------
    print("\n=== Transformer GEMMs through the ArrayFlex substrate ===")
    from repro.configs import get_config, reduced
    from repro.models import lm
    cfg = reduced(get_config("qwen2-0.5b"), compute_dtype="float32",
                  param_dtype="float32")
    cfg_af = reduced(get_config("qwen2-0.5b"), compute_dtype="float32",
                     param_dtype="float32", gemm_backend="arrayflex")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.randint(2, cfg.vocab_size, (2, 12)))
    lx, _, _ = lm.forward(cfg, params, {"tokens": toks})
    la, _, _ = lm.forward(cfg_af, params, {"tokens": toks})
    print(f"  xla vs arrayflex logits max diff "
          f"{float(jnp.max(jnp.abs(lx - la))):.3e}")
    print("  per-site plans (planner Eq.6 selections driving execution):")
    for site, p in sorted(substrate.SITE_PLANS.items()):
        print(f"    {site:12s} M={p.M:4d} N={p.N:4d} T={p.T:4d} -> k={p.k} "
              f"(predicted saving {100 * p.saving:4.1f}%)")

    # -- 5. quantized int8 backend ---------------------------------------
    print("\n=== Int8 weights, fp32 accumulation, int8-planned k ===")
    cfg_i8 = reduced(get_config("qwen2-0.5b"), compute_dtype="float32",
                     param_dtype="float32", gemm_backend="arrayflex_int8")
    substrate.clear_quant_cache()
    l8, _, _ = lm.forward(cfg_i8, params, {"tokens": toks})
    print(f"  fp32-arrayflex vs int8 logits max diff "
          f"{float(jnp.max(jnp.abs(la - l8))):.3e} "
          f"(documented tolerance 0.06: quantization noise only)")
    print(f"  weight-quantize memo: {substrate.quantize_cache_info()}")
    # the per-layer reconfiguration the paper argues for: the SAME shape
    # plans a different collapse depth per datapath precision
    M, N, T = 896, 4864, 512        # qwen2-0.5b mlp.wo, 512-row decode
    k_fp = ops.plan_collapse(M, N, T)
    k_i8 = ops.plan_collapse(M, N, T, precision="int8")
    pf = substrate.plan_gemm(M, N, T, "arrayflex")
    p8 = substrate.plan_gemm(M, N, T, "arrayflex_int8")
    print(f"  mlp.wo (M={M}, N={N}, T={T}): fp32 k={k_fp}, int8 k={k_i8} "
          f"-> int8 Eq.(6') speedup {pf.t_pred_ps / p8.t_pred_ps:.2f}x")

    # -- 6. audit the substrate contract ---------------------------------
    print("\n=== Static analysis: every GEMM routes through the planner ===")
    print("  (full matrix: PYTHONPATH=src python -m repro.analysis.audit)")
    from repro.analysis import jaxpr_audit
    substrate.clear_plan_cache()
    found = jaxpr_audit.audit_model(cfg_af, label="qwen2/arrayflex")
    errs = [f for f in found if f.severity == "error"]
    print(f"  traced forward/decode/prefill: {len(errs)} error(s) "
          f"({len(found) - len(errs)} warning(s)) -> "
          f"{'contract holds' if not errs else 'CONTRACT BROKEN'}")
    # what a violation looks like: a raw `@` GEMM that bypasses dispatch
    bypass = jax.make_jaxpr(lambda a, b: a @ b)(
        jnp.ones((8, 16)), jnp.ones((16, 8)))
    for f in jaxpr_audit.audit_closed_jaxpr(bypass, label="bypass-demo"):
        print(f"  seeded bypass -> {f}")
    # and the runtime twin: strict mode rejects unknown site labels
    with substrate.strict_audit_scope():
        try:
            substrate.gemm(jnp.ones((4, 8)), jnp.ones((8, 4)),
                           site="not.a.site")
        except RuntimeError as e:
            print(f"  strict-audit dispatch -> {e}")
    substrate.clear_plan_cache()

    # -- 7. paged-KV serving with radix prefix reuse ---------------------
    print("\n=== Paged K/V serving (--kv-pages on repro.launch.serve) ===")
    from repro.serving import ServeConfig, ServingEngine
    from repro.serving.engine import Request
    system = list(range(3, 19))                 # 16-token shared prompt
    prompts = [system + [40 + i] for i in range(5)]

    def serve(kv_pages, prefix_cache=False):
        engine = ServingEngine(cfg, params, ServeConfig(
            max_batch=2, max_seq=32, prefill_mode="batched",
            prefill_chunk=8, kv_pages=kv_pages, prefix_cache=prefix_cache))
        reqs = [Request(prompt=p, max_new_tokens=3, rid=i)
                for i, p in enumerate(prompts)]
        engine.submit(reqs[0])                  # leader publishes its pages
        while not reqs[0].out_tokens:
            engine.step()
        for r in reqs[1:]:
            engine.submit(r)
        engine.run_to_completion()
        return [r.out_tokens for r in reqs], engine

    dense_out, _ = serve(0)
    paged_out, eng = serve(24, prefix_cache=True)
    st = eng.stats
    print(f"  planner page_plan -> {eng.page_size} tokens/page "
          f"({eng.pool.n_pages} pages, "
          f"{eng.kv_cache_bytes() // 1024} KiB pool)")
    print(f"  {st['concurrency_peak']} resident sequences on a "
          f"max_batch=2 engine; peak {st['pages_used_peak']} pages")
    print(f"  prefix reuse: {st['prefix_hit_tokens']} prompt tokens "
          f"served from shared pages "
          f"({st['prefill_gemm_dispatches']} prefill GEMM launches)")
    print(f"  paged streams identical to dense: {paged_out == dense_out}")

    # -- 8. W8A8: the int8 x int8 MAC path engages ------------------------
    print("\n=== W8A8: dynamic per-tile activation quantization ===")
    cfg_w8 = reduced(get_config("qwen2-0.5b"), compute_dtype="float32",
                     param_dtype="float32", gemm_backend="arrayflex_w8a8")
    lw, _, _ = lm.forward(cfg_w8, params, {"tokens": toks})
    print(f"  fp32-arrayflex vs w8a8 logits max diff "
          f"{float(jnp.max(jnp.abs(la - lw))):.3e} "
          f"(documented tolerance 0.12: weight + activation rounding)")
    # the acceptance jaxpr fact: the traced dispatch stages int8 x int8
    # dot_generals with an int32 result — the integer MAC path is real
    closed = jax.make_jaxpr(
        lambda a, b: substrate.gemm(a, b, backend="arrayflex_w8a8"))(
            jnp.ones((8, 256), jnp.float32), jnp.ones((256, 32), jnp.float32))
    n_i8 = sum(1 for eqn in jaxpr_audit.iter_eqns(closed.jaxpr)
               if eqn.primitive.name == "dot_general"
               and {str(v.aval.dtype) for v in eqn.invars} == {"int8"})
    print(f"  int8 x int8 dot_generals staged in-kernel: {n_i8}")
    # Eq.(5') quantize boundary term: at the pinned decode shape the actq
    # stage ALONE deepens the argmin (w8a8 without it still picks k=2)
    k_w8_no = ops.plan_collapse(M, N, T, precision="w8a8")
    k_w8 = ops.plan_collapse(M, N, T, precision="w8a8", actq_ops=1)
    pw = substrate.plan_gemm(M, N, T, "arrayflex_w8a8")
    print(f"  mlp.wo (M={M}, N={N}, T={T}): fp32 k={k_fp}, "
          f"w8a8-unpriced k={k_w8_no}, w8a8+actq k={k_w8} "
          f"-> Eq.(6') speedup {pf.t_pred_ps / pw.t_pred_ps:.2f}x vs fp32")
    rows = planner.precision_table(
        cfg_w8, planner.ShapeConfig("demo", 8, 2, "train"))
    r0 = rows[0]
    print(f"  precision_table[{r0['gemm'].name}]: " + "  ".join(
        f"{p}: k={r0['plans'][p].k} t={r0['plans'][p].t_abs_ps / 1e3:.1f}ns"
        for p in ("fp32", "int8", "w8a8")))

    # -- 9. disaggregated prefill/decode serving --------------------------
    print("\n=== Disaggregated serving (--prefill-pods / --decode-pods) ===")
    from repro.parallel import sharding
    from repro.serving import DisaggServeConfig, DisaggServingEngine
    kw = dict(max_batch=2, max_seq=32, prefill_chunk=8)

    def disagg_serve(engine_cls, sc):
        engine = engine_cls(cfg, params, sc)
        reqs = [Request(prompt=p, max_new_tokens=3, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run_to_completion()
        return [r.out_tokens for r in reqs], engine

    colo_out, _ = disagg_serve(ServingEngine, ServeConfig(**kw))
    dis_out, deng = disagg_serve(
        DisaggServingEngine,
        DisaggServeConfig(**kw, prefill_pods=1, decode_pods=1))
    st = deng.stats
    print(f"  K/V handoff: {st['kv_transfer_bytes'] // 1024} KiB pod->pod "
          f"across {len(prompts)} requests")
    print(f"  disagg streams identical to colocated: "
          f"{dis_out == colo_out}")
    vt = sum(deng.ttft_virtual.values()) / len(deng.ttft_virtual)
    print(f"  mean virtual TTFT {vt * 1e3:.1f} ms (per-role clocks: "
          f"neither role pays the other's interleaved dispatches)")
    # the per-role plan objective: at the pinned pipeline boundary site
    # the SAME shape collapses deeper on prefill pods than decode pods
    ep1 = substrate.Epilogue(kind="none", bias=True)
    for T_ in (128, 2048):
        ks = {}
        for role in ("prefill", "decode"):
            t_ops, t_cyc = sharding.pp_transfer_terms(role, 2, T_, 896)
            ks[role] = substrate.plan_gemm(
                896, 896, T_, "arrayflex", epilogue=ep1,
                shard=substrate.ShardSig(transfer_ops=t_ops,
                                         transfer_cycles=t_cyc)).k
        print(f"  attn.wq boundary (M=K=896, pp=2, T={T_}): "
              f"prefill k={ks['prefill']} vs decode k={ks['decode']}")


if __name__ == "__main__":
    main()
