"""Cluster-scale transparent pipelining (beyond-paper, DESIGN.md §3.3).

Plans the pipeline depth for a multi-pod deployment with the paper's
Eq.(6)/(7) math, then runs the actual GPipe schedule over a 4-way 'pod'
mesh (fake devices in a subprocess) and checks it against the sequential
execution.

Run:  PYTHONPATH=src python examples/pipeline_parallel.py
"""
import os
import subprocess
import sys
import textwrap

from repro.parallel import pipeline as cp


def main():
    print("=== pipeline-depth planning (Eq. 6/7 at pod scale) ===")
    for M in (4, 16, 64):
        c = cp.PipelineCost(n_pods=8, microbatches=M, layer_time_ms=2.0,
                            overhead_ms=0.5)
        p = cp.plan(c)
        print(f"  microbatches={M:3d}: collapse k={p['k']} "
              f"(k_hat={p['k_hat']:.2f}) -> {p['stages']} stages, "
              f"latency {p['latency_ms']:.1f}ms "
              f"(vs {p['latency_ms_k1']:.1f}ms at k=1), "
              f"bubble {p['bubble_fraction']*100:.0f}%")

    print("\n=== executing the GPipe schedule on a 4-pod mesh ===")
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        import sys; sys.path.insert(0, "src")
        from repro.parallel.pipeline import make_pipelined
        mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("pod",))
        rng = np.random.RandomState(0)
        w = jnp.asarray(rng.randn(4, 16, 16) * 0.3, jnp.float32)
        x = jnp.asarray(rng.randn(8, 4, 16), jnp.float32)
        stage = lambda wi, h: jnp.tanh(h @ wi)
        piped = jax.jit(make_pipelined(stage, mesh))
        got = piped(w, x)
        want = x
        for i in range(4): want = jnp.tanh(want @ w[i])
        err = float(jnp.max(jnp.abs(got - want)))
        print(f"  4 stages x 8 microbatches: max err vs sequential {err:.2e}")
        assert err < 1e-5
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    print(out.stdout.strip() or out.stderr[-500:])
    assert "max err" in out.stdout
    print("example complete")


if __name__ == "__main__":
    main()
