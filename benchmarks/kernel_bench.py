"""Kernel micro-benchmarks (CPU interpret mode: correctness + structural
cost; wall-times are NOT TPU numbers and are reported only for relative
comparison of schedule shapes)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import timing
from repro.kernels import ref
from repro.kernels.arrayflex_gemm import arrayflex_gemm


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def gemm_collapse_sweep():
    """ArrayFlex GEMM at each collapse depth + the planner's pick."""
    rows = []
    M, K, N = 256, 1024, 256
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    want = np.float32(ref.gemm_ref(x, w))
    for k in (1, 2, 4):
        f = jax.jit(lambda a, b, kk=k: arrayflex_gemm(a, b, bk=128,
                                                      k_collapse=kk))
        us = _time(f, x, w)
        got = np.float32(f(x, w))
        err = float(np.max(np.abs(got - want)))
        cycles = timing.total_cycles(N, K, M, 128, 128, k)
        t_model = timing.t_abs_ps(N, K, M, 128, 128, k) / 1e6
        rows.append({"bench": "gemm_collapse", "k": k,
                     "us_per_call_interpret": round(us, 1),
                     "max_abs_err": f"{err:.1e}",
                     "model_cycles": cycles,
                     "model_time_us": round(t_model, 3)})
    kbest = timing.best_k(N, K, M, 128, 128)
    return rows, f"planner best_k={kbest} (model-time argmin)"
