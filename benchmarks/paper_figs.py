"""Reproductions of the paper's figures as benchmark functions.

Each returns (rows, derived) where rows are CSV-able dicts and derived is a
headline scalar matched against the paper's claim.
"""
from __future__ import annotations

import dataclasses

from repro.core import cnn_shapes, planner, timing


def fig5_layer_tradeoff():
    """Fig. 5: exec time of ResNet-34 layers 20/28 vs collapse depth k on a
    132x132 SA (k in 1..4, linear clock model so k=3 is defined)."""
    tp = dataclasses.replace(timing.DEFAULT_TIMING, mode="linear",
                             supported_k=(1, 2, 3, 4))
    rows = []
    layers = {"layer20": (256, 2304, 196), "layer28": (512, 2304, 49)}
    best = {}
    for name, (M, N, T) in layers.items():
        conv = timing.t_abs_conventional_ps(M, N, T, 132, 132, tp) / 1e6
        times = {}
        for k in (1, 2, 3, 4):
            t = timing.t_abs_ps(M, N, T, 132, 132, k, tp) / 1e6
            times[k] = t
            rows.append({"bench": "fig5", "layer": name, "k": k,
                         "time_us": round(t, 3),
                         "conventional_us": round(conv, 3)})
        best[name] = min(times, key=times.get)
    # paper: layer 20 minimized at k=2..3; layer 28 at k=4
    derived = (f"best_k layer20={best['layer20']} (paper:2) "
               f"layer28={best['layer28']} (paper:4)")
    assert best["layer20"] in (2, 3) and best["layer28"] == 4
    return rows, derived


def fig7_convnext_per_layer():
    """Fig. 7: per-layer exec time of ConvNeXt on 128x128 SAs, ArrayFlex vs
    conventional; early layers prefer k=1, late layers k=4."""
    rows = []
    gemms = [planner.GEMM(f"L{i}", *mnt)
             for i, mnt in enumerate(cnn_shapes.network_mnt("convnext"))]
    plans = [planner.plan_gemm(g, 128, 128) for g in gemms]
    for i, p in enumerate(plans):
        rows.append({"bench": "fig7", "layer": i, "k": p.k,
                     "arrayflex_us": round(p.t_abs_ps / 1e6, 3),
                     "conventional_us": round(p.t_conventional_ps / 1e6, 3),
                     "saving_pct": round(100 * p.saving, 2)})
    ks = [p.k for p in plans]
    total_save = 1.0 - (sum(p.t_abs_ps for p in plans)
                        / sum(p.t_conventional_ps for p in plans))
    derived = (f"total_saving={total_save*100:.1f}% (paper:11%), "
               f"k1_layers={ks.count(1)} k2={ks.count(2)} k4={ks.count(4)}")
    return rows, derived


def fig8_total_exec_time():
    """Fig. 8: normalized full-run exec time for 3 CNNs x {128^2, 256^2}."""
    rows = []
    savings = []
    for R in (128, 256):
        for net in ("resnet34", "mobilenet", "convnext"):
            gemms = [planner.GEMM(f"l{i}", *mnt)
                     for i, mnt in enumerate(cnn_shapes.network_mnt(net))]
            res = planner.plan_network(gemms, R, R)
            savings.append(res["latency_saving"])
            rows.append({"bench": "fig8", "net": net, "sa": f"{R}x{R}",
                         "normalized_time":
                             round(1.0 - res["latency_saving"], 4),
                         "saving_pct":
                             round(100 * res["latency_saving"], 2)})
    derived = (f"savings {min(savings)*100:.1f}%-{max(savings)*100:.1f}% "
               f"(paper: 9%-11%)")
    return rows, derived


def fig9_power_edp():
    """Fig. 9: full-run average power + EDP gain vs the conventional SA."""
    rows = []
    pws, edps = [], []
    for R in (128, 256):
        for net in ("resnet34", "mobilenet", "convnext"):
            gemms = [planner.GEMM(f"l{i}", *mnt)
                     for i, mnt in enumerate(cnn_shapes.network_mnt(net))]
            res = planner.plan_network(gemms, R, R)
            pws.append(res["power_saving"])
            edps.append(res["edp_gain"])
            rows.append({"bench": "fig9", "net": net, "sa": f"{R}x{R}",
                         "power_saving_pct":
                             round(100 * res["power_saving"], 2),
                         "edp_gain": round(res["edp_gain"], 3)})
    derived = (f"power saving {min(pws)*100:.0f}%-{max(pws)*100:.0f}% "
               f"(paper: 13%-23%), EDP {min(edps):.2f}x-{max(edps):.2f}x "
               f"(paper: 1.4x-1.8x)")
    return rows, derived


def beyond_llm_plans():
    """Beyond-paper: ArrayFlex per-GEMM planning over the 10 assigned LM
    architectures.  Key finding: training GEMMs stream T~1M rows, so Eq.(7)
    drives k_hat -> 1 and the configurable design's k=1 clock penalty makes
    ArrayFlex a net LOSS for training — but single-token decode (T=batch)
    is exactly the small-T regime the paper targets, and there shallow
    pipelining wins on every architecture."""
    from repro.configs import ARCHS, SHAPES
    rows = []
    save = {"train_4k": [], "decode_32k": []}
    for shape_name in ("train_4k", "decode_32k"):
        for name, cfg in sorted(ARCHS.items()):
            res = planner.plan_model(cfg, SHAPES[shape_name])
            save[shape_name].append(res["latency_saving"])
            rows.append({"bench": "llm_plan", "arch": name,
                         "shape": shape_name,
                         "latency_saving_pct":
                             round(100 * res["latency_saving"], 2),
                         "power_saving_pct":
                             round(100 * res["power_saving"], 2),
                         "edp_gain": round(res["edp_gain"], 3)})
    mt = 100 * sum(save["train_4k"]) / 10
    md = 100 * sum(save["decode_32k"]) / 10
    return rows, (f"mean latency saving: train {mt:.1f}% (k=1 penalty) "
                  f"vs decode {md:.1f}% — ArrayFlex pays in the small-T "
                  f"serving regime")
