"""Serving-engine benchmark: batched chunked prefill vs the seed's
token-by-token prefill on the reduced qwen2-0.5b config.

Reports, per prefill mode: prefill throughput (tok/s), decode throughput
(tok/s), dispatch counts, and mean time-to-first-token — and asserts that
greedy token streams are identical across modes (the refactor is
behavior-preserving).  CPU wall-times are structural (dispatch overhead
dominates), which is exactly the effect batching the prefill removes.

Standalone:  PYTHONPATH=src python benchmarks/serving_bench.py [--smoke]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def _prompts(n, smoke=False):
    base, spread = (6, 4) if smoke else (18, 13)
    return [[2 + (i * 11 + j) % 97 for j in range(base + (i * 5) % spread)]
            for i in range(n)]


def _run_mode(cfg, params, mode, prompts, *, max_new, max_batch, max_seq,
              prefill_chunk=0):
    sc = ServeConfig(max_batch=max_batch, max_seq=max_seq,
                     prefill_mode=mode, prefill_chunk=prefill_chunk)
    # warmup engine: pay jit compilation outside the timed run
    warm = ServingEngine(cfg, params, sc)
    warm.submit(Request(prompt=prompts[0][:4], max_new_tokens=2))
    warm.run_to_completion()
    warm.stats = {k: 0 if isinstance(v, int) else 0.0
                  for k, v in warm.stats.items()}
    engine = warm
    reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    st = engine.stats
    ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
    return {
        "mode": mode,
        "prefill_chunk": engine.prefill_chunk,
        "prefill_tokens": st["prefill_tokens"],
        "prefill_dispatches": st["prefill_dispatches"],
        "prefill_tok_s": st["prefill_tokens"] / max(st["prefill_time_s"],
                                                    1e-9),
        "decode_tokens": st["decode_tokens"],
        "decode_dispatches": st["decode_dispatches"],
        "decode_tok_s": st["decode_tokens"] / max(st["decode_time_s"], 1e-9),
        "mean_ttft_ms": 1e3 * sum(ttfts) / max(len(ttfts), 1),
    }, [r.out_tokens for r in reqs]


def serving_prefill_modes(smoke: bool = False):
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req, max_new = (3, 2) if smoke else (6, 4)
    prompts = _prompts(n_req, smoke)
    rows, streams = [], {}
    for mode in ("token", "batched"):
        row, out = _run_mode(cfg, params, mode, prompts, max_new=max_new,
                             max_batch=min(4, n_req), max_seq=64)
        row = {k: (round(v, 1) if isinstance(v, float) else v)
               for k, v in row.items()}
        rows.append(row)
        streams[mode] = out
    assert streams["token"] == streams["batched"], \
        "greedy token streams diverged between prefill modes"
    by = {r["mode"]: r for r in rows}
    speedup = (by["batched"]["prefill_tok_s"]
               / max(by["token"]["prefill_tok_s"], 1e-9))
    ttft_gain = (by["token"]["mean_ttft_ms"]
                 / max(by["batched"]["mean_ttft_ms"], 1e-9))
    derived = (f"prefill speedup {speedup:.1f}x "
               f"({by['token']['prefill_dispatches']} -> "
               f"{by['batched']['prefill_dispatches']} dispatches); "
               f"TTFT gain {ttft_gain:.1f}x; outputs identical")
    return rows, derived


def serving_smoke():
    return serving_prefill_modes(smoke=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced request count / lengths for CI")
    args = ap.parse_args(argv)
    rows, derived = serving_prefill_modes(smoke=args.smoke)
    for row in rows:
        print(row)
    print(derived)


if __name__ == "__main__":
    main()
