"""Serving-engine benchmark: batched chunked prefill vs the seed's
token-by-token prefill on the reduced qwen2-0.5b config.

Reports, per prefill mode: prefill throughput (tok/s), decode throughput
(tok/s), dispatch counts, and mean time-to-first-token — and asserts that
greedy token streams are identical across modes (the refactor is
behavior-preserving).  CPU wall-times are structural (dispatch overhead
dominates), which is exactly the effect batching the prefill removes.

Standalone:  PYTHONPATH=src python benchmarks/serving_bench.py [--smoke]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def _prompts(n, smoke=False):
    base, spread = (6, 4) if smoke else (18, 13)
    return [[2 + (i * 11 + j) % 97 for j in range(base + (i * 5) % spread)]
            for i in range(n)]


def _run_mode(cfg, params, mode, prompts, *, max_new, max_batch, max_seq,
              prefill_chunk=0):
    sc = ServeConfig(max_batch=max_batch, max_seq=max_seq,
                     prefill_mode=mode, prefill_chunk=prefill_chunk)
    # warmup engine: pay jit compilation outside the timed run
    warm = ServingEngine(cfg, params, sc)
    warm.submit(Request(prompt=prompts[0][:4], max_new_tokens=2))
    warm.run_to_completion()
    warm.stats = {k: 0 if isinstance(v, int) else 0.0
                  for k, v in warm.stats.items()}
    engine = warm
    reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    st = engine.stats
    ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
    return {
        "mode": mode,
        "prefill_chunk": engine.prefill_chunk,
        "prefill_tokens": st["prefill_tokens"],
        "prefill_dispatches": st["prefill_dispatches"],
        "prefill_tok_s": st["prefill_tokens"] / max(st["prefill_time_s"],
                                                    1e-9),
        "decode_tokens": st["decode_tokens"],
        "decode_dispatches": st["decode_dispatches"],
        "decode_tok_s": st["decode_tokens"] / max(st["decode_time_s"], 1e-9),
        "mean_ttft_ms": 1e3 * sum(ttfts) / max(len(ttfts), 1),
    }, [r.out_tokens for r in reqs]


def serving_prefill_modes(smoke: bool = False):
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req, max_new = (3, 2) if smoke else (6, 4)
    prompts = _prompts(n_req, smoke)
    rows, streams = [], {}
    for mode in ("token", "batched"):
        row, out = _run_mode(cfg, params, mode, prompts, max_new=max_new,
                             max_batch=min(4, n_req), max_seq=64)
        row = {k: (round(v, 1) if isinstance(v, float) else v)
               for k, v in row.items()}
        rows.append(row)
        streams[mode] = out
    assert streams["token"] == streams["batched"], \
        "greedy token streams diverged between prefill modes"
    by = {r["mode"]: r for r in rows}
    speedup = (by["batched"]["prefill_tok_s"]
               / max(by["token"]["prefill_tok_s"], 1e-9))
    ttft_gain = (by["token"]["mean_ttft_ms"]
                 / max(by["batched"]["mean_ttft_ms"], 1e-9))
    derived = (f"prefill speedup {speedup:.1f}x "
               f"({by['token']['prefill_dispatches']} -> "
               f"{by['batched']['prefill_dispatches']} dispatches); "
               f"TTFT gain {ttft_gain:.1f}x; outputs identical")
    return rows, derived


def serving_smoke():
    return serving_prefill_modes(smoke=True)


# ---------------------------------------------------------------------------
# paged K/V + radix prefix reuse

_PAGED_MEMO = {}


def _staggered(engine, reqs):
    """Reuse-sensitive schedule: the first request finishes prefill (and
    publishes its prompt pages when the prefix cache is on) before the
    followers sharing its system prompt arrive."""
    engine.submit(reqs[0])
    while not reqs[0].out_tokens:
        engine.step()
    for r in reqs[1:]:
        engine.submit(r)
    engine.run_to_completion()


def paged_section():
    """Paged-KV measurements: the ``paged`` block of BENCH_substrate.json
    (gated by check_substrate_baseline) plus per-run CSV rows.

    Workload: five requests sharing a 32-token system prompt, submitted
    staggered, on the reduced qwen2-0.5b.  Three engines run the same
    schedule — dense, paged cold (no prefix cache), paged warm (radix
    reuse) — and must emit identical greedy streams.  Launch counts,
    page peaks and prefix-hit tokens are deterministic structure; TTFT
    is reported but not gated (CPU wall time).  The workload is fixed
    (no smoke variant) so the gated numbers match one baseline.
    """
    if "report" in _PAGED_MEMO:
        return _PAGED_MEMO["report"]
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    S, page, max_new, max_batch = 64, 8, 4, 2
    system = [2 + (j * 3) % 89 for j in range(32)]
    prompts = [system + [40 + i, 41 + i] for i in range(5)]

    def run(label, kv_pages=0, prefix=False):
        engine = ServingEngine(cfg, params, ServeConfig(
            max_batch=max_batch, max_seq=S, prefill_mode="batched",
            prefill_chunk=8, kv_pages=kv_pages,
            page_size=page if kv_pages else 0, prefix_cache=prefix))
        reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
                for i, p in enumerate(prompts)]
        _staggered(engine, reqs)
        assert all(r.done for r in reqs)
        st = engine.stats
        ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
        row = {
            "engine": label,
            "kv_bytes": engine.kv_cache_bytes(),
            "prefill_dispatches": st["prefill_dispatches"],
            "prefill_gemm_dispatches": st["prefill_gemm_dispatches"],
            "prefill_tokens": st["prefill_tokens"],
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "pages_used_peak": st["pages_used_peak"],
            "concurrency_peak": (st["concurrency_peak"] if kv_pages
                                 else max_batch),
            "mean_ttft_ms": round(1e3 * sum(ttfts) / max(len(ttfts), 1), 1),
        }
        return row, [r.out_tokens for r in reqs]

    dense_row, dense_out = run("dense")
    cold_row, cold_out = run("paged_cold", kv_pages=32)
    warm_row, warm_out = run("paged_warm", kv_pages=32, prefix=True)
    page_bytes = cold_row["kv_bytes"] // 32
    section = {
        "config": {"page_size": page, "kv_pages": 32, "max_batch": max_batch,
                   "max_seq": S, "requests": len(prompts),
                   "system_prompt_tokens": len(system)},
        "streams_identical": (cold_out == dense_out
                              and warm_out == dense_out),
        "dense_kv_bytes": dense_row["kv_bytes"],
        "paged_pool_bytes": cold_row["kv_bytes"],
        "paged_used_peak_bytes": {
            "cold": cold_row["pages_used_peak"] * page_bytes,
            "warm": warm_row["pages_used_peak"] * page_bytes},
        "prefill_gemm_dispatches": {
            "cold": cold_row["prefill_gemm_dispatches"],
            "warm": warm_row["prefill_gemm_dispatches"]},
        "prefill_tokens": {"cold": cold_row["prefill_tokens"],
                           "warm": warm_row["prefill_tokens"]},
        "prefix_hit_tokens": warm_row["prefix_hit_tokens"],
        "pages_used_peak": {"cold": cold_row["pages_used_peak"],
                            "warm": warm_row["pages_used_peak"]},
        "concurrency_peak": cold_row["concurrency_peak"],
        "mean_ttft_ms": {"dense": dense_row["mean_ttft_ms"],
                         "cold": cold_row["mean_ttft_ms"],
                         "warm": warm_row["mean_ttft_ms"]},
    }
    rows = [dense_row, cold_row, warm_row]
    _PAGED_MEMO["report"] = (rows, section)
    return rows, section


def serving_paged_kv():
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    rows, sec = paged_section()
    gd = sec["prefill_gemm_dispatches"]
    derived = (f"streams identical={sec['streams_identical']}; "
               f"prefix reuse cuts prefill GEMM launches "
               f"{gd['cold']} -> {gd['warm']} "
               f"({sec['prefix_hit_tokens']} prefix tokens reused); "
               f"concurrency {sec['concurrency_peak']} > "
               f"max_batch {sec['config']['max_batch']}")
    return rows, derived


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced request count / lengths for CI")
    args = ap.parse_args(argv)
    rows, derived = serving_prefill_modes(smoke=args.smoke)
    for row in rows:
        print(row)
    print(derived)


if __name__ == "__main__":
    main()
