"""Serving-engine benchmark: batched chunked prefill vs the seed's
token-by-token prefill on the reduced qwen2-0.5b config.

Reports, per prefill mode: prefill throughput (tok/s), decode throughput
(tok/s), dispatch counts, and mean time-to-first-token — and asserts that
greedy token streams are identical across modes (the refactor is
behavior-preserving).  CPU wall-times are structural (dispatch overhead
dominates), which is exactly the effect batching the prefill removes.

Standalone:  PYTHONPATH=src python benchmarks/serving_bench.py [--smoke]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def _prompts(n, smoke=False):
    base, spread = (6, 4) if smoke else (18, 13)
    return [[2 + (i * 11 + j) % 97 for j in range(base + (i * 5) % spread)]
            for i in range(n)]


def _run_mode(cfg, params, mode, prompts, *, max_new, max_batch, max_seq,
              prefill_chunk=0):
    sc = ServeConfig(max_batch=max_batch, max_seq=max_seq,
                     prefill_mode=mode, prefill_chunk=prefill_chunk)
    # warmup engine: pay jit compilation outside the timed run
    warm = ServingEngine(cfg, params, sc)
    warm.submit(Request(prompt=prompts[0][:4], max_new_tokens=2))
    warm.run_to_completion()
    warm.stats = {k: 0 if isinstance(v, int) else 0.0
                  for k, v in warm.stats.items()}
    engine = warm
    reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    st = engine.stats
    ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
    return {
        "mode": mode,
        "prefill_chunk": engine.prefill_chunk,
        "prefill_tokens": st["prefill_tokens"],
        "prefill_dispatches": st["prefill_dispatches"],
        "prefill_tok_s": st["prefill_tokens"] / max(st["prefill_time_s"],
                                                    1e-9),
        "decode_tokens": st["decode_tokens"],
        "decode_dispatches": st["decode_dispatches"],
        "decode_tok_s": st["decode_tokens"] / max(st["decode_time_s"], 1e-9),
        "mean_ttft_ms": 1e3 * sum(ttfts) / max(len(ttfts), 1),
    }, [r.out_tokens for r in reqs]


def serving_prefill_modes(smoke: bool = False):
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req, max_new = (3, 2) if smoke else (6, 4)
    prompts = _prompts(n_req, smoke)
    rows, streams = [], {}
    for mode in ("token", "batched"):
        row, out = _run_mode(cfg, params, mode, prompts, max_new=max_new,
                             max_batch=min(4, n_req), max_seq=64)
        row = {k: (round(v, 1) if isinstance(v, float) else v)
               for k, v in row.items()}
        rows.append(row)
        streams[mode] = out
    assert streams["token"] == streams["batched"], \
        "greedy token streams diverged between prefill modes"
    by = {r["mode"]: r for r in rows}
    speedup = (by["batched"]["prefill_tok_s"]
               / max(by["token"]["prefill_tok_s"], 1e-9))
    ttft_gain = (by["token"]["mean_ttft_ms"]
                 / max(by["batched"]["mean_ttft_ms"], 1e-9))
    derived = (f"prefill speedup {speedup:.1f}x "
               f"({by['token']['prefill_dispatches']} -> "
               f"{by['batched']['prefill_dispatches']} dispatches); "
               f"TTFT gain {ttft_gain:.1f}x; outputs identical")
    return rows, derived


def serving_smoke():
    return serving_prefill_modes(smoke=True)


# ---------------------------------------------------------------------------
# paged K/V + radix prefix reuse

_PAGED_MEMO = {}


def _staggered(engine, reqs):
    """Reuse-sensitive schedule: the first request finishes prefill (and
    publishes its prompt pages when the prefix cache is on) before the
    followers sharing its system prompt arrive."""
    engine.submit(reqs[0])
    while not reqs[0].out_tokens:
        engine.step()
    for r in reqs[1:]:
        engine.submit(r)
    engine.run_to_completion()


def paged_section():
    """Paged-KV measurements: the ``paged`` block of BENCH_substrate.json
    (gated by check_substrate_baseline) plus per-run CSV rows.

    Workload: five requests sharing a 32-token system prompt, submitted
    staggered, on the reduced qwen2-0.5b.  Three engines run the same
    schedule — dense, paged cold (no prefix cache), paged warm (radix
    reuse) — and must emit identical greedy streams.  Launch counts,
    page peaks and prefix-hit tokens are deterministic structure; TTFT
    is reported but not gated (CPU wall time).  The workload is fixed
    (no smoke variant) so the gated numbers match one baseline.
    """
    if "report" in _PAGED_MEMO:
        return _PAGED_MEMO["report"]
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    S, page, max_new, max_batch = 64, 8, 4, 2
    system = [2 + (j * 3) % 89 for j in range(32)]
    prompts = [system + [40 + i, 41 + i] for i in range(5)]

    def run(label, kv_pages=0, prefix=False):
        engine = ServingEngine(cfg, params, ServeConfig(
            max_batch=max_batch, max_seq=S, prefill_mode="batched",
            prefill_chunk=8, kv_pages=kv_pages,
            page_size=page if kv_pages else 0, prefix_cache=prefix))
        reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
                for i, p in enumerate(prompts)]
        _staggered(engine, reqs)
        assert all(r.done for r in reqs)
        st = engine.stats
        ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
        row = {
            "engine": label,
            "kv_bytes": engine.kv_cache_bytes(),
            "prefill_dispatches": st["prefill_dispatches"],
            "prefill_gemm_dispatches": st["prefill_gemm_dispatches"],
            "prefill_tokens": st["prefill_tokens"],
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "pages_used_peak": st["pages_used_peak"],
            "concurrency_peak": (st["concurrency_peak"] if kv_pages
                                 else max_batch),
            "mean_ttft_ms": round(1e3 * sum(ttfts) / max(len(ttfts), 1), 1),
        }
        return row, [r.out_tokens for r in reqs]

    dense_row, dense_out = run("dense")
    cold_row, cold_out = run("paged_cold", kv_pages=32)
    warm_row, warm_out = run("paged_warm", kv_pages=32, prefix=True)
    page_bytes = cold_row["kv_bytes"] // 32
    section = {
        "config": {"page_size": page, "kv_pages": 32, "max_batch": max_batch,
                   "max_seq": S, "requests": len(prompts),
                   "system_prompt_tokens": len(system)},
        "streams_identical": (cold_out == dense_out
                              and warm_out == dense_out),
        "dense_kv_bytes": dense_row["kv_bytes"],
        "paged_pool_bytes": cold_row["kv_bytes"],
        "paged_used_peak_bytes": {
            "cold": cold_row["pages_used_peak"] * page_bytes,
            "warm": warm_row["pages_used_peak"] * page_bytes},
        "prefill_gemm_dispatches": {
            "cold": cold_row["prefill_gemm_dispatches"],
            "warm": warm_row["prefill_gemm_dispatches"]},
        "prefill_tokens": {"cold": cold_row["prefill_tokens"],
                           "warm": warm_row["prefill_tokens"]},
        "prefix_hit_tokens": warm_row["prefix_hit_tokens"],
        "pages_used_peak": {"cold": cold_row["pages_used_peak"],
                            "warm": warm_row["pages_used_peak"]},
        "concurrency_peak": cold_row["concurrency_peak"],
        "mean_ttft_ms": {"dense": dense_row["mean_ttft_ms"],
                         "cold": cold_row["mean_ttft_ms"],
                         "warm": warm_row["mean_ttft_ms"]},
    }
    rows = [dense_row, cold_row, warm_row]
    _PAGED_MEMO["report"] = (rows, section)
    return rows, section


def serving_paged_kv():
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    rows, sec = paged_section()
    gd = sec["prefill_gemm_dispatches"]
    derived = (f"streams identical={sec['streams_identical']}; "
               f"prefix reuse cuts prefill GEMM launches "
               f"{gd['cold']} -> {gd['warm']} "
               f"({sec['prefix_hit_tokens']} prefix tokens reused); "
               f"concurrency {sec['concurrency_peak']} > "
               f"max_batch {sec['config']['max_batch']}")
    return rows, derived


# ---------------------------------------------------------------------------
# resilience: seeded chaos matrix + zero-chaos stream identity

_RESIL_MEMO = {}


def _outcomes(engine):
    return {k[len("outcome_"):]: v for k, v in engine.stats.items()
            if k.startswith("outcome_") and v}


def resilience_section():
    """Seeded fault matrix: the ``resilience`` block of
    BENCH_substrate.json (gated exactly by check_substrate_baseline).

    Every scenario runs the same fixed 3-request greedy workload on the
    reduced qwen2-0.5b with pinned chaos seeds, so every gated field is
    deterministic structure: stream identity against the unhardened
    baseline, retry/preemption/watchdog counters, and the typed outcome
    histogram.  Wall times are deliberately absent.
    """
    if "report" in _RESIL_MEMO:
        return _RESIL_MEMO["report"]
    from repro.runtime.chaos import ChaosConfig
    from repro.serving import EngineCrash

    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5, 6, 7], [11, 12, 13, 14, 15], [21]]
    max_new = 4

    def run(label, n_new=max_new, **sc_kw):
        sc_kw.setdefault("max_batch", 2)
        sc_kw.setdefault("max_seq", 64)
        sc_kw.setdefault("prefill_mode", "batched")
        sc_kw.setdefault("prefill_chunk", 4)
        sc = ServeConfig(**sc_kw)
        engine = ServingEngine(cfg, params, sc)
        reqs = [Request(prompt=list(p), max_new_tokens=n_new, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        restarts = 0
        while True:
            try:
                engine.run_to_completion()
                break
            except EngineCrash:
                restarts += 1
                assert restarts <= 3, f"{label}: crash recovery livelocked"
                engine = ServingEngine.restore(
                    cfg, params, sc, engine.latest_snapshot())
        final = {r.rid: r for r in reqs}
        for r in engine.restored_requests:
            final[r.rid] = r
        reqs = [final[r.rid] for r in reqs]
        assert all(r.done for r in reqs), f"{label}: request left pending"
        return engine, reqs, [r.out_tokens for r in reqs], restarts

    _, _, base, _ = run("baseline")

    eng, _, out, _ = run("hardened", snapshot_every_ticks=2,
                         chaos=ChaosConfig(seed=123))
    zero_chaos = {"streams_identical": out == base,
                  "chaos_fired": len(eng._chaos.chaos_log),
                  "outcomes": _outcomes(eng)}

    # longer decode so page growth actually overruns the 5-page pool and
    # forces at least one youngest-preemption; compared against a dense
    # baseline of the same length
    _, _, base8, _ = run("baseline_long", n_new=8)
    eng, reqs, out, _ = run("preempt_tight_pool", n_new=8, kv_pages=5,
                            page_size=8, preempt_policy="youngest",
                            prefix_cache=True)
    preemption = {"streams_identical": out == base8,
                  "preemptions": eng.stats["preemptions"],
                  "outcomes": _outcomes(eng)}

    matrix = {}
    eng, _, out, _ = run("gemm_transient", chaos=ChaosConfig(gemm_fault_at=0))
    matrix["gemm_transient"] = {
        "streams_identical": out == base,
        "kernel_fault_retries": eng.stats["kernel_fault_retries"],
        "outcomes": _outcomes(eng)}
    eng, _, out, _ = run("nan_transient", chaos=ChaosConfig(nan_logits_at=0))
    matrix["nan_transient"] = {
        "streams_identical": out == base,
        "sample_retries": eng.stats["sample_retries"],
        "outcomes": _outcomes(eng)}
    eng, _, out, _ = run("nan_persistent", chaos=ChaosConfig(nan_logits=1.0))
    matrix["nan_persistent"] = {"outcomes": _outcomes(eng)}
    eng, _, out, _ = run("page_exhaust", kv_pages=24, page_size=8,
                         watchdog_ticks=4,
                         chaos=ChaosConfig(page_exhaust=1.0))
    matrix["page_exhaust"] = {
        "watchdog_fired": eng.stats["watchdog_fired"],
        "outcomes": _outcomes(eng)}
    eng, _, out, restarts = run("crash_restore", snapshot_every_ticks=1,
                                chaos=ChaosConfig(crash_at=2))
    matrix["crash_restore"] = {
        "streams_identical_after_restore": out == base,
        "restarts": restarts,
        "outcomes": _outcomes(eng)}

    section = {
        "config": {"requests": len(prompts), "max_new": max_new,
                   "max_batch": 2, "max_seq": 64, "chaos_seed": 0},
        "zero_chaos": zero_chaos,
        "preemption": preemption,
        "chaos_matrix": matrix,
    }
    rows = [{"scenario": "zero_chaos", **zero_chaos["outcomes"],
             "identical": zero_chaos["streams_identical"]},
            {"scenario": "preemption", **preemption["outcomes"],
             "identical": preemption["streams_identical"],
             "preemptions": preemption["preemptions"]}]
    rows += [{"scenario": k, **v.get("outcomes", {})}
             for k, v in matrix.items()]
    _RESIL_MEMO["report"] = (rows, section)
    return rows, section


def serving_resilience():
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    rows, sec = resilience_section()
    m = sec["chaos_matrix"]
    derived = (f"zero-chaos identical={sec['zero_chaos']['streams_identical']}; "
               f"preempted streams identical={sec['preemption']['streams_identical']} "
               f"({sec['preemption']['preemptions']} preemptions); "
               f"crash restored identical="
               f"{m['crash_restore']['streams_identical_after_restore']}; "
               f"every fault terminates typed")
    return rows, derived


# ---------------------------------------------------------------------------
# disaggregated prefill/decode

_DISAGG_MEMO = {}


def disagg_section():
    """Disaggregated-serving measurements: the ``disagg`` block of
    BENCH_substrate.json (gated by check_substrate_baseline) plus per-run
    CSV rows.

    Workload: a mixed batch on the reduced qwen2-0.5b — three long
    prompts with short decodes (prefill-heavy) interleaved with three
    short prompts with longer decodes (decode-heavy), the case
    disaggregation exists for: colocated, every prefill chunk a long
    prompt needs is paid *between* the short requests' decode steps.

    Gated structure: stream identity vs the colocated engine, the
    planner-picked chunks, handoff bytes, dispatch counts, and the
    analytic per-role ``best_k`` table at the pinned pipeline boundary
    site (attn.wq, M=K=896, one epilogue op, pp=2) — where prefill's
    stage-egress ops keep the argmin deep and decode's serialized
    ingress shallows it.  Everything under ``measured`` is wall time on
    whatever host runs the bench and is reported, NOT gated; the
    disagg-specific numbers there are the role-clock views —
    ``disagg_virtual_ttft_ms`` (a request's virtual TTFT excludes the
    other role's interleaved dispatches) and ``disagg_makespan_s``
    (``max`` of the role busy clocks, where colocated pays their sum).
    """
    if "report" in _DISAGG_MEMO:
        return _DISAGG_MEMO["report"]
    from repro.kernels import substrate
    from repro.parallel import sharding
    from repro.serving import DisaggServeConfig, DisaggServingEngine

    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    long_p = [[2 + (i * 13 + j) % 89 for j in range(40)] for i in range(3)]
    short_p = [[3 + (i * 7 + j) % 89 for j in range(6)] for i in range(3)]
    prompts = [p for pair in zip(long_p, short_p) for p in pair]
    max_new = [2, 6] * 3                      # long->short decode mix
    kw = dict(max_batch=2, max_seq=64, prefill_mode="batched")

    def run(label, engine_cls, sc):
        # warmup engine: pay jit compilation outside the timed run
        warm = engine_cls(cfg, params, sc)
        warm.submit(Request(prompt=prompts[0][:4], max_new_tokens=2))
        warm.run_to_completion()
        warm.stats = {k: 0 if isinstance(v, int) else 0.0
                      for k, v in warm.stats.items()}
        if hasattr(warm, "ttft_virtual"):
            warm.ttft_virtual.clear()
            warm._vt.clear()
        engine = warm
        reqs = [Request(prompt=p, max_new_tokens=n, rid=i)
                for i, (p, n) in enumerate(zip(prompts, max_new))]
        for r in reqs:
            engine.submit(r)
        engine.run_to_completion()
        st = engine.stats
        ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
        row = {"engine": label,
               "prefill_chunk": engine.prefill_chunk,
               "prefill_dispatches": st["prefill_dispatches"],
               "decode_dispatches": st["decode_dispatches"],
               "busy_s": round(st["prefill_time_s"] + st["decode_time_s"],
                               3),
               "mean_ttft_ms": round(1e3 * sum(ttfts) / len(ttfts), 1)}
        return row, engine, [r.out_tokens for r in reqs]

    colo_row, colo_eng, colo_out = run("colocated", ServingEngine,
                                       ServeConfig(**kw))
    dis_row, dis_eng, dis_out = run(
        "disagg", DisaggServingEngine,
        DisaggServeConfig(**kw, prefill_pods=1, decode_pods=1))
    st = dis_eng.stats
    vt = [dis_eng.ttft_virtual[i] for i in range(len(prompts))
          if i in dis_eng.ttft_virtual]
    vt_ms = round(1e3 * sum(vt) / len(vt), 1)
    makespan = round(max(st["prefill_time_s"], st["decode_time_s"]), 3)
    dis_row["mean_virtual_ttft_ms"] = vt_ms
    dis_row["makespan_s"] = makespan

    # analytic per-role plans at the pinned pipeline boundary site
    ep1 = substrate.Epilogue(kind="none", bias=True)

    def role_plan(role, T):
        t_ops, t_cyc = sharding.pp_transfer_terms(role, 2, T, 896)
        return substrate.plan_gemm(
            896, 896, T, "arrayflex", epilogue=ep1,
            shard=substrate.ShardSig(transfer_ops=t_ops,
                                     transfer_cycles=t_cyc))

    role_best_k = []
    for T in (128, 2048):
        pp_, pd_ = role_plan("prefill", T), role_plan("decode", T)
        role_best_k.append({
            "site": "attn.wq", "M": 896, "K": 896, "T": T, "pp": 2,
            "k_colocated": role_plan("", T).k,
            "k_prefill": pp_.k, "k_decode": pd_.k,
            "prefill_pred_us": round(pp_.t_pred_ps / 1e6, 4),
            "decode_pred_us": round(pd_.t_pred_ps / 1e6, 4)})

    section = {
        "config": {"requests": len(prompts), "long_prompt_tokens": 40,
                   "short_prompt_tokens": 6, "max_new": max_new,
                   "max_batch": 2, "max_seq": 64,
                   "prefill_pods": 1, "decode_pods": 1, "pp_stages": 1},
        "streams_identical": dis_out == colo_out,
        "prefill_chunk": {"colocated": colo_row["prefill_chunk"],
                          "disagg": dis_row["prefill_chunk"]},
        "dispatches": {
            "colocated": {"prefill": colo_row["prefill_dispatches"],
                          "decode": colo_row["decode_dispatches"]},
            "disagg": {"prefill": dis_row["prefill_dispatches"],
                       "decode": dis_row["decode_dispatches"]}},
        "kv_transfer_bytes": st["kv_transfer_bytes"],
        "role_best_k": role_best_k,
        "prefill_deeper_than_decode": all(
            r["k_prefill"] > r["k_decode"] for r in role_best_k),
        "measured": {
            "colocated_wall_ttft_ms": colo_row["mean_ttft_ms"],
            "disagg_wall_ttft_ms": dis_row["mean_ttft_ms"],
            "disagg_virtual_ttft_ms": vt_ms,
            "colocated_busy_s": colo_row["busy_s"],
            "disagg_busy_s": dis_row["busy_s"],
            "disagg_makespan_s": makespan},
    }
    rows = [colo_row, dis_row]
    _DISAGG_MEMO["report"] = (rows, section)
    return rows, section


def serving_disagg():
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    rows, sec = disagg_section()
    m = sec["measured"]
    ks = sec["role_best_k"][-1]
    derived = (f"streams identical={sec['streams_identical']}; "
               f"KV handoff {sec['kv_transfer_bytes']} B; disagg TTFT "
               f"{m['disagg_wall_ttft_ms']}ms wall / "
               f"{m['disagg_virtual_ttft_ms']}ms virtual, makespan "
               f"{m['disagg_makespan_s']}s (busy {m['disagg_busy_s']}s); "
               f"boundary k (T={ks['T']}): prefill {ks['k_prefill']} vs "
               f"decode {ks['k_decode']}")
    return rows, derived


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced request count / lengths for CI")
    ap.add_argument("--resilience", action="store_true",
                    help="run the seeded chaos matrix instead of the "
                         "prefill-mode comparison")
    ap.add_argument("--disagg", action="store_true",
                    help="run the disaggregated prefill/decode comparison "
                         "instead of the prefill-mode one")
    args = ap.parse_args(argv)
    if args.resilience:
        rows, sec = resilience_section()
        for row in rows:
            print(row)
        print(serving_resilience()[1])
        return
    if args.disagg:
        rows, _ = disagg_section()
        for row in rows:
            print(row)
        print(serving_disagg()[1])
        return
    rows, derived = serving_prefill_modes(smoke=args.smoke)
    for row in rows:
        print(row)
    print(derived)


if __name__ == "__main__":
    main()
