"""SA utilization + cluster-pipeline benches (paper §II/§III structure).

`occupancy` quantifies WHY shallow pipelining helps small-T layers: the
fill/drain skew is R/k + C/k cycles, so at T ~ R the array idles most of
the time at k=1 and collapse recovers it.  `cluster_pipeline` runs the
Eq.(6)/(7) isomorphism at pod scale.
"""
from __future__ import annotations


from repro.parallel import pipeline as cp
from repro.core import simulator, timing


def occupancy():
    rows = []
    R = C = 64
    for T in (16, 64, 256, 1024):
        for k in (1, 2, 4):
            tr = simulator.occupancy_trace(T, R, C, k)
            total = timing.latency_cycles(R, C, T, k)
            peak = (C // k) * (R // k)
            util = float(tr.sum()) / (total * peak)
            rows.append({"bench": "occupancy", "T": T, "k": k,
                         "cycles": total,
                         "mean_utilization": round(util, 4)})
    # collapse must help utilization most at small T
    small_gain = ([r for r in rows if r["T"] == 16 and r["k"] == 4][0]
                  ["mean_utilization"]
                  / [r for r in rows if r["T"] == 16 and r["k"] == 1][0]
                  ["mean_utilization"])
    big_gain = ([r for r in rows if r["T"] == 1024 and r["k"] == 4][0]
                ["mean_utilization"]
                / [r for r in rows if r["T"] == 1024 and r["k"] == 1][0]
                ["mean_utilization"])
    return rows, (f"utilization gain from k=4: {small_gain:.2f}x at T=16 vs "
                  f"{big_gain:.2f}x at T=1024 (Eq.7 structure)")


def cluster_pipeline():
    rows = []
    for pods in (4, 8, 16):
        for M in (2, 8, 64):
            # overhead ~ p2p latency + dispatch; comparable to a pod's
            # layer-block time at small microbatch counts
            plan = cp.plan(cp.PipelineCost(n_pods=pods, microbatches=M,
                                           layer_time_ms=1.0,
                                           overhead_ms=4.0))
            rows.append({"bench": "cluster_pipe", "pods": pods,
                         "microbatches": M, "best_k": plan["k"],
                         "k_hat": round(plan["k_hat"], 2),
                         "stages": plan["stages"],
                         "saving_pct": round(100 * plan["saving"], 1),
                         "bubble_frac":
                             round(plan["bubble_fraction"], 3)})
    trend = [r["best_k"] for r in rows if r["pods"] == 8]
    return rows, (f"pods=8: best collapse k by microbatches 2/8/64 = "
                  f"{trend} (more microbatches -> shallower, Eq.7)")
