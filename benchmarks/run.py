"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus per-bench detail CSVs to
results/bench/).  CPU wall-times are structural only; the paper-figure
benches report model-time quantities (cycles x clock), which are
hardware-calibrated.
"""
from __future__ import annotations

import csv
import os
import time


def _run(name, fn, out_dir):
    t0 = time.perf_counter()
    rows, derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    if rows:
        path = os.path.join(out_dir, f"{name}.csv")
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    print(f"{name},{us:.0f},{derived}")
    return rows, derived


def main() -> None:
    from benchmarks import (paper_figs, kernel_bench, roofline_table,
                            sa_utilization, serving_bench, substrate_bench)
    out_dir = "results/bench"
    os.makedirs(out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    _run("fig5_layer_tradeoff", paper_figs.fig5_layer_tradeoff, out_dir)
    _run("fig7_convnext_per_layer", paper_figs.fig7_convnext_per_layer,
         out_dir)
    _run("fig8_total_exec_time", paper_figs.fig8_total_exec_time, out_dir)
    _run("fig9_power_edp", paper_figs.fig9_power_edp, out_dir)
    _run("llm_plans_beyond_paper", paper_figs.beyond_llm_plans, out_dir)
    _run("gemm_collapse_sweep", kernel_bench.gemm_collapse_sweep, out_dir)
    _run("sa_occupancy", sa_utilization.occupancy, out_dir)
    _run("cluster_pipeline_plan", sa_utilization.cluster_pipeline, out_dir)
    _run("serving_prefill_modes", serving_bench.serving_prefill_modes,
         out_dir)
    _run("serving_paged_kv", serving_bench.serving_paged_kv, out_dir)
    _run("serving_resilience", serving_bench.serving_resilience, out_dir)
    _run("serving_disagg", serving_bench.serving_disagg, out_dir)
    _run("substrate_sites", substrate_bench.substrate_sites, out_dir)
    _run("roofline_table", roofline_table.roofline_rows, out_dir)
    _run("dryrun_status", roofline_table.dryrun_status_rows, out_dir)


if __name__ == "__main__":
    main()
