"""Substrate benchmark: measured per-site GEMM time vs the planner's
Eq.(6) prediction, plus end-to-end backend equivalence on the reduced
qwen2-0.5b model.

For every GEMM site the model actually executes (``attn.wq``, ``mlp.wo``,
``attn.qk``, ..., recorded by kernels.substrate during a trace), this
bench times the standalone substrate dispatch under each backend and
prints it next to the analytic Eq.(6) model time at the planned collapse
depth k — the paper's selection loop and the executed kernel, joined on
the site label.  It then runs ``forward`` / ``decode_step`` /
``prefill_step`` under ``xla`` and ``arrayflex`` end to end and asserts
the logits agree (fp32-accumulation tolerance) — the arrayflex path
covers every transformer GEMM shape with the padded kernel (no
reference-GEMM fallback exists anymore).

New in the fused-epilogue substrate: the ``fused`` section times the
one-launch dual-GEMM swiglu against the unfused two-launch path and the
expert-batched MoE kernel against the per-expert unroll (equal numerics
asserted for both), and ``dispatch_counts`` / ``moe_expert_launches``
record the per-site launch counts of a traced forward (3 per MoE layer's
expert GEMMs, was 3E).  ``benchmarks/check_substrate_baseline.py`` diffs
these fields against the committed baseline in CI.

New in the sharded substrate: the ``sharded`` section traces the model
under an FSDP=2 x TP=2 host mesh (needs >= 4 devices, else null) and
reports, per site, the logical vs post-partition (M, N, T), the shard
signature, the per-shard Eq.(6') cycles/prediction, and the measured
per-shard standalone dispatch — predicted vs measured time *per shard*.
Its dispatch counts (one launch per site, sharded or not) are gated
exactly against the baseline.

New in the quantized substrate: the ``int8`` section (see
``_int8_section``) gates the weight-quantization memo hit rate (100%
after warmup — no per-dispatch requantization), the int8-vs-fp32 logits
tolerance, the int8 dispatch structure, and the fp32-vs-int8 analytic
k table (``k_shift_sites``: where the int8 datapath re-picks the
collapse depth).

New in the W8A8 substrate: the ``w8a8`` section (see ``_w8a8_section``)
gates the in-kernel quantize-boundary structure of a traced W8A8
dispatch (int8 x int8 -> int32 dot_generals plus the activation int8
casts that feed them — the integer MAC path is a jaxpr fact, not a
tolerance), the fused-swiglu plan three-way (fp32 vs int8 vs w8a8 at
each backend's planned k with the Eq.(6') speedups), the w8a8-vs-fp32
logits tolerance, the dispatch structure, and the fp32-vs-w8a8
``k_shift_sites`` over the full decode cell — where the Eq.(5')
activation-quantize boundary term re-picks the collapse depth.

New in the disaggregated substrate: the ``disagg`` section (see
``serving_bench.disagg_section``) gates colocated-vs-disaggregated
stream identity on a mixed long-prefill/long-decode workload, the K/V
handoff bytes, the per-role dispatch counts, and the analytic
``role_best_k`` table at the pipeline boundary site — where
``sharding.pp_transfer_terms`` deepens prefill's collapse depth and
shallows decode's at the same (M, N, T).

CPU wall-times are structural (the Pallas kernel runs in interpret mode);
the Eq.(6) columns are the hardware-calibrated quantities.

Emits ``results/bench/BENCH_substrate.json`` (uploaded as a CI artifact so
the perf trajectory accumulates across commits).

Standalone:  PYTHONPATH=src python benchmarks/substrate_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import DECODE_32K
from repro.core import planner
from repro.kernels import ops, substrate
from repro.models import lm

OUT_JSON = os.path.join("results", "bench", "BENCH_substrate.json")
EXEC_BACKENDS = ("xla", "arrayflex")


def _cfg(backend="xla"):
    return reduced(get_config("qwen2-0.5b"), compute_dtype="float32",
                   param_dtype="float32", gemm_backend=backend)


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))          # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _time_min(fn, *args, iters=3, repeats=3):
    """min-of-repeats microbenchmark: the minimum is the least
    contention-polluted sample, which is what the CI ratio gate needs."""
    return min(_time(fn, *args, iters=iters) for _ in range(repeats))


def _trace_site_plans(cfg, params, toks):
    """One abstract trace under the arrayflex backend leaves its GEMM
    working set in substrate.SITE_PLANS (plans are recorded at trace time,
    so eval_shape collects them without running any interpreted kernel)."""
    substrate.SITE_PLANS.clear()
    import dataclasses
    cfg_af = dataclasses.replace(cfg, gemm_backend="arrayflex")
    jax.eval_shape(lambda p, b: lm.forward(cfg_af, p, b), params,
                   {"tokens": toks})
    return dict(substrate.SITE_PLANS)


def _site_rows(site_plans, iters):
    """Per-site: measured dispatch time per backend vs Eq.(6') prediction.

    The measured dispatch replays the site's recorded epilogue — the fused
    swiglu plan prices TWO contractions plus the boundary ops, so timing a
    plain single GEMM against it would compare different work.  The two
    labels of a fused dual-GEMM pair share one plan and emit ONE row under
    the joined label."""
    rows = []
    rng = np.random.RandomState(0)
    fused_seen = set()
    for site, plan in sorted(site_plans.items()):
        ep = plan.epilogue
        if ep.dual:
            if id(plan) in fused_seen:
                continue              # second label of the same fused pair
            fused_seen.add(id(plan))
            site = "+".join(s for s, p in sorted(site_plans.items())
                            if p is plan)
        x = jnp.asarray(rng.randn(plan.T, plan.N), jnp.float32)
        w = jnp.asarray(rng.randn(plan.N, plan.M), jnp.float32)
        w2 = (jnp.asarray(rng.randn(plan.N, plan.M), jnp.float32)
              if ep.dual else None)
        b = jnp.asarray(rng.randn(plan.M), jnp.float32) if ep.bias else None
        b2 = (jnp.asarray(rng.randn(plan.M), jnp.float32)
              if ep.bias2 else None)
        row = {"site": site, "M": plan.M, "N": plan.N, "T": plan.T,
               "k": plan.k, "epilogue": ep.kind,
               "contractions": ep.contractions,
               "eq6_pred_us": round(plan.t_pred_ps / 1e6, 4),
               "eq6_conventional_us": round(plan.t_conventional_ps / 1e6, 4),
               "eq6_saving_pct": round(100 * plan.saving, 1)}
        for backend in EXEC_BACKENDS:
            f = jax.jit(lambda a, be=backend, s=site, kind=ep.kind:
                        substrate.gemm(a, w, site=s, backend=be,
                                       epilogue=kind, w2=w2, bias=b,
                                       bias2=b2))
            row[f"measured_{backend}_us"] = round(_time(f, x,
                                                        iters=iters), 1)
        rows.append(row)
    return rows


def _model_rows(params, toks, iters):
    """End-to-end forward/decode/prefill per backend + logits agreement."""
    B, S = toks.shape
    steps, logits = [], {}
    for backend in EXEC_BACKENDS:
        cfg = _cfg(backend)
        fwd = jax.jit(lambda p, b: lm.forward(cfg, p, b)[0])
        us_fwd = _time(fwd, params, {"tokens": toks}, iters=iters)
        logits[backend] = np.float32(fwd(params, {"tokens": toks}))

        dec = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
        cache = lm.init_cache(cfg, B, S)
        us_dec = _time(dec, params, cache, jnp.ones((B,), jnp.int32),
                       jnp.int32(0), iters=iters)

        pre = jax.jit(lambda p, c, t, pos, lens: lm.prefill_step(
            cfg, p, c, t, pos, lens))
        us_pre = _time(pre, params, lm.init_cache(cfg, B, S), toks,
                       jnp.zeros((B,), jnp.int32),
                       jnp.full((B,), S, jnp.int32), iters=iters)
        steps.append({"backend": backend,
                      "forward_us": round(us_fwd, 1),
                      "decode_step_us": round(us_dec, 1),
                      "prefill_step_us": round(us_pre, 1)})
    max_diff = float(np.max(np.abs(logits["xla"] - logits["arrayflex"])))
    assert max_diff < 1e-3, \
        f"backend logits diverged beyond fp32 tolerance: {max_diff}"
    return steps, max_diff


def _fused_swiglu_rows(iters):
    """One-launch dual-GEMM swiglu vs the unfused two-launch path, per
    backend, at equal numerics (max-abs-diff asserted tiny).

    Even on the CPU interpreter the fusion wins (~1.3x at this shape): the
    unfused path materializes both (T, N) intermediates and re-reads x.
    ``iters`` should be >= ~10 — single-shot wall times on shared CPUs are
    noise."""
    rng = np.random.RandomState(1)
    # SA-tile-scale mlp.wi shape: big enough that the saved intermediate
    # materialization (the fusion's point) dominates, not launch overhead
    T, K, N = 256, 512, 512
    x = jnp.asarray(rng.randn(T, K), jnp.float32)
    wg = jnp.asarray(rng.randn(K, N), jnp.float32)
    wu = jnp.asarray(rng.randn(K, N), jnp.float32)
    rows = []
    for backend in EXEC_BACKENDS:
        fused = jax.jit(lambda a, be=backend: substrate.gemm(
            a, wg, w2=wu, epilogue="swiglu", backend=be))

        def unfused(a, be=backend):
            g = substrate.gemm(a, wg, backend=be)
            u = substrate.gemm(a, wu, backend=be)
            return jax.nn.silu(g) * u

        unfused = jax.jit(unfused)
        us_f = _time_min(fused, x, iters=iters, repeats=5)
        us_u = _time_min(unfused, x, iters=iters, repeats=5)
        diff = float(np.max(np.abs(np.float32(fused(x))
                                   - np.float32(unfused(x)))))
        assert diff < 1e-3, f"fused swiglu numerics diverged: {diff}"
        rows.append({"backend": backend, "T": T, "K": K, "N": N,
                     "fused_us": round(us_f, 1),
                     "unfused_us": round(us_u, 1),
                     "speedup": round(us_u / us_f, 3),
                     "max_abs_diff": diff})
    return rows


def _expert_batching_row(iters):
    """ONE expert-batched launch vs the per-expert unroll (what
    expert_gemm did before) under the arrayflex backend.

    CPU-interpret wall times are structural only for this row: the
    interpreter serializes the whole (E, i, j, s) grid through one scan,
    so the batched launch measures *slower* here — the hardware-relevant
    metric is ``launches_batched`` vs ``launches_unrolled`` (1 vs E per
    site; dispatch overhead and scheduling live per launch on TPU)."""
    rng = np.random.RandomState(2)
    G, E, C, K, N = 1, 8, 16, 64, 128
    x = jnp.asarray(rng.randn(G, E, C, K), jnp.float32)
    w = jnp.asarray(rng.randn(E, K, N), jnp.float32)
    batched = jax.jit(lambda a: substrate.expert_gemm(
        a, w, backend="arrayflex"))

    def unrolled(a):
        outs = [substrate.gemm(a[:, e], w[e], backend="arrayflex")
                for e in range(E)]
        return jnp.stack(outs, axis=1)

    unrolled = jax.jit(unrolled)
    us_b = _time_min(batched, x, iters=iters)
    us_u = _time_min(unrolled, x, iters=iters)
    diff = float(np.max(np.abs(np.float32(batched(x))
                               - np.float32(unrolled(x)))))
    assert diff < 1e-3, f"expert batching numerics diverged: {diff}"
    return {"experts": E, "G": G, "C": C, "K": K, "N": N,
            "batched_us": round(us_b, 1), "unrolled_us": round(us_u, 1),
            "speedup": round(us_u / us_b, 3), "max_abs_diff": diff,
            "launches_batched": 1, "launches_unrolled": E}


def _dispatch_counts():
    """Per-site substrate dispatch counts of one traced forward under the
    arrayflex backend (scan traces one super-block, so counts are per
    layer).  The MoE expert-GEMM sites must show 1 launch each — the
    3E -> 3 acceptance claim."""
    out = {}
    for arch in ("qwen2-0.5b", "qwen3-moe-30b-a3b"):
        cfg = reduced(get_config(arch), compute_dtype="float32",
                      param_dtype="float32", gemm_backend="arrayflex")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        substrate.clear_plan_cache()
        jax.eval_shape(lambda p, b, c=cfg: lm.forward(c, p, b), params,
                       {"tokens": jnp.ones((2, 8), jnp.int32)})
        out[arch] = dict(sorted(substrate.DISPATCH_COUNTS.items()))
    moe_cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    E = moe_cfg.moe.num_experts
    moe_counts = out["qwen3-moe-30b-a3b"]
    per_layer = sum(moe_counts.get(s, 0)
                    for s in ("moe.wi_gate", "moe.wi_up", "moe.wo"))
    assert per_layer == 3, f"expected 3 expert-GEMM launches, got {per_layer}"
    launches = {"experts": E,
                "per_moe_layer_unrolled": 3 * E,
                "per_moe_layer_now": per_layer}
    return out, launches


def _sharded_section(iters, backend="arrayflex"):
    """Post-partition plans + per-shard dispatch counts of a traced
    forward under an FSDP=2 x TP=2 host mesh.

    Per site: logical vs per-shard (M, N, T), the shard signature, the
    per-shard Eq.(6') cycle count / prediction, and the measured time of
    the per-shard standalone dispatch — the GEMM each device actually
    executes, epilogue replayed (with int8 weight codes + scales when
    ``backend`` quantizes, and the in-kernel activation quantize when the
    plan's precision is w8a8) — so predicted vs measured joins per shard.
    The dispatch counts are gated exactly by check_substrate_baseline.py:
    sharded dispatch stays ONE launch per site.  Returns None on hosts
    with fewer than 4 devices (the multi-device CI job provides them via
    XLA_FLAGS).
    """
    if len(jax.devices()) < 4:
        return None
    import dataclasses
    quant = substrate._BACKEND_INFO[backend].quantize
    cfg = dataclasses.replace(_cfg(backend), mesh_shape=(2, 2))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((2, 8), jnp.int32)
    substrate.clear_plan_cache()
    jax.eval_shape(lambda p, b: lm.forward(cfg, p, b), params,
                   {"tokens": toks})
    counts = dict(sorted(substrate.DISPATCH_COUNTS.items()))
    site_plans = dict(substrate.SITE_PLANS)
    rng = np.random.RandomState(3)
    rows, fused_seen = [], set()
    for site, plan in sorted(site_plans.items()):
        ep = plan.epilogue
        # only the two labels of a fused dual-GEMM pair share a dispatch:
        # collapse those under the joined label (matching the
        # dispatch_counts key); distinct sites that merely hash to the
        # same cached plan (attn.wk / attn.wv) each keep their row
        if ep.dual:
            if id(plan) in fused_seen:
                continue
            fused_seen.add(id(plan))
            site = "+".join(s for s, p in sorted(site_plans.items())
                            if p is plan)
        x = jnp.asarray(rng.randn(plan.T_shard, plan.N_shard), jnp.float32)
        w = jnp.asarray(rng.randn(plan.N_shard, plan.M_shard), jnp.float32)
        # replay the exact per-shard kernel the sharded dispatch runs: the
        # recorded plan's k (reduce pricing can shift it away from what a
        # fresh unsharded plan of the same shape would pick), and for
        # reduce sites the contraction-only kernel (epilogue post-psum)
        reduce = plan.shard.reduce_ops > 0
        w2 = (jnp.asarray(rng.randn(plan.N_shard, plan.M_shard),
                          jnp.float32) if ep.dual and not reduce else None)
        b = (jnp.asarray(rng.randn(plan.M_shard), jnp.float32)
             if ep.bias and not reduce else None)
        act = "none" if reduce else ep.activation
        ws = w2s = None
        # both quantized precisions stage int8 weight codes + scales; the
        # w8a8 replay additionally quantizes the activation tile in-kernel
        if quant and plan.precision in ("int8", "w8a8"):
            w, ws = substrate._quantize(w)
            if w2 is not None:
                w2, w2s = substrate._quantize(w2)
        aq = plan.precision == "w8a8"
        f = jax.jit(lambda a, k=plan.k, a_=act, q=aq: ops.arrayflex_matmul(
            a, w, w2=w2, bias=b, w_scale=ws, w2_scale=w2s, act_quant=q,
            activation=a_, k_collapse=k))
        rows.append({
            "site": site,
            "logical_MNT": [plan.M, plan.N, plan.T],
            "per_shard_MNT": [plan.M_shard, plan.N_shard, plan.T_shard],
            "shard": [plan.shard.rows, plan.shard.contraction,
                      plan.shard.cols, plan.shard.reduce_ops],
            "k": plan.k, "cycles": plan.cycles,
            "eq6_pred_us": round(plan.t_pred_ps / 1e6, 4),
            "measured_per_shard_us": round(_time(f, x, iters=iters), 1),
        })
    substrate.clear_plan_cache()
    return {"mesh": {"data": 2, "model": 2}, "dispatch_counts": counts,
            "sites": rows}


def _int8_section(params, toks, iters, fused_iters):
    """Quantized-backend section (gated by check_substrate_baseline.py):

    * ``quantize_cache`` — eager substrate dispatches against persistent
      weights must hit the per-weight-identity memo on every lookup after
      the first (hit_rate_after_warmup == 1.0: the hot path never
      re-quantizes; gated exactly);
    * ``fused_swiglu`` — the one-launch dual-GEMM swiglu under int8 vs
      fp32 arrayflex (planned k for each; CPU-interpret wall times are
      structural — the dequant runs extra interpreter ops — while the
      Eq.(6') columns carry the hardware-calibrated int8 win);
    * ``equivalence`` — int8 forward logits vs the fp32 arrayflex
      backend within the documented tolerance (0.06 on the reduced dense
      config; gated);
    * ``dispatch_counts`` — one launch per site under int8, fused and
      expert-batched structure intact (gated exactly);
    * ``analytic_decode_32k`` — fp32-vs-int8 plans side by side for the
      FULL qwen2-0.5b decode cell (planner.precision_table pricing);
      ``k_shift_sites`` counts sites whose best_k moved (gated exactly —
      the per-layer reconfiguration the quantized datapath buys);
    * ``sharded`` — predicted vs measured *per-shard* int8 plans under
      FSDP=2 x TP=2 (>= 4 devices, else null; dispatch counts gated).
    """
    rng = np.random.RandomState(4)
    T, K, N = 256, 512, 512
    x = jnp.asarray(rng.randn(T, K), jnp.float32)
    wg = jnp.asarray(rng.randn(K, N), jnp.float32)
    wu = jnp.asarray(rng.randn(K, N), jnp.float32)

    # -- memo hit rate: every lookup after the first per weight must hit
    substrate.clear_quant_cache()
    n_disp = 12
    for _ in range(n_disp):
        substrate.gemm(x, wg, w2=wu, epilogue="swiglu",
                       backend="arrayflex_int8")
    st = substrate.quantize_cache_info()
    weights = 2
    lookups = st["hits"] + st["misses"]
    assert st["misses"] == weights, f"re-quantized on the hot path: {st}"
    quant_cache = {"dispatches": n_disp, "weights": weights,
                   "lookups": lookups, "misses": st["misses"],
                   "hit_rate_after_warmup":
                       round(st["hits"] / (lookups - weights), 4)}

    # -- fused swiglu: int8 vs fp32 arrayflex at the planned k each
    ep = substrate.Epilogue(kind="swiglu")
    k_fp = substrate.plan_gemm(N, K, T, "arrayflex", ep).k
    k_i8 = substrate.plan_gemm(N, K, T, "arrayflex_int8", ep).k
    t_us = {}
    for backend in ("arrayflex", "arrayflex_int8"):
        f = jax.jit(lambda a, be=backend: substrate.gemm(
            a, wg, w2=wu, epilogue="swiglu", backend=be))
        t_us[backend] = _time_min(f, x, iters=fused_iters, repeats=3)
    fused_swiglu = {
        "T": T, "K": K, "N": N, "k_fp32": k_fp, "k_int8": k_i8,
        "fp32_us": round(t_us["arrayflex"], 1),
        "int8_us": round(t_us["arrayflex_int8"], 1),
        "wall_speedup_vs_fp32": round(
            t_us["arrayflex"] / t_us["arrayflex_int8"], 3),
        "eq6_speedup_vs_fp32": round(
            substrate.plan_gemm(N, K, T, "arrayflex", ep).t_pred_ps
            / substrate.plan_gemm(N, K, T, "arrayflex_int8", ep).t_pred_ps,
            3)}

    # -- model equivalence at the documented tolerance
    fwd_fp = jax.jit(lambda p, b: lm.forward(_cfg("arrayflex"), p, b)[0])
    fwd_i8 = jax.jit(lambda p, b: lm.forward(_cfg("arrayflex_int8"),
                                             p, b)[0])
    diff = float(np.max(np.abs(
        np.float32(fwd_i8(params, {"tokens": toks}))
        - np.float32(fwd_fp(params, {"tokens": toks})))))
    assert diff < 0.06, f"int8 logits beyond documented tolerance: {diff}"

    # -- dispatch structure under int8 (one launch per site)
    counts = {}
    for arch in ("qwen2-0.5b", "qwen3-moe-30b-a3b"):
        cfg = reduced(get_config(arch), compute_dtype="float32",
                      param_dtype="float32", gemm_backend="arrayflex_int8")
        p = lm.init_params(cfg, jax.random.PRNGKey(0))
        substrate.clear_plan_cache()
        jax.eval_shape(lambda pp, b, c=cfg: lm.forward(c, pp, b), p,
                       {"tokens": jnp.ones((2, 8), jnp.int32)})
        counts[arch] = dict(sorted(substrate.DISPATCH_COUNTS.items()))
    substrate.clear_plan_cache()

    # -- analytic fp32-vs-int8 plans for the full decode cell
    rows = []
    for g in planner.model_gemms(get_config("qwen2-0.5b"), DECODE_32K):
        pf = planner.plan_gemm_precision(g, 128, 128, "fp32")
        p8 = planner.plan_gemm_precision(g, 128, 128, "int8")
        rows.append({"site": g.name, "M": g.M, "N": g.N, "T": g.T,
                     "k_fp32": pf.k, "k_int8": p8.k,
                     "fp32_us": round(pf.t_abs_ps / g.count / 1e6, 4),
                     "int8_us": round(p8.t_abs_ps / g.count / 1e6, 4),
                     "int8_speedup": round(pf.t_abs_ps / p8.t_abs_ps, 3)})
    k_shift = sum(r["k_fp32"] != r["k_int8"] for r in rows)

    return {
        "quantize_cache": quant_cache,
        "fused_swiglu": fused_swiglu,
        "equivalence": {"logits_max_abs_diff_vs_fp32": diff,
                        "documented_atol": 0.06},
        "dispatch_counts": counts,
        "analytic_decode_32k": rows,
        "k_shift_sites": k_shift,
        "sharded": _sharded_section(iters, backend="arrayflex_int8"),
    }


def _w8a8_section(params, toks, iters, fused_iters):
    """W8A8-backend section (gated by check_substrate_baseline.py):

    * ``quantize_boundary`` — jaxpr facts of one traced W8A8 swiglu
      dispatch: the count of int8 x int8 -> int32 dot_generals and of the
      in-kernel activation int8 casts that feed them (the weights are
      persistent and memo-quantized outside the trace, so every int8 cast
      in the jaxpr IS an activation-quantize boundary).  Gated exactly —
      the integer MAC path engaging is structure, not a tolerance;
    * ``fused_swiglu`` — the one-launch dual-GEMM swiglu under fp32 vs
      int8 vs w8a8 arrayflex, each at its own planned k, with the
      Eq.(6') speedup columns (wall times structural on the CPU
      interpreter: the per-tile quantize runs as extra interpreted ops);
    * ``equivalence`` — w8a8 forward logits vs the fp32 arrayflex
      backend within the documented tolerance (0.12 on the reduced dense
      config: weight + activation rounding; gated);
    * ``dispatch_counts`` — one launch per site under w8a8, fused and
      expert-batched structure intact (gated exactly);
    * ``analytic_decode_32k`` — fp32-vs-w8a8 plans side by side for the
      FULL qwen2-0.5b decode cell; ``k_shift_sites`` counts sites whose
      best_k moved under the w8a8 datapath + Eq.(5') activation-quantize
      boundary term (gated exactly);
    * ``sharded`` — predicted vs measured *per-shard* w8a8 plans under
      FSDP=2 x TP=2 (>= 4 devices, else null; dispatch counts gated).
    """
    from repro.analysis import jaxpr_audit

    rng = np.random.RandomState(5)
    T, K, N = 256, 512, 512
    x = jnp.asarray(rng.randn(T, K), jnp.float32)
    wg = jnp.asarray(rng.randn(K, N), jnp.float32)
    wu = jnp.asarray(rng.randn(K, N), jnp.float32)

    # -- quantize-boundary structure of the traced dispatch
    substrate.clear_quant_cache()
    closed = jax.make_jaxpr(lambda a: substrate.gemm(
        a, wg, w2=wu, epilogue="swiglu", backend="arrayflex_w8a8"))(x)
    int8_dots = act_casts = 0
    for eqn in jaxpr_audit.iter_eqns(closed.jaxpr):
        if (eqn.primitive.name == "dot_general"
                and {str(v.aval.dtype) for v in eqn.invars} == {"int8"}
                and str(eqn.outvars[0].aval.dtype) == "int32"):
            int8_dots += 1
        if (eqn.primitive.name == "convert_element_type"
                and str(eqn.outvars[0].aval.dtype) == "int8"
                and eqn.outvars[0].aval.ndim >= 2):
            act_casts += 1
    assert int8_dots > 0, "w8a8 dispatch staged no int8 x int8 dot_general"
    quantize_boundary = {"int8_int8_dot_generals": int8_dots,
                         "act_quantize_casts": act_casts}

    # -- fused swiglu three-way: each backend at its own planned k
    ep = substrate.Epilogue(kind="swiglu")
    plans = {be: substrate.plan_gemm(N, K, T, be, ep)
             for be in ("arrayflex", "arrayflex_int8", "arrayflex_w8a8")}
    t_us = {}
    for be in plans:
        f = jax.jit(lambda a, be=be: substrate.gemm(
            a, wg, w2=wu, epilogue="swiglu", backend=be))
        t_us[be] = _time_min(f, x, iters=fused_iters, repeats=3)
    pw = plans["arrayflex_w8a8"]
    fused_swiglu = {
        "T": T, "K": K, "N": N,
        "k_fp32": plans["arrayflex"].k,
        "k_int8": plans["arrayflex_int8"].k,
        "k_w8a8": pw.k,
        "fp32_us": round(t_us["arrayflex"], 1),
        "int8_us": round(t_us["arrayflex_int8"], 1),
        "w8a8_us": round(t_us["arrayflex_w8a8"], 1),
        "eq6_speedup_vs_fp32": round(
            plans["arrayflex"].t_pred_ps / pw.t_pred_ps, 3),
        "eq6_speedup_vs_int8": round(
            plans["arrayflex_int8"].t_pred_ps / pw.t_pred_ps, 3)}

    # -- model equivalence at the documented tolerance
    fwd_fp = jax.jit(lambda p, b: lm.forward(_cfg("arrayflex"), p, b)[0])
    fwd_w8 = jax.jit(lambda p, b: lm.forward(_cfg("arrayflex_w8a8"),
                                             p, b)[0])
    diff = float(np.max(np.abs(
        np.float32(fwd_w8(params, {"tokens": toks}))
        - np.float32(fwd_fp(params, {"tokens": toks})))))
    assert diff < 0.12, f"w8a8 logits beyond documented tolerance: {diff}"

    # -- dispatch structure under w8a8 (one launch per site)
    counts = {}
    for arch in ("qwen2-0.5b", "qwen3-moe-30b-a3b"):
        cfg = reduced(get_config(arch), compute_dtype="float32",
                      param_dtype="float32", gemm_backend="arrayflex_w8a8")
        p = lm.init_params(cfg, jax.random.PRNGKey(0))
        substrate.clear_plan_cache()
        jax.eval_shape(lambda pp, b, c=cfg: lm.forward(c, pp, b), p,
                       {"tokens": jnp.ones((2, 8), jnp.int32)})
        counts[arch] = dict(sorted(substrate.DISPATCH_COUNTS.items()))
    substrate.clear_plan_cache()

    # -- analytic fp32-vs-w8a8 plans for the full decode cell
    rows = []
    for g in planner.model_gemms(get_config("qwen2-0.5b"), DECODE_32K):
        pf = planner.plan_gemm_precision(g, 128, 128, "fp32")
        p8 = planner.plan_gemm_precision(g, 128, 128, "w8a8")
        rows.append({"site": g.name, "M": g.M, "N": g.N, "T": g.T,
                     "k_fp32": pf.k, "k_w8a8": p8.k,
                     "fp32_us": round(pf.t_abs_ps / g.count / 1e6, 4),
                     "w8a8_us": round(p8.t_abs_ps / g.count / 1e6, 4),
                     "w8a8_speedup": round(pf.t_abs_ps / p8.t_abs_ps, 3)})
    k_shift = sum(r["k_fp32"] != r["k_w8a8"] for r in rows)

    return {
        "quantize_boundary": quantize_boundary,
        "fused_swiglu": fused_swiglu,
        "equivalence": {"logits_max_abs_diff_vs_fp32": diff,
                        "documented_atol": 0.12},
        "dispatch_counts": counts,
        "analytic_decode_32k": rows,
        "k_shift_sites": k_shift,
        "sharded": _sharded_section(iters, backend="arrayflex_w8a8"),
    }


def _analytic_full_rows():
    """Eq.(6') plans for the FULL qwen2-0.5b decode cell (no execution):
    what the selection loop buys at real scale.  Uses planner.plan_gemm so
    the fused-epilogue entries (the swiglu wi pair carries epilogue_ops=2)
    are priced exactly as the executed substrate plans are."""
    rows = []
    for g in planner.model_gemms(get_config("qwen2-0.5b"), DECODE_32K):
        p = planner.plan_gemm(g, 128, 128)
        rows.append({"site": g.name, "M": g.M, "N": g.N, "T": g.T,
                     "count": g.count, "k": p.k,
                     "epilogue_ops": g.epilogue_ops,
                     "eq6_pred_us": round(p.t_abs_ps / g.count / 1e6, 4),
                     "eq6_saving_pct": round(100 * p.saving, 1)})
    return rows


def substrate_report(smoke: bool = False):
    iters = 1 if smoke else 3
    B, S = (2, 8) if smoke else (2, 16)
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.RandomState(0).randint(2, cfg.vocab_size, (B, S)))

    site_plans = _trace_site_plans(cfg, params, toks)
    site_rows = _site_rows(site_plans, iters)
    model_rows, max_diff = _model_rows(params, toks, iters)
    # the CI gate compares the fused/unfused *ratio* against the baseline
    # with 20% headroom — average enough iterations that run-to-run ratio
    # noise stays well inside it even on shared runners
    fused_iters = 20 if smoke else 50
    fused_rows = _fused_swiglu_rows(fused_iters)
    expert_row = _expert_batching_row(fused_iters)
    dispatch_counts, moe_launches = _dispatch_counts()
    # snapshot before _sharded_section, whose trace clears the plan cache:
    # the field must mean the same thing on single- and multi-device hosts
    plan_cache = dict(substrate.plan_cache_info()._asdict())
    sharded = _sharded_section(iters)
    int8 = _int8_section(params, toks, iters, fused_iters)
    w8a8 = _w8a8_section(params, toks, iters, fused_iters)
    # serving-layer section: paged K/V + radix prefix reuse (memoized in
    # serving_bench so the run.py CSV entry and this JSON share one run);
    # fixed workload, so the gated numbers match one committed baseline
    try:
        from benchmarks import serving_bench
    except ImportError:
        # script-style invocation (python benchmarks/substrate_bench.py)
        # puts benchmarks/ itself on sys.path, not the repo root
        import sys
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks import serving_bench
    _, paged = serving_bench.paged_section()
    # resilience: seeded chaos matrix + zero-chaos stream identity (also
    # memoized; every gated field is deterministic structure, no wall time)
    _, resilience = serving_bench.resilience_section()
    # disaggregated prefill/decode: stream identity, K/V handoff bytes,
    # and the per-role best_k table at the pp boundary site (memoized)
    _, disagg = serving_bench.disagg_section()

    report = {
        "config": {"arch": "qwen2-0.5b (reduced)", "batch": B, "seq": S,
                   "backends": list(EXEC_BACKENDS), "smoke": smoke},
        "sites": site_rows,
        "model_steps": model_rows,
        "fused": {"swiglu": fused_rows, "expert_batching": expert_row},
        "dispatch_counts": dispatch_counts,
        "moe_expert_launches": moe_launches,
        "sharded": sharded,
        "int8": int8,
        "w8a8": w8a8,
        "paged": paged,
        "resilience": resilience,
        "disagg": disagg,
        "equivalence": {"logits_max_abs_diff": max_diff,
                        "reference_fallbacks": 0},
        "plan_cache": plan_cache,
    }
    if not smoke:
        report["analytic_full_decode_32k"] = _analytic_full_rows()
    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=1)
    af_swiglu = next(r for r in fused_rows if r["backend"] == "arrayflex")
    sh_note = (f", {len(sharded['sites'])} sharded sites @ FSDP2xTP2"
               if sharded else ", sharded: skipped (<4 devices)")
    derived = (f"{len(site_rows)} sites, logits max diff {max_diff:.1e}, "
               f"fused swiglu {af_swiglu['speedup']:.2f}x, "
               f"moe launches {moe_launches['per_moe_layer_unrolled']}->"
               f"{moe_launches['per_moe_layer_now']}/layer"
               f"{sh_note}, int8: quantize hit rate "
               f"{int8['quantize_cache']['hit_rate_after_warmup']:.0%}, "
               f"{int8['k_shift_sites']} k-shift sites, eq6 swiglu "
               f"{int8['fused_swiglu']['eq6_speedup_vs_fp32']:.2f}x, "
               f"w8a8: {w8a8['quantize_boundary']['int8_int8_dot_generals']}"
               f" int8xint8 dots, {w8a8['k_shift_sites']} k-shift sites, "
               f"eq6 swiglu "
               f"{w8a8['fused_swiglu']['eq6_speedup_vs_fp32']:.2f}x, "
               f"disagg streams identical="
               f"{disagg['streams_identical']} "
               f"-> {OUT_JSON}")
    return site_rows, derived


def substrate_sites(smoke: bool = False):
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    return substrate_report(smoke=smoke)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes / iterations for CI")
    args = ap.parse_args(argv)
    rows, derived = substrate_report(smoke=args.smoke)
    for row in rows:
        print(row)
    print(derived)


if __name__ == "__main__":
    main()
