"""Substrate benchmark: measured per-site GEMM time vs the planner's
Eq.(6) prediction, plus end-to-end backend equivalence on the reduced
qwen2-0.5b model.

For every GEMM site the model actually executes (``attn.wq``, ``mlp.wo``,
..., recorded by kernels.substrate during a trace), this bench times the
standalone substrate dispatch under each backend and prints it next to the
analytic Eq.(6) model time at the planned collapse depth k — the paper's
selection loop and the executed kernel, joined on the site label.  It then
runs ``forward`` / ``decode_step`` / ``prefill_step`` under ``xla`` and
``arrayflex`` end to end and asserts the logits agree (fp32-accumulation
tolerance) — the arrayflex path covers every transformer GEMM shape with
the padded kernel (no reference-GEMM fallback exists anymore).

CPU wall-times are structural (the Pallas kernel runs in interpret mode);
the Eq.(6) columns are the hardware-calibrated quantities.

Emits ``results/bench/BENCH_substrate.json`` (uploaded as a CI artifact so
the perf trajectory accumulates across commits).

Standalone:  PYTHONPATH=src python benchmarks/substrate_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import DECODE_32K
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm

OUT_JSON = os.path.join("results", "bench", "BENCH_substrate.json")
EXEC_BACKENDS = ("xla", "arrayflex")


def _cfg(backend="xla"):
    return reduced(get_config("qwen2-0.5b"), compute_dtype="float32",
                   param_dtype="float32", gemm_backend=backend)


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))          # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _trace_site_plans(cfg, params, toks):
    """One abstract trace under the arrayflex backend leaves its GEMM
    working set in substrate.SITE_PLANS (plans are recorded at trace time,
    so eval_shape collects them without running any interpreted kernel)."""
    substrate.SITE_PLANS.clear()
    import dataclasses
    cfg_af = dataclasses.replace(cfg, gemm_backend="arrayflex")
    jax.eval_shape(lambda p, b: lm.forward(cfg_af, p, b), params,
                   {"tokens": toks})
    return dict(substrate.SITE_PLANS)


def _site_rows(site_plans, iters):
    """Per-site: measured dispatch time per backend vs Eq.(6) prediction."""
    rows = []
    rng = np.random.RandomState(0)
    for site, plan in sorted(site_plans.items()):
        x = jnp.asarray(rng.randn(plan.T, plan.N), jnp.float32)
        w = jnp.asarray(rng.randn(plan.N, plan.M), jnp.float32)
        row = {"site": site, "M": plan.M, "N": plan.N, "T": plan.T,
               "k": plan.k,
               "eq6_pred_us": round(plan.t_pred_ps / 1e6, 4),
               "eq6_conventional_us": round(plan.t_conventional_ps / 1e6, 4),
               "eq6_saving_pct": round(100 * plan.saving, 1)}
        for backend in EXEC_BACKENDS:
            f = jax.jit(lambda a, b, be=backend: substrate.gemm(
                a, b, site=site, backend=be))
            row[f"measured_{backend}_us"] = round(_time(f, x, w,
                                                        iters=iters), 1)
        rows.append(row)
    return rows


def _model_rows(params, toks, iters):
    """End-to-end forward/decode/prefill per backend + logits agreement."""
    B, S = toks.shape
    steps, logits = [], {}
    for backend in EXEC_BACKENDS:
        cfg = _cfg(backend)
        fwd = jax.jit(lambda p, b: lm.forward(cfg, p, b)[0])
        us_fwd = _time(fwd, params, {"tokens": toks}, iters=iters)
        logits[backend] = np.float32(fwd(params, {"tokens": toks}))

        dec = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
        cache = lm.init_cache(cfg, B, S)
        us_dec = _time(dec, params, cache, jnp.ones((B,), jnp.int32),
                       jnp.int32(0), iters=iters)

        pre = jax.jit(lambda p, c, t, pos, lens: lm.prefill_step(
            cfg, p, c, t, pos, lens))
        us_pre = _time(pre, params, lm.init_cache(cfg, B, S), toks,
                       jnp.zeros((B,), jnp.int32),
                       jnp.full((B,), S, jnp.int32), iters=iters)
        steps.append({"backend": backend,
                      "forward_us": round(us_fwd, 1),
                      "decode_step_us": round(us_dec, 1),
                      "prefill_step_us": round(us_pre, 1)})
    max_diff = float(np.max(np.abs(logits["xla"] - logits["arrayflex"])))
    assert max_diff < 1e-3, \
        f"backend logits diverged beyond fp32 tolerance: {max_diff}"
    return steps, max_diff


def _analytic_full_rows():
    """Eq.(6) plans for the FULL qwen2-0.5b decode cell (no execution):
    what the selection loop buys at real scale."""
    rows = []
    for g in planner.model_gemms(get_config("qwen2-0.5b"), DECODE_32K):
        p = substrate.plan_gemm(g.M, g.N, g.T, "arrayflex")
        rows.append({"site": g.name, "M": g.M, "N": g.N, "T": g.T,
                     "count": g.count, "k": p.k,
                     "eq6_pred_us": round(p.t_pred_ps / 1e6, 4),
                     "eq6_saving_pct": round(100 * p.saving, 1)})
    return rows


def substrate_report(smoke: bool = False):
    iters = 1 if smoke else 3
    B, S = (2, 8) if smoke else (2, 16)
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.RandomState(0).randint(2, cfg.vocab_size, (B, S)))

    site_plans = _trace_site_plans(cfg, params, toks)
    site_rows = _site_rows(site_plans, iters)
    model_rows, max_diff = _model_rows(params, toks, iters)

    report = {
        "config": {"arch": "qwen2-0.5b (reduced)", "batch": B, "seq": S,
                   "backends": list(EXEC_BACKENDS), "smoke": smoke},
        "sites": site_rows,
        "model_steps": model_rows,
        "equivalence": {"logits_max_abs_diff": max_diff,
                        "reference_fallbacks": 0},
        "plan_cache": dict(substrate.plan_cache_info()._asdict()),
    }
    if not smoke:
        report["analytic_full_decode_32k"] = _analytic_full_rows()
    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=1)
    derived = (f"{len(site_rows)} sites, logits max diff {max_diff:.1e}, "
               f"plan cache {report['plan_cache']['currsize']} entries -> "
               f"{OUT_JSON}")
    return site_rows, derived


def substrate_sites(smoke: bool = False):
    """Benchmark entry (rows, derived) — wired into benchmarks/run.py."""
    return substrate_report(smoke=smoke)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes / iterations for CI")
    args = ap.parse_args(argv)
    rows, derived = substrate_report(smoke=args.smoke)
    for row in rows:
        print(row)
    print(derived)


if __name__ == "__main__":
    main()
