"""Roofline table benchmark: reads the dry-run sweep results and emits the
per-(arch x shape) three-term roofline rows (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load_cells(mesh="1pod"):
    rows = []
    if not os.path.isdir(RESULTS):
        return rows
    for f in sorted(os.listdir(RESULTS)):
        if not f.endswith(f"_{mesh}.json"):
            continue
        try:
            r = json.load(open(os.path.join(RESULTS, f)))
        except Exception:
            continue
        rows.append(r)
    return rows


def roofline_rows():
    rows = []
    worst = None
    for r in load_cells("1pod"):
        if r.get("status") != "ok":
            continue
        roof = r["roofline"]
        rows.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "compute_s": f"{roof['compute_s']:.4g}",
            "memory_s": f"{roof['memory_s']:.4g}",
            "collective_s": f"{roof['collective_s']:.4g}",
            "dominant": roof["dominant"],
            "useful_flops_ratio": round(roof["useful_flops_ratio"], 4),
            "mem_gib_per_dev": r["memory"]["per_device_gib"],
            "fits": r["memory"]["fits_16g_hbm"],
        })
        if worst is None or roof["useful_flops_ratio"] < worst[1]:
            worst = (f"{r['arch']}/{r['shape']}",
                     roof["useful_flops_ratio"])
    derived = (f"{len(rows)} cells; worst useful-flops cell: "
               f"{worst[0]} ({worst[1]:.3f})" if rows else "no sweep results")
    return rows, derived


def dryrun_status_rows():
    rows = []
    n_ok = n_fit = 0
    for mesh in ("1pod", "2pod"):
        for r in load_cells(mesh):
            ok = r.get("status") == "ok"
            n_ok += ok
            fit = ok and r["memory"]["fits_16g_hbm"]
            n_fit += bool(fit)
            rows.append({"bench": "dryrun", "arch": r["arch"],
                         "shape": r["shape"], "mesh": mesh,
                         "status": r.get("status"),
                         "compile_s": r.get("compile_s", ""),
                         "fits": fit if ok else ""})
    return rows, f"{n_ok} compiled cells, {n_fit} fit 16GiB HBM"
