"""CI gate: diff the structural/perf fields of BENCH_substrate.json
against the committed baseline and fail on regression.

What is gated (and why these fields):

* ``moe_expert_launches`` and the per-site ``dispatch_counts`` — exact
  match required.  Launch counts are deterministic structure (the 3E -> 3
  MoE batching, the fused swiglu's single dual-GEMM launch, attention
  QK/PV routed through the substrate); any drift is a real regression.
* ``sharded.dispatch_counts`` — exact match required *when measured*
  (the section needs a >= 4-device host; the multi-device CI job
  provides one via XLA_FLAGS).  Sharded dispatch must stay one launch
  per site — a per-shard unroll sneaking back in is a regression.
* fused swiglu ``speedup`` (arrayflex backend) — must not regress more
  than ``--tolerance`` (default 20%) below the baseline ratio.  A ratio
  of two timings on the same machine is stable enough to gate on, unlike
  absolute CPU wall times.
* ``equivalence.logits_max_abs_diff`` — must stay within fp32 tolerance.
* ``int8`` section — the weight-quantization memo hit rate must be
  exactly 1.0 after warmup (a per-dispatch requantization sneaking back
  in is a regression), the int8 dispatch counts must match exactly (the
  fused/batched launch structure survives quantization), the int8
  logits must stay within the documented 0.06 tolerance of fp32
  arrayflex, and ``k_shift_sites`` (how many full-decode-cell sites the
  int8 datapath replans to a different k) must match exactly — the
  planner finding the shift is the point of the int8 timing model.  The
  int8 wall-clock ratio is reported but NOT gated (the CPU grid
  interpreter pays the dequant as extra interpreted ops; the Eq.(6')
  columns carry the calibrated win).
* ``w8a8`` section — the quantize-boundary op counts of a traced W8A8
  dispatch must match exactly (the int8 x int8 -> int32 dot_generals and
  the in-kernel activation int8 casts: the integer MAC path engaging is
  deterministic jaxpr structure — if either count drifts, the kernel's
  quantize placement changed), the w8a8 dispatch counts must match
  exactly, the w8a8 logits must stay within the documented 0.12
  tolerance of fp32 arrayflex, the fused-swiglu planned-k three-way
  (k_fp32 / k_int8 / k_w8a8) must match exactly, and ``k_shift_sites``
  (full-decode-cell sites the w8a8 datapath + Eq.(5') activation-quantize
  term replans to a different k) must match exactly.  W8A8 wall-clock
  ratios are reported but NOT gated (same CPU-interpreter caveat).

* ``paged`` section — the serving layer's paged-KV workload (five
  requests sharing a system prompt, staggered) is deterministic
  structure end to end: streams must stay identical across the
  dense/paged-cold/paged-warm engines, the cold/warm prefill GEMM launch
  counts, prefix-hit tokens, page peaks and K/V byte totals must match
  the baseline exactly, and warm must launch strictly fewer prefill
  GEMMs than cold (the prefix-reuse win itself).  The TTFT numbers are
  reported but NOT gated (CPU wall time).

* ``resilience`` section — the seeded chaos matrix is deterministic by
  construction (injection decisions are pure functions of (seed, point,
  draw index)), so the whole subtree is gated exactly: zero-chaos
  hardened streams identical to the unhardened baseline with zero fired
  events, preempted streams identical with at least one forced
  preemption, crash-restored streams identical, and the typed outcome
  histograms of every scenario unchanged.

* ``disagg`` section — the disaggregated prefill/decode workload is
  deterministic structure: disagg streams must stay identical to the
  colocated engine's, the planner-picked chunks, per-role dispatch
  counts and K/V handoff bytes must match the baseline exactly, and the
  analytic ``role_best_k`` table at the pipeline boundary site must
  match exactly with prefill strictly deeper than decode at every T
  (the per-role argmin split — ``sharding.pp_transfer_terms`` — is the
  point of the feature).  The TTFT/makespan numbers are reported but
  NOT gated (CPU wall time).

The expert-batching wall-time ratio is reported but NOT gated: the CPU
grid interpreter serializes the batched launch (see substrate_bench), so
its timing is structural; its launch counts are gated instead.

Usage:
  PYTHONPATH=src python benchmarks/check_substrate_baseline.py \
      [--current results/bench/BENCH_substrate.json] \
      [--baseline benchmarks/baselines/BENCH_substrate_baseline.json] \
      [--tolerance 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_CURRENT = "results/bench/BENCH_substrate.json"
DEFAULT_BASELINE = "benchmarks/baselines/BENCH_substrate_baseline.json"
# cap applied to the committed baseline ratio before the tolerance check
# (cross-machine normalization; see comment at the speedup gate)
SPEEDUP_BASELINE_CAP = 1.2


def _fused_speedup(report, backend="arrayflex"):
    for row in report["fused"]["swiglu"]:
        if row["backend"] == backend:
            return row["speedup"]
    raise KeyError(f"no fused swiglu row for backend {backend!r}")


def check(current: dict, baseline: dict, tolerance: float):
    errors = []

    # --- structural: launch counts must match the baseline exactly -------
    if current["moe_expert_launches"] != baseline["moe_expert_launches"]:
        errors.append(
            f"moe_expert_launches changed: {current['moe_expert_launches']}"
            f" != baseline {baseline['moe_expert_launches']}")
    for arch, want in baseline["dispatch_counts"].items():
        got = current["dispatch_counts"].get(arch)
        if got != want:
            errors.append(f"dispatch_counts[{arch}] changed: {got} != "
                          f"baseline {want}")
    eb = current["fused"]["expert_batching"]
    if (eb["launches_batched"], eb["launches_unrolled"]) != (
            baseline["fused"]["expert_batching"]["launches_batched"],
            baseline["fused"]["expert_batching"]["launches_unrolled"]):
        errors.append(f"expert-batching launch counts changed: {eb}")

    # --- sharded: per-shard dispatch counts (exact) when measured --------
    cur_sh = current.get("sharded")
    base_sh = baseline.get("sharded")
    if cur_sh and base_sh:
        if cur_sh["dispatch_counts"] != base_sh["dispatch_counts"]:
            errors.append(
                f"sharded dispatch_counts changed: "
                f"{cur_sh['dispatch_counts']} != baseline "
                f"{base_sh['dispatch_counts']}")
    elif base_sh and not cur_sh:
        print("note: sharded section not measured on this host (needs "
              ">= 4 devices); skipping the sharded dispatch-count gate")

    # --- perf: fused swiglu ratio within tolerance of the baseline -------
    # The ratio is machine-dependent (the baseline was committed from a
    # different box than the CI runner), so cap the baseline before
    # applying the tolerance: an unusually fast baseline machine must not
    # impose a floor a healthy runner cannot reach.  A real regression
    # (fusion slower than unfused) still lands far below the capped floor.
    got = _fused_speedup(current)
    want = min(_fused_speedup(baseline), SPEEDUP_BASELINE_CAP)
    if got < want * (1.0 - tolerance):
        errors.append(
            f"fused swiglu speedup regressed >{tolerance:.0%}: "
            f"{got:.3f}x vs capped baseline {want:.3f}x "
            f"(floor {want * (1.0 - tolerance):.3f}x)")

    # --- numerics: backend equivalence stays within fp32 tolerance -------
    diff = current["equivalence"]["logits_max_abs_diff"]
    if diff > 1e-3:
        errors.append(f"backend logits diverged: {diff}")

    # --- int8: memo hit rate, dispatch structure, tolerance, k shift -----
    i8b = baseline.get("int8")
    i8c = current.get("int8")
    if i8b:
        if not i8c:
            errors.append("int8 section missing from current report")
        else:
            rate = i8c["quantize_cache"]["hit_rate_after_warmup"]
            if rate != 1.0:
                errors.append(
                    f"int8 quantize-cache hit rate after warmup is {rate}, "
                    f"expected 1.0 (per-dispatch requantization)")
            if i8c["dispatch_counts"] != i8b["dispatch_counts"]:
                errors.append(
                    f"int8 dispatch_counts changed: "
                    f"{i8c['dispatch_counts']} != baseline "
                    f"{i8b['dispatch_counts']}")
            d8 = i8c["equivalence"]["logits_max_abs_diff_vs_fp32"]
            if d8 > i8c["equivalence"]["documented_atol"]:
                errors.append(f"int8 logits beyond documented tolerance: "
                              f"{d8}")
            if i8c["k_shift_sites"] != i8b["k_shift_sites"]:
                errors.append(
                    f"int8 k_shift_sites changed: {i8c['k_shift_sites']} "
                    f"!= baseline {i8b['k_shift_sites']}")
            c_sh, b_sh = i8c.get("sharded"), i8b.get("sharded")
            if c_sh and b_sh and (c_sh["dispatch_counts"]
                                  != b_sh["dispatch_counts"]):
                errors.append(
                    f"int8 sharded dispatch_counts changed: "
                    f"{c_sh['dispatch_counts']} != baseline "
                    f"{b_sh['dispatch_counts']}")

    # --- w8a8: boundary structure, dispatch counts, tolerance, k shift ---
    w8b = baseline.get("w8a8")
    w8c = current.get("w8a8")
    if w8b:
        if not w8c:
            errors.append("w8a8 section missing from current report")
        else:
            if w8c["quantize_boundary"] != w8b["quantize_boundary"]:
                errors.append(
                    f"w8a8 quantize-boundary op counts changed: "
                    f"{w8c['quantize_boundary']} != baseline "
                    f"{w8b['quantize_boundary']}")
            if w8c["dispatch_counts"] != w8b["dispatch_counts"]:
                errors.append(
                    f"w8a8 dispatch_counts changed: "
                    f"{w8c['dispatch_counts']} != baseline "
                    f"{w8b['dispatch_counts']}")
            dw = w8c["equivalence"]["logits_max_abs_diff_vs_fp32"]
            if dw > w8c["equivalence"]["documented_atol"]:
                errors.append(f"w8a8 logits beyond documented tolerance: "
                              f"{dw}")
            for kf in ("k_fp32", "k_int8", "k_w8a8"):
                if w8c["fused_swiglu"][kf] != w8b["fused_swiglu"][kf]:
                    errors.append(
                        f"w8a8 fused-swiglu {kf} changed: "
                        f"{w8c['fused_swiglu'][kf]} != baseline "
                        f"{w8b['fused_swiglu'][kf]}")
            if w8c["k_shift_sites"] != w8b["k_shift_sites"]:
                errors.append(
                    f"w8a8 k_shift_sites changed: {w8c['k_shift_sites']} "
                    f"!= baseline {w8b['k_shift_sites']}")
            c_sh, b_sh = w8c.get("sharded"), w8b.get("sharded")
            if c_sh and b_sh and (c_sh["dispatch_counts"]
                                  != b_sh["dispatch_counts"]):
                errors.append(
                    f"w8a8 sharded dispatch_counts changed: "
                    f"{c_sh['dispatch_counts']} != baseline "
                    f"{b_sh['dispatch_counts']}")

    # --- paged: stream identity, launch/byte structure, reuse win --------
    pgb = baseline.get("paged")
    pgc = current.get("paged")
    if pgb:
        if not pgc:
            errors.append("paged section missing from current report")
        else:
            if not pgc["streams_identical"]:
                errors.append("paged/dense greedy streams diverged")
            gd = pgc["prefill_gemm_dispatches"]
            if gd["warm"] >= gd["cold"]:
                errors.append(
                    f"prefix reuse stopped cutting prefill GEMM launches: "
                    f"warm {gd['warm']} >= cold {gd['cold']}")
            for field in ("prefill_gemm_dispatches", "prefill_tokens",
                          "prefix_hit_tokens", "pages_used_peak",
                          "dense_kv_bytes", "paged_pool_bytes",
                          "paged_used_peak_bytes", "concurrency_peak"):
                if pgc[field] != pgb[field]:
                    errors.append(
                        f"paged {field} changed: {pgc[field]} != "
                        f"baseline {pgb[field]}")

    # --- resilience: chaos matrix outcomes + stream identity -------------
    rsb = baseline.get("resilience")
    rsc = current.get("resilience")
    if rsb:
        if not rsc:
            errors.append("resilience section missing from current report")
        else:
            zc = rsc["zero_chaos"]
            if not zc["streams_identical"]:
                errors.append("zero-chaos hardened streams diverged from "
                              "the unhardened baseline")
            if zc["chaos_fired"] != 0:
                errors.append(f"zero-probability chaos fired "
                              f"{zc['chaos_fired']} event(s)")
            if not rsc["preemption"]["streams_identical"]:
                errors.append("preempted streams diverged from the "
                              "un-preempted baseline")
            if rsc["preemption"]["preemptions"] < 1:
                errors.append("tight-pool workload no longer forces a "
                              "preemption (the scenario tests nothing)")
            for field in ("zero_chaos", "preemption", "chaos_matrix"):
                if rsc[field] != rsb[field]:
                    errors.append(
                        f"resilience {field} changed: {rsc[field]} != "
                        f"baseline {rsb[field]}")

    # --- disagg: stream identity, handoff structure, per-role k table ----
    dgb = baseline.get("disagg")
    dgc = current.get("disagg")
    if dgb:
        if not dgc:
            errors.append("disagg section missing from current report")
        else:
            if not dgc["streams_identical"]:
                errors.append("disagg/colocated greedy streams diverged")
            if not dgc["prefill_deeper_than_decode"]:
                errors.append(
                    "role pricing no longer splits the boundary argmin: "
                    "prefill best_k not strictly deeper than decode's at "
                    f"every T ({dgc['role_best_k']})")
            for field in ("prefill_chunk", "dispatches",
                          "kv_transfer_bytes", "role_best_k"):
                if dgc[field] != dgb[field]:
                    errors.append(
                        f"disagg {field} changed: {dgc[field]} != "
                        f"baseline {dgb[field]}")
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional regression of perf ratios")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    errors = check(current, baseline, args.tolerance)
    if errors:
        for e in errors:
            print(f"REGRESSION: {e}")
        return 1
    i8 = current.get("int8") or {}
    i8_note = (f", int8 quantize hit rate "
               f"{i8['quantize_cache']['hit_rate_after_warmup']:.0%}, "
               f"{i8['k_shift_sites']} k-shift sites"
               if i8 else "")
    w8 = current.get("w8a8") or {}
    if w8:
        i8_note += (f", w8a8 "
                    f"{w8['quantize_boundary']['int8_int8_dot_generals']} "
                    f"int8xint8 dots / {w8['k_shift_sites']} k-shift sites")
    pg = current.get("paged") or {}
    if pg:
        gd = pg["prefill_gemm_dispatches"]
        i8_note += (f", paged prefill GEMMs {gd['cold']}->{gd['warm']} "
                    f"with prefix reuse")
    dg = current.get("disagg") or {}
    if dg:
        ks = dg["role_best_k"][-1]
        i8_note += (f", disagg boundary k (T={ks['T']}) prefill "
                    f"{ks['k_prefill']} vs decode {ks['k_decode']}")
    print(f"substrate baseline check OK: "
          f"moe launches {current['moe_expert_launches']['per_moe_layer_unrolled']}"
          f"->{current['moe_expert_launches']['per_moe_layer_now']}/layer, "
          f"fused swiglu {_fused_speedup(current):.2f}x "
          f"(baseline {_fused_speedup(baseline):.2f}x), "
          f"logits diff {current['equivalence']['logits_max_abs_diff']:.1e}"
          f"{i8_note}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
