"""Cycle-accurate SA simulator: functional exactness + Eq.(3)/(4) cycles."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import simulator, timing


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("shape", [(4, 8, 8), (10, 16, 24), (7, 32, 16)])
def test_tile_int_csa_exact(k, shape):
    T, R, C = shape
    rng = np.random.RandomState(42)
    A = jnp.asarray(rng.randint(-128, 127, (T, R)), jnp.int32)
    B = jnp.asarray(rng.randint(-128, 127, (R, C)), jnp.int32)
    X, cyc = simulator.simulate_tile(A, B, k)
    np.testing.assert_array_equal(np.asarray(X),
                                  np.asarray(A) @ np.asarray(B))
    assert cyc == timing.latency_cycles(R, C, T, k)


@pytest.mark.parametrize("k", [1, 2])
def test_tile_float(k):
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.randn(6, 8), jnp.float32)
    B = jnp.asarray(rng.randn(8, 8), jnp.float32)
    X, _ = simulator.simulate_tile(A, B, k, use_csa=False)
    np.testing.assert_allclose(np.asarray(X),
                               np.asarray(A) @ np.asarray(B),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(T=st.integers(1, 12),
       nr=st.integers(1, 3), nc=st.integers(1, 3),
       k=st.sampled_from([1, 2, 4]))
def test_tiled_matmul_property(T, nr, nc, k):
    """Tiled execution == plain matmul; cycles == Eq.(4)."""
    R = C = 8
    N, M = nr * R - 3, nc * C - 5          # deliberately ragged
    rng = np.random.RandomState(T * 7 + nr * 3 + nc + k)
    A = jnp.asarray(rng.randint(-64, 64, (T, N)), jnp.int32)
    B = jnp.asarray(rng.randint(-64, 64, (N, M)), jnp.int32)
    X, cycles = simulator.simulate_matmul(A, B, R, C, k)
    np.testing.assert_array_equal(np.asarray(X),
                                  np.asarray(A) @ np.asarray(B))
    assert cycles == timing.total_cycles(M, N, T, R, C, k)


def test_csa_compressor_bit_exact():
    rng = np.random.RandomState(1)
    x, y, z = (jnp.asarray(rng.randint(-2**20, 2**20, 50), jnp.int32)
               for _ in range(3))
    s, c = simulator.csa_3_2(x, y, z)
    np.testing.assert_array_equal(np.asarray(s + c),
                                  np.asarray(x + y + z))


def test_occupancy_trace_totals():
    # total (cycle, column-group) activity == T * n_column_groups * n_stages
    T, R, C, k = 5, 8, 8, 2
    tr = simulator.occupancy_trace(T, R, C, k)
    assert tr.sum() == T * (C // k) * (R // k)
