"""Paged K/V state: page pool, radix prefix tree, memory-bounded engine.

Property tests (hypothesis, or tests/_hypothesis_stub.py when absent)
check the pool/tree invariants the serving engine leans on: refcounts
partition pages exactly, ``match`` returns the longest fully-paged
published prefix (against a reference model), splits preserve lookups,
and eviction only reclaims tree-only (refcount-1) leaves.  Engine tests
check the admission contract: resident concurrency is bounded by the
page budget, not ``max_batch``.
"""
import random

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.core import planner
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request
from repro.serving.paged import PagePool, RadixCache


# ---------------------------------------------------------------- PagePool
def test_pool_alloc_is_deterministic_lowest_first():
    pool = PagePool(8, 4)
    assert pool.alloc(3) == [1, 2, 3]
    pool.decref(2)
    pool.decref(1)
    # freed pages return to the tail and are reused first (LIFO), then
    # the untouched descending tail resumes lowest-first
    assert pool.alloc(5) == [1, 2, 4, 5, 6]
    assert pool.alloc(2) is None            # only page 7 is free
    assert pool.alloc(1) == [7]
    assert pool.n_free == 0


def test_pool_guards_scratch_and_free_pages():
    pool = PagePool(4, 2)
    with pytest.raises(ValueError):
        pool.incref(PagePool.SCRATCH)
    with pytest.raises(ValueError):
        pool.decref(PagePool.SCRATCH)
    with pytest.raises(ValueError):
        pool.incref(1)                      # free: nothing to share
    (pg,) = pool.alloc(1)
    pool.incref(pg)
    pool.decref(pg)
    pool.decref(pg)                         # back to free
    with pytest.raises(ValueError):
        pool.decref(pg)
    with pytest.raises(ValueError):
        PagePool(1, 2)                      # no room for scratch + data


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_pages=st.integers(2, 12))
def test_pool_refcounts_partition_pages(seed, n_pages):
    """Under random alloc/incref/decref traffic, {free} and {refcount>0}
    exactly partition the allocatable pages, and n_free/n_used agree."""
    rng = random.Random(seed)
    pool = PagePool(n_pages, 4)
    held = []                               # (page, model_refcount)
    for _ in range(60):
        op = rng.randrange(3)
        if op == 0:
            got = pool.alloc(rng.randrange(0, n_pages))
            if got is not None:
                held.extend((pg, 1) for pg in got)
        elif op == 1 and held:
            i = rng.randrange(len(held))
            pg, rc = held[i]
            pool.incref(pg)
            held[i] = (pg, rc + 1)
        elif op == 2 and held:
            i = rng.randrange(len(held))
            pg, rc = held[i]
            pool.decref(pg)
            held[i] = (pg, rc - 1)
            if rc == 1:
                held.pop(i)
        model = {}
        for pg, rc in held:
            model[pg] = model.get(pg, 0) + rc
        assert {pg for pg in range(pool.n_pages)
                if pool.refcounts[pg] > 0} == set(model)
        assert all(pool.refcounts[pg] == rc for pg, rc in model.items())
        assert set(pool.free_pages) == (
            set(range(1, n_pages)) - set(model))
        assert pool.n_free + pool.n_used == n_pages - 1


# --------------------------------------------------------------- RadixCache
def test_radix_split_on_mid_node_divergence():
    """A second prompt diverging inside a path-compressed node splits it;
    both full paths and the shared stem keep matching."""
    pool = PagePool(32, 2)
    tree = RadixCache(2)
    a = [1, 1, 2, 2, 3, 3, 4, 4]
    pa = pool.alloc(4)
    tree.insert(a, pa, pool)
    assert tree.n_nodes() == 1 and tree.n_pages() == 4
    b = [1, 1, 2, 2, 9, 9]
    shared = tree.match(b)
    assert shared == pa[:2]
    pb = shared + pool.alloc(1)
    tree.insert(b, pb, pool)
    assert tree.n_nodes() == 3               # stem + two tails
    assert tree.n_pages() == 5               # shared stem stored once
    assert tree.match(a) == pa
    assert tree.match(b) == pb
    assert tree.match([1, 1, 2, 2]) == pa[:2]
    assert tree.match([7, 7]) == []
    # partial pages never match: 5 tokens -> only 2 full pages of prefix
    assert tree.match([1, 1, 2, 2, 3]) == pa[:2]


def test_radix_evict_respects_refcounts_and_lru():
    pool = PagePool(32, 2)
    tree = RadixCache(2)
    pa = pool.alloc(2)
    tree.insert([1, 1, 2, 2], pa, pool)      # refcounts 2 (seq + tree)
    pb = pool.alloc(2)
    tree.insert([5, 5, 6, 6], pb, pool)
    for pg in pa + pb:
        pool.decref(pg)                      # sequences released: tree-only
    tree.match([1, 1, 2, 2])                 # bump A -> B is now LRU
    assert tree.evict(1, pool) == 2          # whole leaf B goes at once
    assert tree.match([5, 5, 6, 6]) == []
    assert tree.match([1, 1, 2, 2]) == pa
    pool.incref(pa[0])                       # a borrower pins A
    assert tree.evict(4, pool) == 0          # nothing evictable left
    pool.decref(pa[0])
    assert tree.evict(4, pool) == 2
    assert tree.n_pages() == 0 and tree.n_nodes() == 0
    assert pool.n_free == pool.n_pages - 1   # every page returned


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), page=st.integers(1, 3))
def test_radix_match_equals_reference_model(seed, page):
    """Random publish/lookup traffic against a flat reference model: the
    trie's match must return exactly the pages of the longest prefix
    whose every full page was published, and the tree must hold exactly
    one pool reference per published page."""
    rng = random.Random(seed)
    pool = PagePool(256, page)
    tree = RadixCache(page)
    published = {}                  # key-path tuple -> physical page
    releasable = []
    for _ in range(10):
        toks = [rng.randrange(3) for _ in range(rng.randrange(0, 9 * page))]
        keys = [tuple(toks[i * page:(i + 1) * page])
                for i in range(len(toks) // page)]
        expect = []
        for i in range(len(keys)):
            pg = published.get(tuple(keys[:i + 1]))
            if pg is None:
                break
            expect.append(pg)
        assert tree.match(toks) == expect
        # admit like the engine: borrow the match, alloc the rest, publish
        for pg in expect:
            pool.incref(pg)
        fresh = pool.alloc(len(keys) - len(expect))
        pages = expect + fresh
        tree.insert(toks[:len(keys) * page], pages, pool)
        for i in range(len(keys)):
            published.setdefault(tuple(keys[:i + 1]), pages[i])
        releasable.extend(pages)
    for pg in releasable:           # every sequence releases its refs
        pool.decref(pg)
    # tree-only now: exactly one reference per published physical page
    assert tree.n_pages() == len(set(published.values()))
    for pg in set(published.values()):
        assert pool.refcounts[pg] == 1
    tree.evict(len(published) + 1, pool)
    assert pool.n_free == pool.n_pages - 1


# ------------------------------------------------------- engine admission
@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_concurrency_exceeds_max_batch(model):
    """Admission is page-budget-bounded: with short requests the engine
    keeps more sequences resident than dispatch rows, round-robining the
    decode ticks — the dense path would cap residency at max_batch."""
    cfg, params = model
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=2, max_seq=32,
                                       kv_pages=40, page_size=8))
    reqs = [Request(prompt=[3 + i, 4 + i, 5 + i], max_new_tokens=4, rid=i)
            for i in range(8)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
    assert engine.stats["concurrency_peak"] > engine.sc.max_batch
    assert engine.stats["concurrency_peak"] == 8
    assert engine.pool.n_used == 0          # all reservations released


def test_engine_admission_blocks_on_page_budget(model):
    """When the pool cannot hold everyone, admission is head-of-line FIFO:
    later requests wait for pages, everyone still completes, and peak page
    usage never exceeds the pool."""
    cfg, params = model
    # 4 data pages; each request reserves ceil((3+4)/8)=1 page -> at most
    # 4 resident, the rest queue head-of-line
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=2, max_seq=32,
                                       kv_pages=5, page_size=8))
    reqs = [Request(prompt=[3 + i, 4 + i, 5 + i], max_new_tokens=4, rid=i)
            for i in range(8)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
    assert engine.stats["concurrency_peak"] <= 4    # 4 data pages
    assert engine.stats["pages_used_peak"] <= 4
    assert engine.pool.n_used == 0


def test_engine_validates_page_geometry(model):
    cfg, params = model
    with pytest.raises(ValueError, match="divide max_seq"):
        ServingEngine(cfg, params,
                      ServeConfig(max_batch=2, max_seq=32,
                                  kv_pages=10, page_size=7))
    with pytest.raises(ValueError, match="need at least"):
        ServingEngine(cfg, params,
                      ServeConfig(max_batch=2, max_seq=32,
                                  kv_pages=2, page_size=8))
    with pytest.raises(ValueError, match="batched"):
        ServingEngine(cfg, params,
                      ServeConfig(max_batch=2, max_seq=32, kv_pages=10,
                                  page_size=8, prefill_mode="token"))


def test_planner_page_plan_divides_max_seq():
    for S in (16, 32, 64, 128, 256):
        page = planner.page_plan(S)
        assert page > 0 and S % page == 0
    # waste pressure: short expected lengths pull the page size down
    assert planner.page_plan(128, expected_len=8) <= \
        planner.page_plan(128, expected_len=128)
