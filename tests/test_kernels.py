"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.arrayflex_gemm import arrayflex_gemm
from repro.kernels.flash_attention import flash_attention

TOL = {jnp.float32: 1e-3, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mkn", [(256, 512, 256), (128, 1024, 384),
                                 (64, 256, 128)])
@pytest.mark.parametrize("k_collapse", [1, 2, 4])
def test_gemm_vs_ref(mkn, dtype, k_collapse):
    M, K, N = mkn
    rng = np.random.RandomState(M + K + N + k_collapse)
    x = jnp.asarray(rng.randn(M, K), dtype)
    w = jnp.asarray(rng.randn(K, N), dtype)
    got = arrayflex_gemm(x, w, bk=64, k_collapse=k_collapse)
    want = ref.gemm_ref(x, w)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("K,bk,k_collapse", [
    (130, 128, 4),    # the seed's silent-wrong-answer case (~4.8 abs error)
    (130, 128, 1),    # K not a multiple of the clamped block
    (96, 64, 4),      # bk * k_collapse > K, K % k_collapse == 0
    (100, 64, 4),     # bk * k_collapse > K, K % k_collapse != 0
    (257, 64, 2),     # prime-ish K, multiple steps with remainder
    (384, 64, 3),     # non-power-of-two collapse, exact tiling
    (70, 32, 3),      # everything ragged
])
def test_gemm_nondivisible_k_exact(K, bk, k_collapse):
    """Any (K, bk, k_collapse) must match jnp.dot to fp32 tolerance."""
    M, N = 64, 128
    rng = np.random.RandomState(K + bk + k_collapse)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    got = arrayflex_gemm(x, w, bk=bk, k_collapse=k_collapse)
    want = ref.gemm_ref(x, w)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)


def test_gemm_empty_dims_are_zero():
    for shape_x, shape_w in (((64, 0), (0, 64)), ((0, 8), (8, 8)),
                             ((8, 8), (8, 0))):
        out = arrayflex_gemm(jnp.zeros(shape_x, jnp.float32),
                             jnp.zeros(shape_w, jnp.float32), k_collapse=4)
        assert out.shape == (shape_x[0], shape_w[1])
        assert not np.any(np.asarray(out))


def test_gemm_rejects_bad_tiling():
    x = jnp.zeros((300, 128), jnp.float32)   # 300 not divisible by bm=128
    w = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError):
        arrayflex_gemm(x, w)
    with pytest.raises(ValueError):
        arrayflex_gemm(jnp.zeros((128, 64)), jnp.zeros((32, 128)))
    with pytest.raises(ValueError):
        arrayflex_gemm(jnp.zeros((128, 64)), jnp.zeros((64, 128)),
                       k_collapse=0)


@pytest.mark.parametrize("M,K,N", [
    (300, 64, 128),    # ragged M > SA tile
    (128, 64, 130),    # ragged N > SA tile
    (200, 130, 200),   # everything ragged (M, K, N)
    (3, 130, 96),      # small ragged M/N (own-tile), ragged K
])
def test_arrayflex_matmul_ragged_mn_exact(M, K, N):
    """Ragged M rows / N columns are zero-padded to the tile grid and
    sliced, never silently dropped and never routed to a fallback."""
    rng = np.random.RandomState(M + K + N)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    for k_collapse in (0, 1, 4):    # 0 = planner-chosen
        got = ops.arrayflex_matmul(x, w, k_collapse=k_collapse)
        np.testing.assert_allclose(np.float32(got),
                                   np.float32(ref.gemm_ref(x, w)),
                                   rtol=1e-5, atol=1e-4)


def test_arrayflex_matmul_out_dtype():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 64), jnp.bfloat16)
    w = jnp.asarray(rng.randn(64, 128), jnp.bfloat16)
    out = ops.arrayflex_matmul(x, w, out_dtype=jnp.float32)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        np.float32(out), np.float32(ref.gemm_ref(x, w, jnp.float32)),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("T,kv_chunk", [(97, 64), (320, 128), (130, 64)])
def test_flash_ragged_kv_matches_ref(T, kv_chunk):
    """The flash kernel pads ragged KV to the chunk grid and masks the
    tail, so the planner's chunk pick runs as-is."""
    rng = np.random.RandomState(T)
    q = jnp.asarray(rng.randn(2, 64, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, T, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, T, 32), jnp.float32)
    for causal in (True, False):
        got = flash_attention(q, k, v, causal=causal, bq=32,
                              kv_chunk=kv_chunk)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-3, atol=1e-3)


def test_gemm_collapse_invariance():
    """Property: results identical across collapse depths (same math)."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(128, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 128), jnp.float32)
    outs = [np.float32(arrayflex_gemm(x, w, bk=64, k_collapse=k))
            for k in (1, 2, 4)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    dict(BH=4, S=256, T=256, D=64, causal=True, window=0),
    dict(BH=2, S=128, T=256, D=64, causal=False, window=0),
    dict(BH=3, S=256, T=256, D=64, causal=True, window=96),
    dict(BH=2, S=256, T=256, D=128, causal=True, window=0),
])
def test_flash_vs_ref(cfg, dtype):
    rng = np.random.RandomState(cfg["S"] + cfg["D"])
    q = jnp.asarray(rng.randn(cfg["BH"], cfg["S"], cfg["D"]), dtype)
    k = jnp.asarray(rng.randn(cfg["BH"], cfg["T"], cfg["D"]), dtype)
    v = jnp.asarray(rng.randn(cfg["BH"], cfg["T"], cfg["D"]), dtype)
    got = flash_attention(q, k, v, causal=cfg["causal"],
                          window=cfg["window"], bq=64, kv_chunk=64)
    want = ref.attention_ref(q, k, v, causal=cfg["causal"],
                             window=cfg["window"])
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=TOL[dtype], atol=TOL[dtype])


def test_planner_driven_wrappers():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(4, 64, 256), jnp.float32)   # leading dims
    w = jnp.asarray(rng.randn(256, 128), jnp.float32)
    got = ops.arrayflex_matmul(x, w)
    want = ref.gemm_ref(x.reshape(-1, 256), w).reshape(4, 64, 128)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-3, atol=1e-3)
    assert ops.plan_collapse(128, 256, 64) in (1, 2, 4)
    # empty shapes return exact zeros; ragged shapes run the kernel (padded)
    empty = ops.arrayflex_matmul(jnp.zeros((0, 130), jnp.float32),
                                 jnp.zeros((130, 128), jnp.float32))
    assert empty.shape == (0, 128)
    ragged = ops.arrayflex_matmul(jnp.ones((3, 130), jnp.float32),
                                  jnp.ones((130, 128), jnp.float32))
    np.testing.assert_allclose(np.float32(ragged), 130.0, rtol=1e-5)

    q = jnp.asarray(rng.randn(2, 128, 64), jnp.float32)
    k = jnp.asarray(rng.randn(2, 320, 64), jnp.float32)   # non-pow2 T
    v = jnp.asarray(rng.randn(2, 320, 64), jnp.float32)
    got = ops.attention(q, k, v, causal=False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-3, atol=1e-3)
