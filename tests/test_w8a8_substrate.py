"""W8A8 ArrayFlex backend: dynamic per-tile activation quantization.

Covers the quantizer itself (property tests over ``quantize_tile``), the
int8 x int8 -> int32 kernel MAC path (jaxpr acceptance assertion), the
Eq.(5') activation-quantize boundary term and its k-shift, exempt-site
routing, and the model-level equivalence matrix
w8a8 x {dense, MoE, Mamba} x {unsharded, TP2}.

Tolerance contract (documented here and in docs/substrate.md):

* quantizer level — ``quantize_tile`` round-trips with per-element error
  <= ``scale / 2 = amax / 254``; an all-zero tile yields all-zero codes
  (zero K-padding tails contribute exactly 0 to the accumulator).
* kernel level, single-tile shapes — when the whole operand fits one
  (bm, bk) grid tile the in-kernel quantizer sees exactly the full
  operand, so the w8a8 dispatch must equal the fake-quantized fp32
  oracle — per-tile-quantized activation against per-output-channel
  quantized weight — to fp32 accumulation tolerance
  (atol 1e-4): the kernel adds NO error beyond quantization.
* model level vs the fp32 arrayflex backend — per-tile activation
  rounding adds ~0.4% relative error per GEMM on top of the W8 weight
  error; on the reduced fp32 configs: dense/Mamba ``atol=0.12``
  (observed ~0.031 on logit scale ~0.55).  The MoE family amplifies it
  through router top-k flips on near-tie tokens exactly as under W8:
  ``atol=2.5`` (observed ~1.13 on logit scale ~3.0).
* sharded (TP2) w8a8 vs unsharded w8a8 — NOT bit-exact, unlike W8: a
  row-parallel shard re-tiles the contraction, so the per-tile
  activation scales differ from the unsharded tiling.  The discrepancy
  is quantization-noise sized and bounded by the same family tolerances
  (observed ~0.022 dense / ~0.026 Mamba / ~1.14 MoE).
* greedy streams — bit-identical run-to-run per backend, and on the
  pinned prompts identical to the fp32 arrayflex stream (top-1 margins
  exceed the quantization perturbation; deterministic on CPU).
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.core import planner, timing
from repro.kernels import ops, substrate
from repro.kernels.arrayflex_gemm import quantize_tile
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# model-level w8a8-vs-fp32 tolerance per family (see module docstring)
ATOL = {"qwen2-0.5b": 0.12, "mamba2-370m": 0.12, "qwen3-moe-30b-a3b": 2.5}


def _cfg(arch, backend="xla", mesh=()):
    return reduced(ARCHS[arch], compute_dtype="float32",
                   param_dtype="float32", gemm_backend=backend,
                   mesh_shape=mesh)


_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        _PARAMS[arch] = lm.init_params(_cfg(arch), jax.random.PRNGKey(0))
    return _PARAMS[arch]


_TOKS = np.random.RandomState(0).randint(2, 512, (2, 16))


def _fake_quant(a):
    """Per-tile fake-quant of an activation (quantize_tile semantics)."""
    q, s = quantize_tile(jnp.asarray(a, jnp.float32))
    return q.astype(jnp.float32) * s


def _dequant_w(w):
    """Per-output-channel fake-quant of a weight (quantize_weight
    semantics — the weight side of W8A8 is identical to W8)."""
    q, s = substrate._quantize(w)
    return q.astype(jnp.float32) * s[..., None, :]


# ----------------------------------------------------------- registration
def test_w8a8_backend_registered_with_metadata():
    assert "arrayflex_w8a8" in substrate.backends()
    info = substrate._BACKEND_INFO["arrayflex_w8a8"]
    assert info.collapse and info.quantize and info.act_quantize
    assert info.precision == "w8a8"
    # W8 quantizes weights only; its activations stay fp32
    assert not substrate._BACKEND_INFO["arrayflex_int8"].act_quantize
    assert substrate.backend_act_quantizes("arrayflex_w8a8")
    assert not substrate.backend_act_quantizes("arrayflex_int8")


def test_register_act_quantize_requires_quantize():
    """An activation-only int8 path has no dequant-scale story — the
    registry must reject the inconsistent capability combination."""
    with pytest.raises(ValueError, match="act_quantize requires quantize"):
        substrate.register_backend("_a8", lambda *a: None,
                                   precision="int8", act_quantize=True)
    assert "_a8" not in substrate.backends()


# ------------------------------------------- quantizer property tests
@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       rows=st.sampled_from([1, 3, 8, 128]),
       cols=st.sampled_from([1, 7, 128]),
       log_mag=st.floats(-6.0, 6.0))
def test_quantize_tile_round_trip_bound(seed, rows, cols, log_mag):
    """codes * scale reproduces the tile within scale/2 = amax/254 per
    element, across magnitudes spanning twelve decades."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, cols) * 10.0 ** log_mag, jnp.float32)
    codes, scale = quantize_tile(x)
    assert codes.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(codes))) <= 127
    amax = float(jnp.max(jnp.abs(x)))
    assert float(scale) == pytest.approx(max(amax, 1e-12) / 127.0, rel=1e-6)
    err = np.abs(np.float32(codes) * float(scale) - np.float32(x))
    assert float(err.max()) <= float(scale) / 2 + 1e-12 * amax


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       rows=st.sampled_from([1, 5, 8]),
       cols=st.sampled_from([3, 7, 100]),
       pad_to=st.sampled_from([8, 128]))
def test_quantize_tile_zero_and_ragged_tail(seed, rows, cols, pad_to):
    """An all-zero tile quantizes to all-zero codes (finite scale, no
    NaN), and a zero-padded ragged tail neither changes the tile's scale
    nor contributes nonzero codes — K-padding is exact through the
    quantizer, so the padded accumulator matches the unpadded one."""
    zc, zs = quantize_tile(jnp.zeros((rows, cols), jnp.float32))
    assert float(zs) > 0 and not np.isnan(float(zs))
    assert int(jnp.max(jnp.abs(zc))) == 0
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, cols), jnp.float32)
    xp = jnp.pad(x, ((0, 0), (0, max(pad_to - cols, 0))))
    c, s = quantize_tile(x)
    cp, sp = quantize_tile(xp)
    assert float(s) == float(sp)
    np.testing.assert_array_equal(np.asarray(cp[:, :cols]), np.asarray(c))
    assert int(jnp.max(jnp.abs(cp[:, cols:]))) == 0 if pad_to > cols else True


@settings(max_examples=20, deadline=None)
@given(kk=st.sampled_from([128, 256, 512]), sign=st.booleans())
def test_int32_accumulator_no_overflow_at_max_tile(kk, sign):
    """Worst-case int8 x int8 dot at the largest contraction tile the
    kernel ever runs (bk <= 512): |acc| <= kk * 127^2 ~= 8.3e6, five
    orders below the int32 ceiling — the per-step accumulator cannot
    wrap, so deferring the scale fold to fp32 is exact."""
    v = (-127 if sign else 127) * jnp.ones((1, kk), jnp.int8)
    w = 127 * jnp.ones((kk, 1), jnp.int8)
    acc = jnp.dot(v, w, preferred_element_type=jnp.int32)
    assert acc.dtype == jnp.int32
    assert int(acc[0, 0]) == (-1 if sign else 1) * kk * 127 * 127
    assert kk * 127 * 127 < np.iinfo(np.int32).max // 256


# -------------------------------------------- kernel-level exactness
@pytest.mark.parametrize("epilogue,bias", [
    ("none", False), ("silu", True), ("swiglu", True),
])
def test_w8a8_single_tile_matches_fake_quant_oracle(epilogue, bias):
    """Single-tile shapes: the in-kernel quantizer sees the whole
    operand, so w8a8 == fake-quantized fp32 oracle exactly (atol 1e-4) —
    the kernel's int8 MAC + deferred scale fold adds no error beyond
    quantization."""
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(8, 64), jnp.float32)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    w2 = jnp.asarray(rng.randn(64, 32), jnp.float32) \
        if epilogue == "swiglu" else None
    b = jnp.asarray(rng.randn(32), jnp.float32) if bias else None
    got = substrate.gemm(x, w, backend="arrayflex_w8a8", epilogue=epilogue,
                         w2=w2, bias=b)
    want = substrate.gemm(_fake_quant(x), _dequant_w(w), backend="xla",
                          epilogue=epilogue,
                          w2=None if w2 is None else _dequant_w(w2), bias=b)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-4)


def test_w8a8_multi_tile_tracks_fp32():
    """Ragged multi-tile shapes: per-tile scales differ from the global
    scale, so there is no closed-form oracle — bound the relative error
    against fp32 at the combined W8+A8 noise level instead."""
    rng = np.random.RandomState(8)
    x = jnp.asarray(rng.randn(300, 200), jnp.float32)
    w = jnp.asarray(rng.randn(200, 260), jnp.float32)
    got = substrate.gemm(x, w, backend="arrayflex_w8a8")
    want = substrate.gemm(x, w, backend="xla")
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.05, rel
    # residual fuses through the same store (satellite: fused sublayer add)
    r = jnp.asarray(rng.randn(300, 260), jnp.float32)
    got_r = substrate.gemm(x, w, backend="arrayflex_w8a8", residual=r)
    np.testing.assert_allclose(np.float32(got_r), np.float32(got + r),
                               rtol=1e-5, atol=1e-5)


def test_w8a8_expert_gemm_tracks_reference():
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(2, 3, 5, 16), jnp.float32)     # (G,E,C,K)
    w = jnp.asarray(rng.randn(3, 16, 24), jnp.float32)       # (E,K,N)
    got = substrate.expert_gemm(x, w, backend="arrayflex_w8a8")
    want = jnp.einsum("gecd,edf->gecf", x, w)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.05, rel


# ----------------------------- acceptance: the int8 MAC path engages
def _int8_dot_count(closed):
    n = 0
    from repro.analysis.jaxpr_audit import iter_eqns
    for eqn in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        dts = {str(v.aval.dtype) for v in eqn.invars}
        if dts == {"int8"} and str(eqn.outvars[0].aval.dtype) == "int32":
            n += 1
    return n


def test_w8a8_kernel_stages_int8_int8_int32_dot():
    """Acceptance: the traced w8a8 dispatch carries dot_general equations
    with BOTH operands int8 and an int32 result — the int8 x int8 MAC
    path actually engages in-kernel.  Neither the fp32 nor the
    weight-only W8 backend stages any (W8 dequants the weight before its
    fp32 dot)."""
    x = jnp.ones((8, 256), jnp.float32)
    w = jnp.ones((256, 32), jnp.float32)

    def n_dots(backend):
        closed = jax.make_jaxpr(
            lambda a, b: substrate.gemm(a, b, backend=backend))(x, w)
        return _int8_dot_count(closed)

    assert n_dots("arrayflex_w8a8") >= 1
    assert n_dots("arrayflex") == 0
    assert n_dots("arrayflex_int8") == 0


# ------------------------------------------- w8a8-aware planning
def test_w8a8_timing_params():
    tp = timing.W8A8_TIMING
    assert timing.timing_for("w8a8") is tp
    assert tp.d_actq_ps > 0
    # the quantize boundary term prices per-step: period grows with it
    assert tp.clock_period_ps(2, actq_ops=1) > tp.clock_period_ps(2)
    # fp32/int8 datapaths never charge it
    assert timing.DEFAULT_TIMING.d_actq_ps == 0
    assert timing.INT8_TIMING.d_actq_ps == 0


def test_actq_term_shifts_best_k_at_model_shape():
    """Acceptance: the pinned decode GEMM (M, N, T) = (896, 4864, 512)
    plans k=2 on the w8a8 datapath with the quantizer UNpriced, and k=4
    with the Eq.(5') actq term priced — the activation-quantize boundary
    stage itself tips the argmin toward deeper collapse."""
    M, N, T = 896, 4864, 512
    assert ops.plan_collapse(M, N, T) == 2                       # fp32
    assert ops.plan_collapse(M, N, T, precision="w8a8") == 2     # no actq
    assert ops.plan_collapse(M, N, T, precision="w8a8",
                             actq_ops=1) == 4                    # actq priced
    p = substrate.plan_gemm(M, N, T, "arrayflex_w8a8")
    pf = substrate.plan_gemm(M, N, T, "arrayflex")
    assert (pf.k, p.k) == (2, 4)
    assert p.precision == "w8a8" and p.t_pred_ps < pf.t_pred_ps


def test_plan_prices_actq_and_dequant_together():
    """The cached plan charges BOTH the dequant boundary multiply
    (epilogue_ops) and the activation-quantize stage (actq_ops)."""
    p = substrate.plan_gemm(256, 128, 64, "arrayflex_w8a8")
    want = timing.t_abs_ps(256, 128, 64, ops.SA_R, ops.SA_C, p.k,
                           params=timing.W8A8_TIMING, epilogue_ops=1,
                           actq_ops=1)
    assert p.t_pred_ps == want
    # analytic planner table agrees
    g = planner.GEMM("mlp.wo", 256, 128, 64)
    lp = planner.plan_gemm_precision(g, 128, 128, "w8a8")
    assert lp.t_abs_ps == p.t_pred_ps and lp.k == p.k


def test_precision_table_three_way():
    rows = planner.precision_table(_cfg("qwen2-0.5b"),
                                   planner.ShapeConfig("t", 8, 2, "train"))
    assert rows
    assert all({"fp32", "int8", "w8a8"} <= set(r["plans"]) for r in rows)
    # the w8a8 datapath beats fp32 at every site despite the actq stage
    assert all(r["plans"]["w8a8"].t_abs_ps < r["plans"]["fp32"].t_abs_ps
               for r in rows)


# ------------------------------------------- exempt-site routing
def test_w8a8_exempt_and_actq_sites():
    """moe.router stays on the fp32 arrayflex base (bit-for-bit); the
    batched attn.qk quantizes (both operands are activations) while
    attn.pv stays exempt (softmax probability mass would be crushed by
    symmetric per-tile int8)."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(6, 8), jnp.float32)
    w = jnp.asarray(rng.randn(8, 4), jnp.float32)
    substrate.clear_plan_cache()
    got = substrate.gemm(x, w, site="moe.router", backend="arrayflex_w8a8")
    want = substrate.gemm(x, w, site="moe.router", backend="arrayflex")
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-6, atol=1e-6)
    assert substrate.SITE_PLANS["moe.router"].precision == "fp32"
    assert "attn.qk" in substrate.BATCHED_ACTQ_SITES
    assert "attn.pv" not in substrate.BATCHED_ACTQ_SITES
    q = jnp.asarray(rng.randn(2, 16, 32), jnp.float32)
    kT = jnp.asarray(rng.randn(2, 32, 16), jnp.float32)
    substrate.clear_plan_cache()
    qk = substrate.batched_gemm(q, kT, site="attn.qk",
                                backend="arrayflex_w8a8")
    assert substrate.SITE_PLANS["attn.qk"].precision == "w8a8"
    ref = substrate.batched_gemm(q, kT, site="attn.qk", backend="xla")
    rel = float(jnp.linalg.norm(qk - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05, rel
    substrate.batched_gemm(q, kT, site="attn.pv", backend="arrayflex_w8a8")
    assert substrate.SITE_PLANS["attn.pv"].precision == "fp32"
    substrate.clear_plan_cache()


# --------------------------------------- model-level equivalence matrix
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m"])
def test_w8a8_forward_and_decode_match_fp32(arch):
    """w8a8 x {dense, MoE, Mamba}, unsharded: logits within the
    documented tolerance of the fp32 arrayflex backend, and the family's
    weight GEMMs really planned the w8a8 datapath."""
    toks = jnp.asarray(_TOKS, jnp.int32)
    params = _params(arch)
    want, _, _ = lm.forward(_cfg(arch, "arrayflex"), params,
                            {"tokens": toks})
    substrate.SITE_PLANS.clear()
    got, _, _ = lm.forward(_cfg(arch, "arrayflex_w8a8"), params,
                           {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               atol=ATOL[arch])
    family = ({"mamba.z", "mamba.xbc", "mamba.out"} if arch == "mamba2-370m"
              else {"moe.wi_gate", "moe.wo"} if "moe" in arch
              else {"attn.wq", "mlp.wi_gate", "unembed"})
    for s in family:
        p = substrate.SITE_PLANS[s]
        assert p.backend == "arrayflex_w8a8" and p.precision == "w8a8", s
    tok = jnp.asarray([3, 5], jnp.int32)
    want, _ = lm.decode_step(_cfg(arch, "arrayflex"), params,
                             lm.init_cache(_cfg(arch), 2, 8), tok,
                             jnp.int32(0))
    got, _ = lm.decode_step(_cfg(arch, "arrayflex_w8a8"), params,
                            lm.init_cache(_cfg(arch), 2, 8), tok,
                            jnp.int32(0))
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               atol=ATOL[arch])


def test_w8a8_greedy_streams_bit_identical():
    """Acceptance: greedy streams are bit-identical run-to-run under
    w8a8, and on the pinned prompts identical to the fp32 arrayflex
    stream (the perturbation never flips a top-1 margin here)."""
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]

    def run(backend):
        cfg = _cfg("qwen2-0.5b", backend)
        eng = ServingEngine(cfg, _params("qwen2-0.5b"),
                            ServeConfig(max_batch=2, max_seq=32))
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    first = run("arrayflex_w8a8")
    assert first == run("arrayflex_w8a8")        # run-to-run determinism
    assert first == run("arrayflex")


def test_w8a8_one_launch_per_site():
    """The w8a8 backend keeps the fused/batched launch structure — one
    launch per site, including the fused swiglu pair and the
    expert-batched MoE sites."""
    for arch in ("qwen2-0.5b", "qwen3-moe-30b-a3b"):
        cfg = _cfg(arch, "arrayflex_w8a8")
        substrate.clear_plan_cache()
        jax.eval_shape(lambda p, b, c=cfg: lm.forward(c, p, b),
                       _params(arch), {"tokens": jnp.ones((2, 8), jnp.int32)})
        counts = dict(substrate.DISPATCH_COUNTS)
        assert all(v == 1 for v in counts.values()), counts
        if "moe" in arch:
            assert {"moe.router", "moe.wi_gate", "moe.wi_up",
                    "moe.wo"} <= set(counts)
        else:
            assert "mlp.wi_gate+mlp.wi_up" in counts
    substrate.clear_plan_cache()


# --------------------------------------- multi-device TP2 cells (8 dev)
@needs8
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m"])
def test_multidev_w8a8_tp2_matches_unsharded(arch):
    """w8a8 x {dense, MoE, Mamba} x TP2.  Unlike W8, TP2 w8a8 is NOT
    bit-exact vs unsharded w8a8 — row-parallel shards re-tile the
    contraction and the per-tile activation scales move with the tiling —
    but the drift is quantization-noise sized (same family tolerances),
    and TP2 stays within the documented bound of fp32 arrayflex."""
    toks = jnp.asarray(_TOKS, jnp.int32)
    params = _params(arch)
    un, _, _ = lm.forward(_cfg(arch, "arrayflex_w8a8"), params,
                          {"tokens": toks})
    tp, _, _ = lm.forward(_cfg(arch, "arrayflex_w8a8", (1, 2)), params,
                          {"tokens": toks})
    np.testing.assert_allclose(np.float32(tp), np.float32(un),
                               atol=ATOL[arch])
    fp, _, _ = lm.forward(_cfg(arch, "arrayflex"), params,
                          {"tokens": toks})
    np.testing.assert_allclose(np.float32(tp), np.float32(fp),
                               atol=ATOL[arch])


@needs8
def test_multidev_w8a8_tp2_stream_and_plans():
    """TP2 w8a8 greedy stream matches the unsharded w8a8 stream on the
    pinned prompts; row-parallel plans record w8a8 precision WITH the
    reduce boundary priced, and dispatch stays one launch per site."""
    params = _params("qwen2-0.5b")
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]

    def run(mesh):
        eng = ServingEngine(_cfg("qwen2-0.5b", "arrayflex_w8a8", mesh),
                            params, ServeConfig(max_batch=2, max_seq=32))
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    assert run((1, 2)) == run(())
    substrate.clear_plan_cache()
    cfg = _cfg("qwen2-0.5b", "arrayflex_w8a8", (1, 2))
    jax.eval_shape(lambda p, b: lm.forward(cfg, p, b), params,
                   {"tokens": jnp.asarray(_TOKS, jnp.int32)})
    assert all(v == 1 for v in substrate.DISPATCH_COUNTS.values())
    wo = substrate.SITE_PLANS["attn.wo"]
    assert wo.precision == "w8a8" and wo.shard.reduce_ops == 1
    wq = substrate.SITE_PLANS["attn.wq"]
    assert wq.precision == "w8a8" and wq.shard.cols == 2
    substrate.clear_plan_cache()


# ------------------------------------------- tier-1 subprocess coverage
def test_w8a8_sharded_equivalence_subprocess():
    """On a single-device host, run the multidev w8a8 cells once in an
    8-device subprocess so tier-1 always covers the TP2 column."""
    if len(jax.devices()) >= 8:
        pytest.skip("multi-device host runs test_multidev_* directly")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join("tests", "test_w8a8_substrate.py"),
         "-k", "multidev"],
        capture_output=True, text=True, timeout=1500, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout[-4000:] + out.stderr[-2000:]
    assert "passed" in out.stdout
