"""Static-analysis subsystem: seeded violations for every finding code,
clean runs over the backend x model matrix, and the strict-audit runtime
enforcement.

Each seeded test plants exactly one contract violation and asserts the
matching pass fails loudly with the *distinct* finding code — proving the
auditor detects what it claims to detect, not just that clean code
passes.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import ast_lint, jaxpr_audit, kernel_check
from repro.analysis.findings import CODES, Finding, Report
from repro.configs import get_config, reduced
from repro.core import planner
from repro.kernels import substrate
from repro.kernels.arrayflex_gemm import store_phase

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def codes(findings):
    return sorted({f.code for f in findings})


# ---------------------------------------------------------------------------
# findings / report plumbing

def test_finding_severity_defaults_from_codes():
    assert Finding("AF001", "x", "m").severity == "error"
    assert Finding("AF008", "x", "m").severity == "warning"
    assert Finding("ZZ999", "x", "m").severity == "error"   # unknown: strict


def test_report_exit_code_and_json():
    r = Report()
    r.extend([Finding("AF008", "a", "warn-only")])
    assert r.ok and r.exit_code == 0 and len(r.warnings) == 1
    r.extend([Finding("AF001", "b", "boom")])
    assert not r.ok and r.exit_code == 1
    d = r.to_dict()
    assert d["n_errors"] == 1 and d["n_warnings"] == 1
    assert d["findings"][1]["code"] == "AF001"


def test_every_code_documented():
    for code, (sev, desc) in CODES.items():
        assert sev in ("error", "warning") and desc, code


# ---------------------------------------------------------------------------
# jaxpr auditor: clean matrix

CLEAN_CELLS = [
    ("qwen2-0.5b", "xla"),
    ("qwen2-0.5b", "arrayflex"),
    ("qwen3-moe-30b-a3b", "arrayflex"),
    ("mamba2-370m", "arrayflex"),
]


@pytest.mark.parametrize("arch,backend", CLEAN_CELLS,
                         ids=[f"{a}-{b}" for a, b in CLEAN_CELLS])
def test_jaxpr_audit_clean(arch, backend):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              gemm_backend=backend)
    findings = jaxpr_audit.audit_model(cfg)
    errors = [f for f in findings if f.severity == "error"]
    assert not errors, "\n".join(str(f) for f in errors)


def test_jaxpr_audit_int8_warns_af008_only():
    """The int8 path necessarily stages quantize_weight under make_jaxpr
    (the ROADMAP W8A8 hoist): warnings, never errors."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              gemm_backend="arrayflex_int8")
    findings = jaxpr_audit.audit_model(cfg)
    assert not [f for f in findings if f.severity == "error"], \
        "\n".join(str(f) for f in findings)
    assert codes([f for f in findings if f.severity == "warning"]) \
        == ["AF008"]


def test_jaxpr_audit_int8_prequantized_clean():
    """With lm.prequantize_params hoisting quantization out of the trace
    (the serving-engine path), the int8 audit goes fully clean: the AF008
    staged-requantize warnings of the raw-tree path disappear."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              gemm_backend="arrayflex_int8")
    findings = jaxpr_audit.audit_model(cfg, prequantize=True)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_jaxpr_audit_w8a8_prequantized_clean():
    """The W8A8 backend's dynamic activation quantization (in-kernel
    quantize_tile per tile, batched-QK _quantize of K) is declared via
    BackendInfo.act_quantize and priced by the Eq.(5') actq term — the
    auditor must classify it clean, not AF003/AF008."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              gemm_backend="arrayflex_w8a8")
    findings = jaxpr_audit.audit_model(cfg, prequantize=True)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_jaxpr_audit_w8a8_raw_tree_warns_af008_only():
    """Raw-tree W8A8 stages weight quantization like W8: AF008 warnings
    only — the activation-quantize casts must not add AF003 errors."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              gemm_backend="arrayflex_w8a8")
    findings = jaxpr_audit.audit_model(cfg)
    assert not [f for f in findings if f.severity == "error"], \
        "\n".join(str(f) for f in findings)
    assert codes(findings) == ["AF008"]


def test_jaxpr_audit_w8a8_actq_declaration_is_load_bearing():
    """The same W8A8 trace audited WITHOUT the act_quantize declaration
    must flag the in-kernel activation casts as rogue AF003 — proving the
    classifier keys on the backend's declared capability, not on blanket
    int8-cast tolerance."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              gemm_backend="arrayflex_w8a8")
    entries = jaxpr_audit._trace_entries(cfg, prequantize=True)
    substrate.clear_plan_cache()
    try:
        closed = entries[0][1]()                    # forward
        undeclared = jaxpr_audit.audit_closed_jaxpr(
            closed, quantized=True, act_quantized=False)
        assert "AF003" in codes(undeclared)
        declared = jaxpr_audit.audit_closed_jaxpr(
            closed, quantized=True, act_quantized=True)
        assert declared == [], "\n".join(str(f) for f in declared)
    finally:
        substrate.clear_plan_cache()


# ---------------------------------------------------------------------------
# jaxpr auditor: seeded violations (one per code)

def test_seeded_af001_bypass_gemm():
    def bypass(x, w):
        return x @ w                    # test-file frames: unattributed

    closed = jax.make_jaxpr(bypass)(jnp.ones((4, 8)), jnp.ones((8, 4)))
    assert codes(jaxpr_audit.audit_closed_jaxpr(closed)) == ["AF001"]


def test_seeded_af002_bf16_psum_on_quantized_path():
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    f = shard_map(lambda x: jax.lax.psum(x, "model"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    closed = jax.make_jaxpr(f)(jnp.ones((4, 4), jnp.bfloat16))
    found = jaxpr_audit.audit_closed_jaxpr(closed, quantized=True)
    assert codes(found) == ["AF002"]
    # same trace on a non-quantized path, no substrate frames: tolerated
    assert jaxpr_audit.audit_closed_jaxpr(closed, quantized=False) == []


def test_seeded_af002_unpriced_psum_boundary():
    """Sharding-contract leg: a substrate psum staged while NO recorded
    plan priced a reduce boundary (ShardSig.reduce_ops == 0) trips AF002.

    Seeded through the real dispatch pipeline: a ShardCtx with forced
    ``reduce_axes`` over a 1-device mesh makes ``_sharded_gemm`` take the
    psum path while ``signature()`` prices ceil(log2(1)) == 0 reduce ops
    — exactly the 'combine tree rode free' drift the check exists for
    (the production sharding rules only set reduce_axes when tp > 1)."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    ctx = substrate.ShardCtx(mesh, P(None, "model"), P("model", None),
                             P(None, None), reduce_axes=("model",))
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    substrate.clear_plan_cache()
    try:
        closed = jax.make_jaxpr(
            lambda a, b: substrate.gemm(a, b, site="mlp.wo", shard=ctx))(x, w)
        plan = substrate.SITE_PLANS["mlp.wo"]
        assert plan.shard.reduce_ops == 0          # the seeded mispricing
        found = jaxpr_audit.check_psum_boundaries(closed, quantized=True)
        assert found and codes(found) == ["AF002"]
        assert "reduce_ops" in found[0].message
        # same trace with the reduce priced somewhere: clean
        priced = dataclasses.replace(
            plan, shard=dataclasses.replace(plan.shard, reduce_ops=1))
        assert jaxpr_audit.check_psum_boundaries(
            closed, quantized=True, site_plans={"mlp.wo": priced}) == []
        # the leg only binds quantized backends (fp32 paths keep the
        # dtype-only AF002 semantics)
        assert jaxpr_audit.check_psum_boundaries(closed,
                                                 quantized=False) == []
    finally:
        substrate.clear_plan_cache()


def test_seeded_af003_rogue_int8_cast():
    closed = jax.make_jaxpr(
        lambda w: w.astype(jnp.int8).astype(jnp.float32) @ w)(
            jnp.ones((8, 8)))
    found = jaxpr_audit.audit_closed_jaxpr(closed)
    assert "AF003" in codes(found)


def test_seeded_af004_bf16_pallas_accumulator():
    def kernel(x_ref, o_ref, acc_ref):
        acc_ref[...] = x_ref[...].astype(jnp.bfloat16)
        o_ref[...] = acc_ref[...].astype(jnp.float32)

    f = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.bfloat16)])
    closed = jax.make_jaxpr(f)(jnp.ones((8, 128), jnp.float32))
    assert codes(jaxpr_audit.audit_closed_jaxpr(closed)) == ["AF004"]


def test_seeded_af007_unknown_site_label():
    substrate.clear_plan_cache()
    try:
        found = jaxpr_audit.check_recorded_sites(
            counts={"attn.wq": 1, "bogus.site": 2})
        assert codes(found) == ["AF007"]
        assert "bogus.site" in found[0].message or \
            "bogus.site" in found[0].where
    finally:
        substrate.clear_plan_cache()


def test_seeded_af007_config_foreign_site():
    """A planner-known label that is not in this config's own GEMM walk
    still trips the per-config cross-check (e.g. an MoE site recorded
    while tracing a dense model)."""
    dense = reduced(get_config("qwen2-0.5b"))
    found = jaxpr_audit.check_recorded_sites(dense,
                                             counts={"moe.router": 1})
    assert codes(found) == ["AF007"]
    moe = reduced(get_config("qwen3-moe-30b-a3b"))
    assert jaxpr_audit.check_recorded_sites(moe,
                                            counts={"moe.router": 1}) == []


# ---------------------------------------------------------------------------
# kernel <-> timing consistency

def test_kernel_check_clean():
    assert kernel_check.run() == []


def test_seeded_af005_store_drops_bias():
    def broken_store(y, y2=None, w_scale=None, w2_scale=None, bias=None,
                     bias2=None, residual=None, activation="none"):
        return store_phase(y, y2, w_scale, w2_scale, None, bias2,
                           activation, residual)  # silently ignores bias

    found = kernel_check.check_epilogue_pricing(store_fn=broken_store)
    assert found and codes(found) == ["AF005"]
    assert all("bias=True" in f.where for f in found)


def test_seeded_af005_extra_unpriced_op():
    def gilded_store(y, y2=None, w_scale=None, w2_scale=None, bias=None,
                     bias2=None, residual=None, activation="none"):
        out = store_phase(y, y2, w_scale, w2_scale, bias, bias2,
                          activation, residual)
        return out * jnp.tanh(out)            # fused but never priced

    found = kernel_check.check_epilogue_pricing(store_fn=gilded_store)
    assert found and codes(found) == ["AF005"]


def test_seeded_af006_undeclared_gemmcall_field():
    keying = dict(substrate.CALL_FIELD_KEYING)
    del keying["bias"]                        # field with no keying story
    found = kernel_check.check_plan_key(call_keying=keying)
    assert codes(found) == ["AF006"]
    assert any("GemmCall.bias" in f.where for f in found)


def test_seeded_af006_stale_declaration_and_bad_attr():
    keying = dict(substrate.CALL_FIELD_KEYING)
    keying["ghost"] = "operand: field that no longer exists"
    keying["bias"] = "epilogue:no_such_attr"
    found = kernel_check.check_plan_key(call_keying=keying)
    assert codes(found) == ["AF006"] and len(found) == 2


def test_seeded_af006_noncompare_key_field():
    @dataclasses.dataclass(frozen=True)
    class LeakySig:
        rows: int = 1
        note: str = dataclasses.field(default="", compare=False)

    found = kernel_check.check_plan_key(shard_cls=LeakySig)
    assert codes(found) == ["AF006"]
    assert any("LeakySig.note" in f.where for f in found)


def test_seeded_af006_key_signature_drift():
    found = kernel_check.check_plan_key(
        key_params=("M", "N", "T", "backend", "epilogue"))
    assert codes(found) == ["AF006"]


# ---------------------------------------------------------------------------
# AST lint

def test_lint_repo_clean():
    found = ast_lint.run()
    assert found == [], "\n".join(str(f) for f in found)


def test_lint_seeded_violations(tmp_path):
    zone = tmp_path / "nn"
    zone.mkdir()
    (zone / "bad.py").write_text(textwrap.dedent("""\
        import jax.numpy as jnp
        from repro.kernels import substrate

        def sneaky(x, w):
            y = x @ w
            z = jnp.einsum("ij,jk->ik", x, w)
            h = substrate.gemm(x, w)
            g = substrate.gemm(x, w, site="totally.bogus")
            substrate.DISPATCH_COUNTS.clear()
            substrate.SITE_PLANS["x"] = None
            return y + z + h + g
    """))
    found = ast_lint.lint_paths([tmp_path], root=tmp_path)
    by_code = {c: [f for f in found if f.code == c] for c in codes(found)}
    assert codes(found) == ["AFL01", "AFL02", "AFL03"]
    assert len(by_code["AFL01"]) == 2       # `@` and einsum
    assert len(by_code["AFL02"]) == 2       # missing site=, bogus label
    assert len(by_code["AFL03"]) == 2       # .clear() and subscript write
    assert all(":" in f.where for f in found)   # file:line locations


def test_lint_seeded_paged_state_mutation(tmp_path):
    """AFL03's second ownership group: page-table/pool state may only be
    rewired inside serving/engine.py + serving/paged.py."""
    zone = tmp_path / "serving"
    zone.mkdir()
    (zone / "rogue.py").write_text(textwrap.dedent("""\
        def hijack(pool, seq, node):
            pool.free_pages.append(3)
            pool.refcounts[4] += 1
            seq.block_table[0] = 7
            node.children.pop(("a",))
            return seq
    """))
    found = ast_lint.lint_paths([tmp_path], root=tmp_path)
    assert codes(found) == ["AFL03"] and len(found) == 4
    assert all("serving/engine.py + serving/paged.py" in f.message
               for f in found)
    # the same file under an owner path is clean
    (zone / "engine.py").write_text((zone / "rogue.py").read_text())
    owned = ast_lint.lint_paths([zone / "engine.py"], root=tmp_path)
    assert owned == []


def test_lint_seeded_chaos_and_snapshot_state_mutation(tmp_path):
    """AFL03's chaos + snapshot ownership groups: chaos draw-state may
    only move inside runtime/chaos.py, engine snapshot state only inside
    serving/engine.py."""
    zone = tmp_path / "serving"
    zone.mkdir()
    (zone / "rogue.py").write_text(textwrap.dedent("""\
        def hijack(chaos_engine, engine):
            chaos_engine.chaos_draws["engine.tick"] = 0
            chaos_engine.chaos_draws.update({"pool.alloc": 9})
            chaos_engine.chaos_log.append(("engine.tick", 0, "forged"))
            engine._snapshots.pop()
            engine._snapshots[0] = {}
            return engine
    """))
    found = ast_lint.lint_paths([tmp_path], root=tmp_path)
    assert codes(found) == ["AFL03"] and len(found) == 5
    chaos_msgs = [f for f in found if "runtime/chaos.py" in f.message]
    snap_msgs = [f for f in found if "serving/engine.py" in f.message
                 and "snapshot" in f.message]
    assert len(chaos_msgs) == 3 and len(snap_msgs) == 2
    # the same mutations under the respective owner paths are clean
    rt = tmp_path / "runtime"
    rt.mkdir()
    (rt / "chaos.py").write_text(textwrap.dedent("""\
        def advance(self):
            self.chaos_draws["engine.tick"] = 1
            self.chaos_log.append(("engine.tick", 1, ""))
    """))
    assert ast_lint.lint_paths([rt / "chaos.py"], root=tmp_path) == []
    (zone / "engine.py").write_text(textwrap.dedent("""\
        def snap(self):
            self._snapshots[:] = [{}]
    """))
    assert ast_lint.lint_paths([zone / "engine.py"], root=tmp_path) == []


def test_lint_allowlist_and_forwarded_site(tmp_path):
    """ALLOWLIST functions may use raw GEMMs; a non-literal site= (a
    forwarder like nn.layers.linear) is left to the runtime check."""
    zone = tmp_path / "nn"
    zone.mkdir()
    (zone / "moe.py").write_text(textwrap.dedent("""\
        from repro.kernels import substrate

        def moe_apply_reference(x, w):
            return x @ w

        def linear(x, w, site):
            return substrate.gemm(x, w, site=site)
    """))
    assert ast_lint.lint_paths([tmp_path], root=tmp_path) == []


def test_lint_zones_exclude_kernels(tmp_path):
    """Raw contractions inside kernels/ are the substrate itself."""
    zone = tmp_path / "kernels"
    zone.mkdir()
    (zone / "somekernel.py").write_text("def f(x, w):\n    return x @ w\n")
    assert ast_lint.lint_paths([tmp_path], root=tmp_path) == []


# ---------------------------------------------------------------------------
# strict-audit runtime enforcement

def test_strict_audit_scope_raises_af007():
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    substrate.clear_plan_cache()
    with substrate.strict_audit_scope():
        substrate.gemm(x, w, site="mlp.wo")          # known label: fine
        with pytest.raises(RuntimeError, match="AF007"):
            substrate.gemm(x, w, site="bogus.site")
    substrate.clear_plan_cache()


def test_strict_audit_env_and_contextvar(monkeypatch):
    monkeypatch.delenv("REPRO_STRICT_AUDIT", raising=False)
    assert not substrate.strict_audit_enabled()
    monkeypatch.setenv("REPRO_STRICT_AUDIT", "1")
    assert substrate.strict_audit_enabled()
    monkeypatch.setenv("REPRO_STRICT_AUDIT", "0")
    assert not substrate.strict_audit_enabled()


def test_strict_audit_off_records_unknown_site():
    """Outside strict mode the legacy behavior stands: unknown labels are
    recorded (and surface later via check_dispatch_sites / the auditor)."""
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    substrate.clear_plan_cache()
    try:
        substrate.gemm(x, w, site="bogus.site")
        assert substrate.DISPATCH_COUNTS.get("bogus.site") == 1
        with pytest.raises(RuntimeError, match="AF007"):
            substrate.check_dispatch_sites()
    finally:
        substrate.clear_plan_cache()


def test_check_dispatch_sites_clean():
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    substrate.clear_plan_cache()
    try:
        substrate.gemm(x, w, site="mlp.wo")
        substrate.check_dispatch_sites()             # no raise
    finally:
        substrate.clear_plan_cache()


def test_site_registry_covers_model_gemms():
    reg = planner.site_registry()
    assert {"attn.wq", "mlp.wo", "moe.router", "mamba.out",
            "unembed"} <= reg
    assert "bogus.site" not in reg


# ---------------------------------------------------------------------------
# the CLI, end to end (subprocess: owns XLA_FLAGS for the TP2 column)

def test_audit_cli_clean_with_tp2(tmp_path):
    out = tmp_path / "audit.json"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.audit",
         "--models", "qwen2-0.5b", "--backends", "xla", "arrayflex_int8",
         "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert res.returncode == 0, res.stdout + res.stderr
    import json
    data = json.loads(out.read_text())
    assert data["ok"] and data["n_errors"] == 0
    tags = [c["cell"] for c in data["meta"]["cells"]]
    assert "qwen2-0.5b/xla/tp2" in tags
    assert "qwen2-0.5b/arrayflex_int8/unsharded" in tags
    # int8 cells carry the staged-quantize warning, by design
    assert data["n_warnings"] > 0
    assert all(f["code"] == "AF008" for f in data["findings"])
