"""GEMM planner over model configs; cluster pipeline; serving engine."""
import numpy as np
import jax

from repro.configs import ARCHS, SHAPES, reduced
from repro.parallel import pipeline as cp
from repro.core import planner
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def test_model_gemms_cover_families():
    for arch in ("qwen2-0.5b", "mixtral-8x22b", "jamba-1.5-large-398b",
                 "mamba2-370m", "whisper-base", "llama-3.2-vision-90b"):
        gs = planner.model_gemms(ARCHS[arch], SHAPES["train_4k"])
        names = {g.name.split(".")[0] for g in gs}
        assert "unembed" in names
        if ARCHS[arch].moe:
            assert "moe" in names
        if ARCHS[arch].family in ("ssm", "hybrid"):
            assert "mamba" in names
        assert all(g.M > 0 and g.N > 0 and g.T > 0 for g in gs)


def test_plan_model_regime_structure():
    """The beyond-paper finding: training GEMMs (huge T) pay the k=1 clock
    tax (negative saving); decode (tiny T) is the technique's sweet spot."""
    train = planner.plan_model(ARCHS["llama3-8b"], SHAPES["train_4k"])
    assert -0.15 < train["latency_saving"] < 0.05
    dec = planner.plan_model(ARCHS["llama3-8b"], SHAPES["decode_32k"])
    assert dec["latency_saving"] > 0.15
    assert dec["edp_gain"] > 1.5


def test_attention_plan_tradeoff():
    # higher per-step overhead pushes toward bigger chunks (deeper collapse)
    small = planner.attention_plan(4096, 32768, step_overhead=0.1)
    big = planner.attention_plan(4096, 32768, step_overhead=1e4)
    assert big >= small


def test_attention_plan_ragged_kv_is_costed():
    """kv_len divisible by no choice must still pick the cost-optimal chunk
    (the seed silently fell back to min(choices) without costing it)."""
    choices = (256, 512, 1024, 2048, 4096)

    def exact_cost(kc, seq_len, kv_len, overhead, per_elem=1.0 / 1024):
        kc = min(kc, kv_len)
        full, rem = divmod(kv_len, kc)
        c = full * (overhead + per_elem * kc * seq_len)
        if rem:
            c += overhead + per_elem * rem * seq_len
        return c

    for kv_len in (5000, 33000, 999):
        for overhead in (0.1, 10.0, 1e4):
            got = planner.attention_plan(4096, kv_len, choices=choices,
                                         step_overhead=overhead)
            costs = {min(kc, kv_len): exact_cost(kc, 4096, kv_len, overhead)
                     for kc in choices}
            assert costs[got] == min(costs.values()), (kv_len, overhead, got)
    # heavy per-step overhead on ragged kv must not collapse to min(choices)
    assert planner.attention_plan(4096, 5000, choices=choices,
                                  step_overhead=1e6) > min(choices)


def test_cluster_pipeline_structure():
    c = cp.PipelineCost(n_pods=8, microbatches=1, layer_time_ms=1.0,
                        overhead_ms=0.1)
    # single microbatch: no pipelining benefit -> collapse everything
    assert cp.best_collapse(c) == 8
    c2 = cp.PipelineCost(n_pods=8, microbatches=64, layer_time_ms=1.0,
                         overhead_ms=0.01)
    # many microbatches, tiny overhead: keep all stages
    assert cp.best_collapse(c2) == 1
    plan = cp.plan(cp.PipelineCost(8, 16, 1.0, 2.0))
    assert plan["latency_ms"] <= plan["latency_ms_k1"]
    assert 0 <= plan["bubble_fraction"] < 1


def test_serving_engine_greedy_matches_manual():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(max_batch=2, max_seq=64))
    prompt = [5, 6, 7]
    req = Request(prompt=prompt, max_new_tokens=6)
    engine.submit(req)
    engine.run_to_completion()
    assert len(req.out_tokens) == 6

    # manual greedy decode through the raw model path
    import jax.numpy as jnp
    cache = lm.init_cache(cfg, 1, 64)
    tok = None
    outs = []
    for t, x in enumerate(prompt):
        logits, cache = lm.decode_step(cfg, params, cache,
                                       jnp.asarray([x], jnp.int32),
                                       jnp.int32(t))
    tok = int(np.argmax(np.asarray(logits[0])))
    outs.append(tok)
    for t in range(len(prompt), len(prompt) + 5):
        logits, cache = lm.decode_step(cfg, params, cache,
                                       jnp.asarray([tok], jnp.int32),
                                       jnp.int32(t))
        tok = int(np.argmax(np.asarray(logits[0])))
        outs.append(tok)
    assert req.out_tokens == outs


def test_serving_continuous_batching():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(max_batch=2, max_seq=64))
    reqs = [Request(prompt=[3, 4, 5], max_new_tokens=4, rid=i)
            for i in range(5)]
    for r in reqs:
        engine.submit(r)
    ticks = engine.run_to_completion()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    # 5 requests through 2 slots must take more ticks than one wave
    assert ticks >= 12


def test_serving_ragged_prompts_match_isolated():
    """Per-slot positions: ragged prompts decoded together must equal each
    request decoded alone (continuous batching correctness)."""
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5, 6, 7], [11, 12, 13, 14, 15, 16], [21, 22]]

    def run(reqs, max_batch):
        eng = ServingEngine(cfg, params,
                            ServeConfig(max_batch=max_batch, max_seq=64))
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    together = run([Request(prompt=p, max_new_tokens=5, rid=i)
                    for i, p in enumerate(prompts)], max_batch=3)
    alone = [run([Request(prompt=p, max_new_tokens=5)], max_batch=1)[0]
             for p in prompts]
    assert together == alone
