"""Quantized int8 ArrayFlex backend: kernel exactness vs the dequantized
oracle, the weight-quantization memo, int8-aware Eq.(5')/(7) planning (the
k-shift), per-backend plan-cache stats, backend validation at config
resolve, and the model-level equivalence matrix
int8 x {dense, MoE, Mamba} x {epilogues on/off} x {unsharded, TP2}.

Tolerance contract (documented here and in docs/substrate.md):

* kernel level — the int8 kernel must match ``x @ (codes * scales)``
  (the dequantized-weight fp32 oracle) to fp32 accumulation-order
  tolerance (atol 1e-4): the kernel adds NO error beyond quantization.
* model level vs the fp32 arrayflex backend — per-output-channel int8
  rounding is a relative weight perturbation of ~scale/2 per element;
  on the reduced fp32 configs that compounds to a few percent of the
  logit scale: dense/Mamba ``atol=0.06`` (observed ~0.011 on logit
  scale ~0.55).  The MoE family amplifies it: a random-init router has
  near-uniform probabilities, so tiny residual-stream perturbations flip
  top-k choices on near-tie tokens and those tokens take entirely
  different experts — ``atol=2.0`` (observed ~0.99 on logit scale ~3.0;
  a trained router's decisive margins would not flip).  The router
  *weights* themselves are quantization-exempt (QUANT_EXEMPT_SITES).
* sharded (TP2) int8 vs unsharded int8 — near bit-exact (atol 1e-4):
  quantization happens once before sharding, the scales shard with the
  output axis, and the TP psum stays fp32, so only fp32 accumulation
  order differs.
"""
import dataclasses
import gc
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.core import planner, timing
from repro.kernels import ops, substrate
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# model-level int8-vs-fp32 tolerance per family (see module docstring)
ATOL = {"qwen2-0.5b": 0.06, "mamba2-370m": 0.06, "qwen3-moe-30b-a3b": 2.0}


def _cfg(arch, backend="xla", mesh=()):
    return reduced(ARCHS[arch], compute_dtype="float32",
                   param_dtype="float32", gemm_backend=backend,
                   mesh_shape=mesh)


_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        _PARAMS[arch] = lm.init_params(_cfg(arch), jax.random.PRNGKey(0))
    return _PARAMS[arch]


_TOKS = np.random.RandomState(0).randint(2, 512, (2, 16))


def _dequant(w):
    q, s = substrate._quantize(w)
    return q.astype(jnp.float32) * s[..., None, :]


# ----------------------------------------------------------- registration
def test_int8_backend_registered_with_metadata():
    assert "arrayflex_int8" in substrate.backends()
    info = substrate._BACKEND_INFO["arrayflex_int8"]
    assert info.collapse and info.quantize and info.precision == "int8"
    # fp32 arrayflex keeps collapse without quantization
    info_fp = substrate._BACKEND_INFO["arrayflex"]
    assert info_fp.collapse and not info_fp.quantize
    with pytest.raises(ValueError, match="unknown datapath precision"):
        substrate.register_backend("_bad", lambda *a: None,
                                   precision="int3")
    substrate._BACKENDS.pop("_bad", None)
    substrate._BACKEND_INFO.pop("_bad", None)


def test_backend_validated_at_config_resolve():
    """Satellite: an unknown gemm_backend fails at the entry points with
    the registered list, not deep inside dispatch."""
    with pytest.raises(ValueError, match="arrayflex_int8"):
        substrate.check_backend("nope")
    cfg = _cfg("qwen2-0.5b", backend="arrayfex")       # typo'd
    with pytest.raises(ValueError, match="registered"):
        lm.forward(cfg, _params("qwen2-0.5b"),
                   {"tokens": jnp.ones((1, 4), jnp.int32)})
    with pytest.raises(ValueError, match="registered"):
        lm.decode_step(cfg, _params("qwen2-0.5b"), None,
                       jnp.ones((1,), jnp.int32), jnp.int32(0))
    with pytest.raises(ValueError, match="registered"):
        ServingEngine(cfg, _params("qwen2-0.5b"),
                      ServeConfig(max_batch=1, max_seq=8))


# ------------------------------------------------------ quantization memo
def test_quantize_weight_memo_and_eviction():
    w = jnp.asarray(np.random.RandomState(0).randn(32, 16), jnp.float32)
    substrate.clear_quant_cache()
    q1, s1 = substrate.quantize_weight(w)
    q2, s2 = substrate.quantize_weight(w)
    assert q1 is q2 and s1 is s2
    st = substrate.quantize_cache_info()
    assert st["hits"] == 1 and st["misses"] == 1 and st["size"] == 1
    assert q1.dtype == jnp.int8 and s1.shape == (16,)
    assert int(jnp.max(jnp.abs(q1))) <= 127
    # every dispatch with the same weight object is a pure dict hit
    for _ in range(5):
        substrate.quantize_weight(w)
    assert substrate.quantize_cache_info()["hits"] == 6
    # the weakref death callback evicts the entry with the array
    del w, q1, q2
    gc.collect()
    assert substrate.quantize_cache_info()["size"] == 0
    # tracers quantize in-graph (per-compilation, counted separately)
    jax.jit(lambda a: substrate.quantize_weight(a)[0])(
        jnp.ones((8, 4), jnp.float32))
    assert substrate.quantize_cache_info()["traced"] >= 1
    substrate.clear_quant_cache()
    assert substrate.quantize_cache_info() == {
        "hits": 0, "misses": 0, "traced": 0, "size": 0}


def test_quantize_expert_bank_per_expert_scales():
    w = jnp.asarray(np.random.RandomState(1).randn(3, 16, 8), jnp.float32)
    q, s = substrate._quantize(w)
    assert q.shape == (3, 16, 8) and s.shape == (3, 8)
    np.testing.assert_allclose(np.float32(_dequant(w)), np.float32(w),
                               atol=float(jnp.max(s)) / 2 + 1e-6)


# -------------------------------------------- kernel-level exactness
@pytest.mark.parametrize("epilogue,bias", [
    ("none", False), ("silu", True), ("gelu", False), ("swiglu", True),
])
@pytest.mark.parametrize("shape", [
    (7, 64, 32),        # small everything
    (300, 130, 200),    # ragged M/K/N beyond the SA tile
    (128, 256, 128),    # exact tiling
])
def test_int8_gemm_matches_dequant_oracle(shape, epilogue, bias):
    """The int8 dispatch must equal the fp32 xla path run on the
    dequantized weights — the kernel adds no error beyond quantization
    (epilogues on/off, ragged shapes, fused dual contraction)."""
    T, K, N = shape
    rng = np.random.RandomState(sum(shape))
    x = jnp.asarray(rng.randn(T, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    w2 = jnp.asarray(rng.randn(K, N), jnp.float32) \
        if epilogue == "swiglu" else None
    b = jnp.asarray(rng.randn(N), jnp.float32) if bias else None
    b2 = b if (bias and epilogue == "swiglu") else None
    got = substrate.gemm(x, w, backend="arrayflex_int8", epilogue=epilogue,
                         w2=w2, bias=b, bias2=b2)
    want = substrate.gemm(x, _dequant(w), backend="xla", epilogue=epilogue,
                          w2=None if w2 is None else _dequant(w2),
                          bias=b, bias2=b2)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-3, atol=1e-3)


def test_int8_expert_gemm_matches_dequant_oracle():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 3, 5, 16), jnp.float32)   # (G,E,C,K)
    w = jnp.asarray(rng.randn(3, 16, 24), jnp.float32)     # (E,K,N)
    got = substrate.expert_gemm(x, w, backend="arrayflex_int8")
    want = jnp.einsum("gecd,edf->gecf", x, _dequant(w))
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-4)


def test_int8_empty_and_exempt_paths():
    # empty contraction short-circuits without quantizing
    out = substrate.gemm(jnp.zeros((2, 0)), jnp.zeros((0, 4)),
                         backend="arrayflex_int8")
    assert out.shape == (2, 4) and float(jnp.max(jnp.abs(out))) == 0.0
    # a quantization-exempt site runs the fp32 kernel bit-for-bit
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(6, 8), jnp.float32)
    w = jnp.asarray(rng.randn(8, 4), jnp.float32)
    got = substrate.gemm(x, w, site="moe.router", backend="arrayflex_int8")
    want = substrate.gemm(x, w, site="moe.router", backend="arrayflex")
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-6, atol=1e-6)


def test_unknown_backend_raises_on_every_entry():
    """batched_gemm / expert_gemm used to run an unknown backend name
    through the builtin fallthrough silently; all three entries must
    raise with the registered list."""
    x3 = jnp.ones((2, 4, 8))
    w3 = jnp.ones((2, 8, 4))
    with pytest.raises(ValueError, match="registered"):
        substrate.batched_gemm(x3, w3, backend="nope")
    with pytest.raises(ValueError, match="registered"):
        substrate.expert_gemm(jnp.ones((1, 2, 4, 8)), w3, backend="nope")


def test_custom_quantizing_backend_expert_unroll_gets_scales():
    """A custom (non-builtin) quantizing backend's expert unroll must
    receive each expert's dequant scales — dropping them would hand the
    backend raw int8 codes and silently mis-scale every column."""
    seen = []

    def mine(x2, w, plan, call):
        seen.append(call.w_scale)
        y = jnp.dot(x2, w.astype(jnp.float32))
        return y * call.w_scale if call.w_scale is not None else y

    substrate.register_backend("_q8", mine, precision="int8",
                               quantize=True)
    try:
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(2, 3, 5, 16), jnp.float32)
        w = jnp.asarray(rng.randn(3, 16, 24), jnp.float32)
        got = substrate.expert_gemm(x, w, backend="_q8")
        assert len(seen) == 3 and all(s is not None and s.shape == (24,)
                                      for s in seen)
        want = jnp.einsum("gecd,edf->gecf", x, _dequant(w))
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-4, atol=1e-4)
    finally:
        substrate._BACKENDS.pop("_q8")
        substrate._BACKEND_INFO.pop("_q8")
        substrate.clear_plan_cache()


def test_register_backend_evicts_stale_plans():
    """Re-registering a name with different metadata must not keep
    serving plans cached under the old collapse/precision."""
    substrate.register_backend("_re", lambda x2, w, p, c: x2 @ w)
    try:
        assert substrate.plan_gemm(512, 256, 128, "_re").k == 1
        substrate.register_backend("_re", lambda x2, w, p, c: x2 @ w,
                                   collapse=True)
        assert substrate.plan_gemm(512, 256, 128, "_re").k == \
            ops.plan_collapse(512, 256, 128)
    finally:
        substrate._BACKENDS.pop("_re")
        substrate._BACKEND_INFO.pop("_re")
        substrate.clear_plan_cache()


def test_exempt_site_priced_as_fp32_base():
    """moe.router under the int8 backend executes fp32 weights, so its
    recorded plan must be the fp32 arrayflex plan (k, precision, and
    Eq.(6') prediction), not an int8-priced one."""
    substrate.clear_plan_cache()
    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    substrate.gemm(x, w, site="moe.router", backend="arrayflex_int8")
    p = substrate.SITE_PLANS["moe.router"]
    assert p.backend == "arrayflex" and p.precision == "fp32"
    assert p == substrate.plan_gemm(8, 16, 8, "arrayflex")
    substrate.clear_plan_cache()


# ------------------------------------------------- int8-aware planning
def test_int8_timing_params():
    tp8 = timing.INT8_TIMING
    assert tp8.mode == "linear" and tp8.freq_table_ghz == ()
    # the collapse increment shrinks proportionally more than the base
    # MAC path (fp32 accumulate stays), so d_base/d_inc RISES ...
    assert (tp8.d_base_ps / tp8.d_inc_ps
            > timing.DEFAULT_TIMING.d_base_ps
            / timing.DEFAULT_TIMING.d_inc_ps)
    # ... and Eq.(7)'s continuous optimum rises with it
    assert timing.k_hat(128, 128, 512, tp8) > \
        timing.k_hat(128, 128, 512, timing.DEFAULT_TIMING)
    # every supported k is faster per cycle than the fp32 datapath
    for k in tp8.supported_k:
        assert tp8.clock_period_ps(k) < \
            timing.DEFAULT_TIMING.clock_period_ps(k)
    assert timing.timing_for("fp32") is timing.DEFAULT_TIMING
    assert timing.timing_for("int8") is timing.INT8_TIMING
    with pytest.raises(ValueError, match="precision"):
        timing.timing_for("fp16")


def test_int8_shifts_best_k_at_model_shape():
    """Acceptance: a real model GEMM shape — qwen2-0.5b's mlp.wo at a
    512-row decode batch, (M, N, T) = (896, 4864, 512) — plans k=2 under
    the fp32 silicon numbers but k=4 under the int8 datapath: the cheap
    int8 collapse stages amortize over deeper merges (Eq. 7)."""
    M, N, T = 896, 4864, 512
    assert ops.plan_collapse(M, N, T) == 2
    assert ops.plan_collapse(M, N, T, precision="int8") == 4
    # the substrate's backend-keyed plans see the same shift, and the
    # int8 plan records its precision and predicts a faster execution
    pf = substrate.plan_gemm(M, N, T, "arrayflex")
    p8 = substrate.plan_gemm(M, N, T, "arrayflex_int8")
    assert (pf.k, p8.k) == (2, 4)
    assert pf.precision == "fp32" and p8.precision == "int8"
    assert p8.t_pred_ps < pf.t_pred_ps
    assert p8.saving > 0


def test_plan_prices_dequant_as_boundary_op():
    """The per-channel dequant multiply rides the carry-propagate
    boundary: one Eq.(5') op per contraction, on top of epilogue and
    reduce ops."""
    p = substrate.plan_gemm(256, 128, 64, "arrayflex_int8")
    want = timing.t_abs_ps(256, 128, 64, ops.SA_R, ops.SA_C, p.k,
                           params=timing.INT8_TIMING, epilogue_ops=1)
    assert p.t_pred_ps == want
    ep = substrate.Epilogue(kind="swiglu")
    pd = substrate.plan_gemm(256, 128, 64, "arrayflex_int8", ep)
    want = timing.t_abs_ps(256, 128, 64, ops.SA_R, ops.SA_C, pd.k,
                           params=timing.INT8_TIMING,
                           epilogue_ops=ep.ops + 2, contractions=2)
    assert pd.t_pred_ps == want
    # analytic side-by-side table prices int8 the same way
    g = planner.GEMM("mlp.wo", 256, 128, 64)
    lp = planner.plan_gemm_precision(g, 128, 128, "int8")
    assert lp.t_abs_ps == p.t_pred_ps
    assert lp.k == p.k


def test_precision_table_side_by_side():
    rows = planner.precision_table(_cfg("qwen2-0.5b"),
                                   planner.ShapeConfig("t", 8, 2, "train"))
    assert rows and all({"fp32", "int8"} <= set(r["plans"]) for r in rows)
    assert all(r["plans"]["int8"].t_abs_ps <= r["plans"]["fp32"].t_abs_ps
               for r in rows)


# ------------------------------------------- per-backend plan-cache stats
def test_plan_cache_per_backend_stats():
    substrate.clear_plan_cache()
    substrate.plan_gemm(64, 32, 16, "arrayflex")
    substrate.plan_gemm(64, 32, 16, "arrayflex")
    substrate.plan_gemm(64, 32, 16, "arrayflex_int8")
    info = substrate.plan_cache_info()
    assert info.per_backend["arrayflex"] == {"hits": 1, "misses": 1}
    assert info.per_backend["arrayflex_int8"] == {"hits": 0, "misses": 1}
    assert info.hits == 1 and info.misses == 2
    assert "per_backend" in info._asdict()
    substrate.clear_plan_cache()
    assert substrate.plan_cache_info().per_backend == {}


def test_serving_plan_cache_steady_state():
    """Satellite: after the first decode tick every plan the serving loop
    needs is cached — steady-state dispatch is cache-hit-only (zero new
    misses, per backend and in aggregate)."""
    cfg = _cfg("qwen2-0.5b", "arrayflex_int8")
    substrate.clear_plan_cache()
    eng = ServingEngine(cfg, _params("qwen2-0.5b"),
                        ServeConfig(max_batch=2, max_seq=32))
    for i, p in enumerate([[5, 6, 7], [11, 12, 13, 14], [21, 22]]):
        eng.submit(Request(prompt=p, max_new_tokens=6, rid=i))
    eng.step()                       # first tick: traces + plans
    m0 = substrate.plan_cache_info().misses
    per0 = substrate.plan_cache_info().per_backend
    eng.run_to_completion()
    info = substrate.plan_cache_info()
    assert info.misses == m0, (per0, info.per_backend)
    substrate.clear_plan_cache()


# --------------------------------------- model-level equivalence matrix
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m"])
def test_int8_forward_and_decode_match_fp32(arch):
    """int8 x {dense, MoE, Mamba}, unsharded: logits within the
    documented tolerance of the fp32 arrayflex backend (see module
    docstring for why MoE's bound is looser)."""
    toks = jnp.asarray(_TOKS, jnp.int32)
    params = _params(arch)
    want, _, _ = lm.forward(_cfg(arch, "arrayflex"), params,
                            {"tokens": toks})
    substrate.SITE_PLANS.clear()
    got, _, _ = lm.forward(_cfg(arch, "arrayflex_int8"), params,
                           {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               atol=ATOL[arch])
    # the family's weight GEMMs really planned the int8 datapath
    family = ({"mamba.z", "mamba.xbc", "mamba.out"} if arch == "mamba2-370m"
              else {"moe.wi_gate", "moe.wo"} if "moe" in arch
              else {"attn.wq", "mlp.wi_gate", "unembed"})
    for s in family:
        p = substrate.SITE_PLANS[s]
        assert p.backend == "arrayflex_int8" and p.precision == "int8", s
    # decode path too
    tok = jnp.asarray([3, 5], jnp.int32)
    want, _ = lm.decode_step(_cfg(arch, "arrayflex"), params,
                             lm.init_cache(_cfg(arch), 2, 8), tok,
                             jnp.int32(0))
    got, _ = lm.decode_step(_cfg(arch, "arrayflex_int8"), params,
                            lm.init_cache(_cfg(arch), 2, 8), tok,
                            jnp.int32(0))
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               atol=ATOL[arch])


def test_int8_equals_fake_quant_fp32_end_to_end():
    """The strong form of model-level correctness: the int8 backend must
    match the plain fp32 xla backend run on *fake-quantized* params
    (quantize-dequantize applied to exactly the weights the dispatch
    quantizes — every linear/swiglu 'w' leaf of an untied dense model) to
    fp32 accumulation tolerance.  This pins the whole pipeline — memo,
    kernel, scale handling, epilogues — with no quantization-noise slack.
    """
    cfg = _cfg("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, tie_embeddings=False)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    def fq(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", p)))
                 for p in path]
        return _dequant(leaf) if names[-1] == "w" else leaf

    fq_params = jax.tree_util.tree_map_with_path(fq, params)
    toks = jnp.asarray(_TOKS, jnp.int32)
    cfg8 = dataclasses.replace(cfg, gemm_backend="arrayflex_int8")
    got, _, _ = lm.forward(cfg8, params, {"tokens": toks})
    want, _, _ = lm.forward(cfg, fq_params, {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-4)


def test_int8_greedy_streams_identical():
    """Acceptance: the serving engine produces bit-identical greedy
    streams under int8 and fp32 arrayflex on the reduced qwen2 config
    (the pinned prompts' top-1 margins exceed the quantization
    perturbation; verified deterministic on the CPU backend)."""
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]

    def run(backend):
        cfg = _cfg("qwen2-0.5b", backend)
        eng = ServingEngine(cfg, _params("qwen2-0.5b"),
                            ServeConfig(max_batch=2, max_seq=32))
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    assert run("arrayflex_int8") == run("arrayflex")


def test_int8_one_launch_per_site():
    """DISPATCH_COUNTS: the int8 backend keeps the fused/batched launch
    structure — one launch per site, including the fused swiglu pair and
    the expert-batched MoE sites."""
    for arch in ("qwen2-0.5b", "qwen3-moe-30b-a3b"):
        cfg = _cfg(arch, "arrayflex_int8")
        params = _params(arch)
        substrate.clear_plan_cache()
        jax.eval_shape(lambda p, b, c=cfg: lm.forward(c, p, b), params,
                       {"tokens": jnp.ones((2, 8), jnp.int32)})
        counts = dict(substrate.DISPATCH_COUNTS)
        assert all(v == 1 for v in counts.values()), counts
        if "moe" in arch:
            assert {"moe.router", "moe.wi_gate", "moe.wi_up",
                    "moe.wo"} <= set(counts)
        else:
            assert "mlp.wi_gate+mlp.wi_up" in counts
    substrate.clear_plan_cache()


# ------------------------------------------ sharded int8 (degenerate mesh)
def test_int8_sharded_dispatch_degenerate_mesh_exact():
    """The shard_map path with int8 operands on a (1, 1) mesh — incl. a
    size-1 psum reduce, where the per-shard kernel dequants its partial
    before the fp32 psum — must reproduce the unsharded int8 dispatch."""
    mesh = make_host_mesh(1, 1)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 16), jnp.float32)
    w = jnp.asarray(rng.randn(16, 32), jnp.float32)
    w2 = jnp.asarray(rng.randn(16, 32), jnp.float32)
    b = jnp.asarray(rng.randn(32), jnp.float32)
    ctx = substrate.ShardCtx(mesh, P(None, None), P(None, None),
                             P(None, None))
    red = substrate.ShardCtx(mesh, P(None, None), P(None, None),
                             P(None, None), reduce_axes=("model",))
    want = substrate.gemm(x, w, backend="arrayflex_int8", w2=w2, bias=b,
                          epilogue="swiglu")
    got = substrate.gemm(x, w, backend="arrayflex_int8", w2=w2, bias=b,
                         epilogue="swiglu", shard=ctx)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)
    want_r = substrate.gemm(x, w, backend="arrayflex_int8", bias=b,
                            epilogue="silu")
    got_r = substrate.gemm(x, w, backend="arrayflex_int8", bias=b,
                           epilogue="silu", shard=red)
    np.testing.assert_allclose(np.float32(got_r), np.float32(want_r),
                               rtol=1e-5, atol=1e-4)
    # expert entry through its shard_map path (scales shard with E)
    xe = jnp.asarray(rng.randn(2, 4, 3, 16), jnp.float32)
    we = jnp.asarray(rng.randn(4, 16, 8), jnp.float32)
    ec = substrate.ShardCtx(mesh, P(None, None, None, None),
                            P(None, None, None), P(None, None, None, None))
    got = substrate.expert_gemm(xe, we, backend="arrayflex_int8", shard=ec)
    want = substrate.expert_gemm(xe, we, backend="arrayflex_int8")
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------- multi-device TP2 cells (8 dev)
@needs8
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m"])
def test_multidev_int8_tp2_matches_unsharded(arch):
    """int8 x {dense, MoE, Mamba} x TP2: sharded int8 logits are near
    bit-exact vs unsharded int8 (one quantization, scales shard with the
    output axis, fp32 psum) and within the documented tolerance of fp32
    arrayflex."""
    toks = jnp.asarray(_TOKS, jnp.int32)
    params = _params(arch)
    un8, _, _ = lm.forward(_cfg(arch, "arrayflex_int8"), params,
                           {"tokens": toks})
    tp8, _, _ = lm.forward(_cfg(arch, "arrayflex_int8", (1, 2)), params,
                           {"tokens": toks})
    np.testing.assert_allclose(np.float32(tp8), np.float32(un8),
                               rtol=1e-5, atol=1e-4)
    fp, _, _ = lm.forward(_cfg(arch, "arrayflex"), params,
                          {"tokens": toks})
    np.testing.assert_allclose(np.float32(tp8), np.float32(fp),
                               atol=ATOL[arch])


@needs8
def test_multidev_int8_tp2_stream_and_plans():
    """TP2 int8 serving stream matches unsharded int8 bit-for-bit; the
    row-parallel site plans record int8 precision + reduce pricing and
    dispatch stays one launch per site."""
    params = _params("qwen2-0.5b")
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]

    def run(mesh):
        eng = ServingEngine(_cfg("qwen2-0.5b", "arrayflex_int8", mesh),
                            params, ServeConfig(max_batch=2, max_seq=32))
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    assert run((1, 2)) == run(())
    substrate.clear_plan_cache()
    cfg = _cfg("qwen2-0.5b", "arrayflex_int8", (1, 2))
    jax.eval_shape(lambda p, b: lm.forward(cfg, p, b), params,
                   {"tokens": jnp.asarray(_TOKS, jnp.int32)})
    assert all(v == 1 for v in substrate.DISPATCH_COUNTS.values())
    wo = substrate.SITE_PLANS["attn.wo"]
    assert wo.precision == "int8" and wo.shard.reduce_ops == 1
    assert wo.N_shard == wo.N // 2
    wq = substrate.SITE_PLANS["attn.wq"]
    assert wq.precision == "int8" and wq.shard.cols == 2
    substrate.clear_plan_cache()


# ------------------------------------------- tier-1 subprocess coverage
def test_int8_sharded_equivalence_subprocess():
    """On a single-device host, run the multidev int8 cells once in an
    8-device subprocess so tier-1 always covers the TP2 column of the
    equivalence matrix."""
    if len(jax.devices()) >= 8:
        pytest.skip("multi-device host runs test_multidev_* directly")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join("tests", "test_int8_substrate.py"),
         "-k", "multidev"],
        capture_output=True, text=True, timeout=1500, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout[-4000:] + out.stderr[-2000:]
    assert "passed" in out.stdout
