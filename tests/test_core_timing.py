"""Eq.(1)-(7) latency/clock model properties."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cnn_shapes, planner, timing
from repro.core.timing import TimingParams


def test_eq1_is_eq3_at_k1():
    for (R, C, T) in [(128, 128, 196), (16, 8, 3), (256, 256, 49)]:
        assert timing.latency_cycles(R, C, T, 1) == \
            timing.latency_cycles_conventional(R, C, T)


def test_paper_fig5_anchors():
    # layer 20 of ResNet-34: (M,N,T)=(256,2304,196) -> k=2 on the shipped
    # design {1,2,4}; layer 28 (512,2304,49) -> k=4  (paper §III-C / §IV)
    assert timing.best_k(256, 2304, 196, 132, 132) == 2
    assert timing.best_k(512, 2304, 49, 132, 132) == 4


def test_khat_structure():
    tp = TimingParams()
    # k_hat grows when T shrinks (paper: late CNN layers prefer deeper
    # collapse) and when the SA grows
    assert timing.k_hat(128, 128, 49, tp) > timing.k_hat(128, 128, 196, tp)
    assert timing.k_hat(256, 256, 196, tp) > timing.k_hat(128, 128, 196, tp)


def test_clock_table_matches_paper():
    tp = TimingParams()
    assert tp.clock_ghz(1) == pytest.approx(1.8)
    assert tp.clock_ghz(2) == pytest.approx(1.7)
    assert tp.clock_ghz(4) == pytest.approx(1.4)
    # linear fit stays within 3% of the table
    lin = TimingParams(mode="linear")
    for k in (1, 2, 4):
        assert lin.clock_period_ps(k) == pytest.approx(
            tp.clock_period_ps(k), rel=0.03)


@settings(max_examples=200, deadline=None)
@given(R=st.sampled_from([16, 32, 64, 128, 256]),
       C=st.sampled_from([16, 32, 64, 128, 256]),
       T=st.integers(1, 4096), k=st.sampled_from([1, 2, 4]))
def test_cycles_positive_and_monotone_in_k(R, C, T, k):
    c1 = timing.latency_cycles(R, C, T, 1)
    ck = timing.latency_cycles(R, C, T, k)
    assert 0 < ck <= c1              # collapsing never adds cycles
    if k > 1:
        assert ck < c1 or (R // k == R and C // k == C)


@settings(max_examples=100, deadline=None)
@given(M=st.integers(1, 4096), N=st.integers(1, 8192), T=st.integers(1, 2048))
def test_best_k_is_argmin(M, N, T):
    tp = TimingParams()
    k = timing.best_k(M, N, T, 128, 128, tp)
    times = {kk: timing.t_abs_ps(M, N, T, 128, 128, kk, tp)
             for kk in tp.supported_k}
    assert times[k] == min(times.values())


def test_best_k_tie_determinism():
    """On exact cost ties, best_k returns the first minimizer in
    ``supported_k`` order — stable across calls and across orderings."""
    # d_inc=0 linear mode: clock period is k-independent; with R=C=1 the
    # cycle counts tie across all supported k, so every k is a minimizer.
    tp = TimingParams(mode="linear", d_inc_ps=0.0)
    for k in (1, 2, 4):
        assert timing.latency_cycles(1, 1, 10, k) == \
            timing.latency_cycles(1, 1, 10, 1)
    assert timing.best_k(64, 64, 10, 1, 1, tp) == tp.supported_k[0]
    # reversed preference order flips the tie-break, nothing else
    tp_rev = TimingParams(mode="linear", d_inc_ps=0.0,
                          supported_k=(4, 2, 1))
    assert timing.best_k(64, 64, 10, 1, 1, tp_rev) == 4
    # repeated evaluation is bit-stable
    assert all(timing.best_k(256, 2304, 196, 132, 132) ==
               timing.best_k(256, 2304, 196, 132, 132) for _ in range(5))


def test_best_k_brackets_khat_over_shape_sweep():
    """Eq.(6) is unimodal in continuous k, so the discrete argmin must be
    one of the two supported depths bracketing Eq.(7)'s k_hat."""
    tp = TimingParams(mode="linear")
    ks = tp.supported_k
    for R, C in ((128, 128), (64, 64), (132, 132), (256, 128)):
        for T in (1, 3, 17, 49, 196, 784, 3136, 12544, 50176):
            kh = timing.k_hat(R, C, T, tp)
            lo = max([k for k in ks if k <= kh], default=ks[0])
            hi = min([k for k in ks if k >= kh], default=ks[-1])
            best = timing.best_k(512, 512, T, R, C, tp)
            assert best in (lo, hi), (R, C, T, kh, best)


def test_plan_network_edp_band_on_paper_cnns():
    """Satellite: the paper's headline EDP gain (Figs. 8/9) lands in the
    1.4x-1.8x band for the dense-GEMM CNNs; MobileNet's depthwise-dominated
    GEMM mapping sits just below it (tiny-N layers cap the win)."""
    def edp(net):
        gemms = [planner.GEMM(f"l{i}", *mnt)
                 for i, mnt in enumerate(cnn_shapes.network_mnt(net))]
        return planner.plan_network(gemms, 128, 128)["edp_gain"]

    for net in ("resnet34", "convnext"):
        assert 1.4 <= edp(net) <= 1.8, (net, edp(net))
    assert 1.25 <= edp("mobilenet") <= 1.8


@settings(max_examples=50, deadline=None)
@given(T=st.integers(2, 4096))
def test_khat_matches_continuous_optimum(T):
    """Eq.(7) equals the numeric argmin of Eq.(6) over continuous k."""
    tp = TimingParams(mode="linear")
    R = C = 128
    kh = timing.k_hat(R, C, T, tp)

    def t_abs(k):
        cyc = R + R / k + C / k + T - 2
        return cyc * (tp.d_base_ps + k * tp.d_inc_ps)

    # golden-section-lite: scan a fine grid
    ks = [1 + i * 0.01 for i in range(1, 1600)]
    k_num = min(ks, key=t_abs)
    if 1.05 < kh < 15.5:
        assert abs(k_num - kh) / kh < 0.02
