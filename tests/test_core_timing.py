"""Eq.(1)-(7) latency/clock model properties."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import timing
from repro.core.timing import TimingParams


def test_eq1_is_eq3_at_k1():
    for (R, C, T) in [(128, 128, 196), (16, 8, 3), (256, 256, 49)]:
        assert timing.latency_cycles(R, C, T, 1) == \
            timing.latency_cycles_conventional(R, C, T)


def test_paper_fig5_anchors():
    # layer 20 of ResNet-34: (M,N,T)=(256,2304,196) -> k=2 on the shipped
    # design {1,2,4}; layer 28 (512,2304,49) -> k=4  (paper §III-C / §IV)
    assert timing.best_k(256, 2304, 196, 132, 132) == 2
    assert timing.best_k(512, 2304, 49, 132, 132) == 4


def test_khat_structure():
    tp = TimingParams()
    # k_hat grows when T shrinks (paper: late CNN layers prefer deeper
    # collapse) and when the SA grows
    assert timing.k_hat(128, 128, 49, tp) > timing.k_hat(128, 128, 196, tp)
    assert timing.k_hat(256, 256, 196, tp) > timing.k_hat(128, 128, 196, tp)


def test_clock_table_matches_paper():
    tp = TimingParams()
    assert tp.clock_ghz(1) == pytest.approx(1.8)
    assert tp.clock_ghz(2) == pytest.approx(1.7)
    assert tp.clock_ghz(4) == pytest.approx(1.4)
    # linear fit stays within 3% of the table
    lin = TimingParams(mode="linear")
    for k in (1, 2, 4):
        assert lin.clock_period_ps(k) == pytest.approx(
            tp.clock_period_ps(k), rel=0.03)


@settings(max_examples=200, deadline=None)
@given(R=st.sampled_from([16, 32, 64, 128, 256]),
       C=st.sampled_from([16, 32, 64, 128, 256]),
       T=st.integers(1, 4096), k=st.sampled_from([1, 2, 4]))
def test_cycles_positive_and_monotone_in_k(R, C, T, k):
    c1 = timing.latency_cycles(R, C, T, 1)
    ck = timing.latency_cycles(R, C, T, k)
    assert 0 < ck <= c1              # collapsing never adds cycles
    if k > 1:
        assert ck < c1 or (R // k == R and C // k == C)


@settings(max_examples=100, deadline=None)
@given(M=st.integers(1, 4096), N=st.integers(1, 8192), T=st.integers(1, 2048))
def test_best_k_is_argmin(M, N, T):
    tp = TimingParams()
    k = timing.best_k(M, N, T, 128, 128, tp)
    times = {kk: timing.t_abs_ps(M, N, T, 128, 128, kk, tp)
             for kk in tp.supported_k}
    assert times[k] == min(times.values())


@settings(max_examples=50, deadline=None)
@given(T=st.integers(2, 4096))
def test_khat_matches_continuous_optimum(T):
    """Eq.(7) equals the numeric argmin of Eq.(6) over continuous k."""
    tp = TimingParams(mode="linear")
    R = C = 128
    kh = timing.k_hat(R, C, T, tp)

    def t_abs(k):
        cyc = R + R / k + C / k + T - 2
        return cyc * (tp.d_base_ps + k * tp.d_inc_ps)

    # golden-section-lite: scan a fine grid
    ks = [1 + i * 0.01 for i in range(1, 1600)]
    k_num = min(ks, key=t_abs)
    if 1.05 < kh < 15.5:
        assert abs(k_num - kh) / kh < 0.02
