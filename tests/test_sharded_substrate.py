"""Sharded SPMD substrate: mesh clamps, divisibility fallbacks, the
shard-keyed plan cache, and the multi-device sharded-equivalence suite
(dense / MoE / Mamba reduced models under TP=2/4 and FSDP=2xTP=2, xla +
arrayflex backends, vs the unsharded xla path).

The multi-device tests (``test_multidev_*``) need an 8-device host
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  On a
single-device host they skip in-process and run once through the
subprocess wrapper, so tier-1 always exercises them; the CI multi-device
job runs them directly.
"""
import dataclasses
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.kernels import ops, substrate
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.parallel import sharding
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# a mesh-shaped stub is enough for the rule/divisibility helpers, which
# only consult .shape / .axis_names — real >1-axis meshes need >1 device
_STUB = SimpleNamespace(shape={"data": 2, "model": 4},
                        axis_names=("data", "model"))


# ------------------------------------------------------ satellite: mesh fix
def test_make_host_mesh_degenerate_clamps():
    n = len(jax.devices())
    for d, m in ((0, 1), (1, 0), (0, 0), (n + 3, 1), (1, n + 3), (99, 99)):
        mesh = make_host_mesh(d, m)
        sizes = dict(mesh.shape)
        assert sizes["data"] >= 1 and sizes["model"] >= 1, (d, m, sizes)
        assert sizes["data"] * sizes["model"] <= n


def test_make_host_mesh_strict_raises():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="device"):
        make_host_mesh(n + 1, 1, strict=True)
    with pytest.raises(ValueError):
        make_host_mesh(0, 1, strict=True)
    mesh = make_host_mesh(1, 1, strict=True)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# ---------------------------------------- satellite: _divisible / _maybe
def test_divisible_missing_axis_counts_as_one():
    """A rule naming an axis the mesh doesn't have (e.g. 'pod' on a
    single-pod mesh) must mean replication (size 1), not a KeyError."""
    stub = SimpleNamespace(shape={"data": 2, "model": 2},
                           axis_names=("data", "model"))
    assert sharding._divisible(8, stub, ("pod", "data"))
    assert not sharding._divisible(7, stub, ("pod", "data"))
    assert sharding._maybe(8, stub, ("pod", "data")) == ("pod", "data")


def test_maybe_replicates_on_indivisible():
    assert sharding._maybe(7, _STUB, "data") is None
    assert sharding._maybe(8, _STUB, "data") == "data"
    assert sharding._maybe(8, _STUB, ("data", "model")) == ("data", "model")
    assert sharding._maybe(12, _STUB, ("data", "model")) is None  # 12 % 8


def test_param_pspec_replicates_indivisible_dims():
    """Regression for the replicate-on-indivisible fallback in
    param_pspec_tree: an axis that doesn't divide its dim drops to None
    while the dividing axis survives."""
    params = {"wq": {"w": np.zeros((6, 10))}}   # in 6 % 2 == 0, out 10 % 4
    specs = sharding.param_pspec_tree(params, _STUB)
    assert tuple(specs["wq"]["w"]) == ("data", None)
    params = {"wq": {"w": np.zeros((8, 8))}}    # both divide
    specs = sharding.param_pspec_tree(params, _STUB)
    assert tuple(specs["wq"]["w"]) == ("data", "model")


# ------------------------------------- satellite: plan-cache shard keying
def test_plan_cache_shard_keying():
    """Same logical (M, N, T) under 1-way vs 4-way TP: distinct GemmPlans,
    distinct best_k (the TP contraction's psum combine tree is priced into
    the Eq.(5') boundary), logical vs per-shard fields recorded."""
    substrate.clear_plan_cache()
    p1 = substrate.plan_gemm(512, 256, 128, "arrayflex")
    sig = substrate.ShardSig(rows=1, contraction=4, cols=1, reduce_ops=2)
    p4 = substrate.plan_gemm(512, 256, 128, "arrayflex",
                             substrate.EPILOGUE_NONE, sig)
    assert p1 is not p4
    assert (p4.M, p4.N, p4.T) == (p1.M, p1.N, p1.T) == (512, 256, 128)
    assert (p1.M_shard, p1.N_shard, p1.T_shard) == (512, 256, 128)
    assert (p4.M_shard, p4.N_shard, p4.T_shard) == (512, 64, 128)
    assert p1.k != p4.k
    assert p1.k == ops.plan_collapse(512, 256, 128)
    assert p4.k == ops.plan_collapse(512, 64, 128, epilogue_ops=2)
    assert p4.cycles > 0 and p4.cycles != p1.cycles
    # repeated sharded lookup is a cache hit, not a recomputation
    h0 = substrate.plan_cache_info().hits
    assert substrate.plan_gemm(512, 256, 128, "arrayflex",
                               substrate.EPILOGUE_NONE, sig) is p4
    assert substrate.plan_cache_info().hits > h0
    # column-parallel signature: distinct per-shard M, cheaper per shard
    col = substrate.ShardSig(cols=4)
    pc = substrate.plan_gemm(512, 256, 128, "arrayflex",
                             substrate.EPILOGUE_NONE, col)
    assert pc.M_shard == 128 and pc.t_pred_ps < p1.t_pred_ps


def test_shard_ctx_signature_and_divides():
    ctx = substrate.ShardCtx(_STUB, P("data", None), P(None, "model"),
                             P("data", "model"))
    assert ctx.signature() == substrate.ShardSig(rows=2, contraction=1,
                                                 cols=4, reduce_ops=0)
    assert ctx.divides(8, 5, 8) and not ctx.divides(7, 5, 8) \
        and not ctx.divides(8, 5, 6)
    row = substrate.ShardCtx(_STUB, P("data", "model"), P("model", None),
                             P("data", None), reduce_axes=("model",))
    assert row.signature() == substrate.ShardSig(rows=2, contraction=4,
                                                 cols=1, reduce_ops=2)


# --------------------------------------------- shard-context derivation
def test_gemm_shard_ctx_site_rules():
    col = sharding.gemm_shard_ctx("attn.wq", 64, 64, 64, mesh=_STUB)
    assert col.w_spec == P(None, "model") and col.out_spec == P("data",
                                                                "model")
    assert col.reduce_axes == ()
    row = sharding.gemm_shard_ctx("attn.wo", 64, 64, 64, mesh=_STUB)
    assert row.reduce_axes == ("model",) and row.w_spec == P("model", None)
    assert row.signature().reduce_ops == 2
    # replicated-weight site still shards the streamed rows over data
    rep = sharding.gemm_shard_ctx("moe.router", 64, 64, 6, mesh=_STUB)
    assert rep.w_spec == P(None, None) and rep.x_spec == P("data", None)
    # fused dual-GEMM label takes its kind from the first component
    j = sharding.gemm_shard_ctx("mlp.wi_gate+mlp.wi_up", 64, 64, 128,
                                mesh=_STUB)
    assert j.w_spec == P(None, "model")
    # indivisible out dim: TP drops, data-row sharding survives
    fb = sharding.gemm_shard_ctx("attn.wq", 64, 64, 6, mesh=_STUB)
    assert fb.w_spec == P(None, None) and fb.x_spec == P("data", None)
    # nothing divides -> replicated dispatch; no mesh / no site -> None
    assert sharding.gemm_shard_ctx("attn.wq", 7, 5, 6, mesh=_STUB) is None
    assert sharding.gemm_shard_ctx("attn.wq", 8, 8, 8) is None
    assert sharding.gemm_shard_ctx("", 8, 8, 8, mesh=_STUB) is None


def test_batched_and_expert_ctx_rules():
    assert sharding.batched_shard_ctx(8, mesh=_STUB).x_spec == \
        P(("data", "model"), None, None)
    assert sharding.batched_shard_ctx(4, mesh=_STUB).x_spec == \
        P("model", None, None)
    assert sharding.batched_shard_ctx(6, mesh=_STUB).x_spec == \
        P("data", None, None)
    assert sharding.batched_shard_ctx(7, mesh=_STUB) is None
    assert sharding.expert_shard_ctx(8, mesh=_STUB).x_spec == \
        P(None, "model", None, None)
    assert sharding.expert_shard_ctx(6, mesh=_STUB) is None  # 6 % 4
    assert sharding.expert_shard_ctx(8) is None              # no mesh


def test_mesh_from_config_validation():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    assert sharding.mesh_from_config(cfg) is None
    off = dataclasses.replace(cfg, mesh_shape=(1, 4), gemm_sharding="none")
    assert sharding.mesh_from_config(off) is None
    with pytest.raises(ValueError, match="gemm_sharding"):
        sharding.mesh_from_config(
            dataclasses.replace(cfg, gemm_sharding="wat"))
    with pytest.raises(ValueError, match="device"):
        sharding.mesh_from_config(dataclasses.replace(
            cfg, mesh_shape=(len(jax.devices()) + 1, 1)))


def test_model_gemms_post_partition():
    """The analytic walker emits per-device GEMMs when the config declares
    a mesh — the same col/row/batched/expert decomposition the dispatch
    runs, so the analytic table joins the shard-keyed plan cache."""
    from repro.configs.base import ShapeConfig
    from repro.core import planner
    shape = ShapeConfig("t", 8, 2, "train")
    base_cfg = reduced(ARCHS["qwen2-0.5b"])
    base = {g.name: g for g in planner.model_gemms(base_cfg, shape)}
    sh_cfg = dataclasses.replace(base_cfg, mesh_shape=(2, 2))
    sh = {g.name: g for g in planner.model_gemms(sh_cfg, shape)}
    assert sh["attn.wq"].M == base["attn.wq"].M // 2      # col: M / tp
    assert sh["attn.wq"].T == base["attn.wq"].T // 2      # rows / dp
    assert sh["attn.wo"].N == base["attn.wo"].N // 2      # row: N / tp
    assert sh["attn.wo"].epilogue_ops == \
        base["attn.wo"].epilogue_ops + 1                  # psum tree priced
    assert sh["attn.qk"].count == base["attn.qk"].count // 4
    assert sh["unembed"].M == base["unembed"].M // 2
    # GQA regression: the qk/pv count divides by the shards of the RUNTIME
    # batch axis (B*KV), not of the analytic count (n_attn*B*H) — here
    # B*KV = 1*2 is indivisible by tp=4, so the dispatch replicates and
    # the analytic table must claim no sharding either
    b1 = ShapeConfig("b1", 8, 1, "train")
    gqa_base = {g.name: g for g in planner.model_gemms(base_cfg, b1)}
    gqa = {g.name: g for g in planner.model_gemms(
        dataclasses.replace(base_cfg, mesh_shape=(1, 4)), b1)}
    assert gqa["attn.qk"].count == gqa_base["attn.qk"].count
    # gemm_sharding="none" keeps the logical table
    off = dataclasses.replace(base_cfg, mesh_shape=(2, 2),
                              gemm_sharding="none")
    assert planner.model_gemms(off, shape) == \
        planner.model_gemms(base_cfg, shape)
    # expert entries divide their count when E % tp == 0, else replicate
    moe_cfg = reduced(ARCHS["qwen3-moe-30b-a3b"])
    mbase = {g.name: g for g in planner.model_gemms(moe_cfg, shape)}
    msh = {g.name: g for g in planner.model_gemms(
        dataclasses.replace(moe_cfg, mesh_shape=(1, 2)), shape)}
    assert msh["moe.wi_gate"].count == mbase["moe.wi_gate"].count // 2
    m3 = {g.name: g for g in planner.model_gemms(
        dataclasses.replace(moe_cfg, mesh_shape=(1, 3)), shape)}
    assert m3["moe.wi_gate"].count == mbase["moe.wi_gate"].count  # 4 % 3


# --------------------------- single-device shard_map execution (tier-1)
def test_sharded_dispatch_degenerate_mesh_exact():
    """The shard_map execution path itself runs on any host: a (1, 1) mesh
    context (incl. a size-1 psum reduce) must reproduce the unsharded
    dispatch for every backend and epilogue."""
    mesh = make_host_mesh(1, 1)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 16), jnp.float32)
    w = jnp.asarray(rng.randn(16, 32), jnp.float32)
    w2 = jnp.asarray(rng.randn(16, 32), jnp.float32)
    b = jnp.asarray(rng.randn(32), jnp.float32)
    ctx = substrate.ShardCtx(mesh, P(None, None), P(None, None),
                             P(None, None))
    red = substrate.ShardCtx(mesh, P(None, None), P(None, None),
                             P(None, None), reduce_axes=("model",))
    for backend in ("xla", "arrayflex", "ref"):
        want = substrate.gemm(x, w, backend=backend, w2=w2, bias=b,
                              epilogue="swiglu")
        got = substrate.gemm(x, w, backend=backend, w2=w2, bias=b,
                             epilogue="swiglu", shard=ctx)
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-5, atol=1e-4)
        want_r = substrate.gemm(x, w, backend=backend, bias=b,
                                epilogue="silu")
        got_r = substrate.gemm(x, w, backend=backend, bias=b,
                               epilogue="silu", shard=red)
        np.testing.assert_allclose(np.float32(got_r), np.float32(want_r),
                                   rtol=1e-5, atol=1e-4)
    # batched + expert entries through their shard_map paths
    xb = jnp.asarray(rng.randn(4, 8, 16), jnp.float32)
    wb = jnp.asarray(rng.randn(4, 16, 8), jnp.float32)
    s3 = P(None, None, None)
    got = substrate.batched_gemm(xb, wb,
                                 shard=substrate.ShardCtx(mesh, s3, s3, s3))
    np.testing.assert_allclose(np.float32(got),
                               np.float32(substrate.batched_gemm(xb, wb)),
                               rtol=1e-5, atol=1e-4)
    xe = jnp.asarray(rng.randn(2, 4, 3, 16), jnp.float32)
    we = jnp.asarray(rng.randn(4, 16, 8), jnp.float32)
    ec = substrate.ShardCtx(mesh, P(None, None, None, None),
                            P(None, None, None), P(None, None, None, None))
    got = substrate.expert_gemm(xe, we, shard=ec)
    np.testing.assert_allclose(np.float32(got),
                               np.float32(substrate.expert_gemm(xe, we)),
                               rtol=1e-5, atol=1e-4)


# ----------------------------------- multi-device equivalence (8 devices)
def _cfg(arch, backend="xla", mesh=()):
    """fp32 everywhere: cross-mesh differences are pure accumulation
    order, so logits agree to fp32 tolerance and greedy ties cannot
    flip."""
    return reduced(ARCHS[arch], compute_dtype="float32",
                   param_dtype="float32", gemm_backend=backend,
                   mesh_shape=mesh)


_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        _PARAMS[arch] = lm.init_params(_cfg(arch), jax.random.PRNGKey(0))
    return _PARAMS[arch]


_TOKS = np.random.RandomState(0).randint(2, 512, (2, 16))
MESHES = {"tp2": (1, 2), "tp4": (1, 4), "fsdp2_tp2": (2, 2)}


@needs8
@pytest.mark.parametrize("backend", ["xla", "arrayflex"])
@pytest.mark.parametrize("mesh", list(MESHES.values()), ids=list(MESHES))
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m"])
def test_multidev_forward_matches_unsharded(arch, mesh, backend):
    """Acceptance: sharded logits match the unsharded xla path for every
    family x mesh x backend cell."""
    toks = jnp.asarray(_TOKS, jnp.int32)
    want, _, _ = lm.forward(_cfg(arch), _params(arch), {"tokens": toks})
    got, _, _ = lm.forward(_cfg(arch, backend, mesh), _params(arch),
                           {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-3)


def _greedy_stream(cfg, params, steps=5):
    cache = lm.init_cache(cfg, 2, 16)
    toks = jnp.asarray(_TOKS[:, :8], jnp.int32)
    logits, cache = lm.prefill_step(cfg, params, cache, toks,
                                    jnp.zeros(2, jnp.int32),
                                    jnp.full(2, 8, jnp.int32))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(steps):
        out.append(np.asarray(tok).tolist())
        logits, cache = lm.decode_step(cfg, params, cache, tok,
                                       jnp.int32(8 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return out


@needs8
@pytest.mark.parametrize("backend", ["xla", "arrayflex"])
@pytest.mark.parametrize("mesh", list(MESHES.values()), ids=list(MESHES))
def test_multidev_greedy_stream_identical(mesh, backend):
    """Acceptance: prefill + decode greedy streams are bit-identical to
    the unsharded path under every mesh."""
    params = _params("qwen2-0.5b")
    want = _greedy_stream(_cfg("qwen2-0.5b"), params)
    got = _greedy_stream(_cfg("qwen2-0.5b", backend, mesh), params)
    assert got == want


@needs8
@pytest.mark.parametrize("backend", ["xla", "arrayflex"])
@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "mamba2-370m"])
def test_multidev_moe_mamba_decode_step(arch, backend):
    params = _params(arch)
    tok = jnp.asarray([3, 5], jnp.int32)
    want, _ = lm.decode_step(_cfg(arch), params,
                             lm.init_cache(_cfg(arch), 2, 8), tok,
                             jnp.int32(0))
    got, _ = lm.decode_step(_cfg(arch, backend, (1, 2)), params,
                            lm.init_cache(_cfg(arch), 2, 8), tok,
                            jnp.int32(0))
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-3)


@needs8
def test_multidev_site_plans_and_dispatch_counts():
    """Plan cache keys on post-partition shapes (logical vs per-shard
    recorded) and sharded dispatch stays ONE launch per site."""
    params = _params("qwen2-0.5b")
    toks = {"tokens": jnp.asarray(_TOKS, jnp.int32)}
    substrate.clear_plan_cache()
    jax.eval_shape(lambda p, b: lm.forward(_cfg("qwen2-0.5b", "arrayflex"),
                                           p, b), params, toks)
    base_counts = dict(substrate.DISPATCH_COUNTS)
    substrate.clear_plan_cache()
    cfg = _cfg("qwen2-0.5b", "arrayflex", (1, 4))
    jax.eval_shape(lambda p, b: lm.forward(cfg, p, b), params, toks)
    assert dict(substrate.DISPATCH_COUNTS) == base_counts
    wq = substrate.SITE_PLANS["attn.wq"]
    assert wq.shard.cols == 4 and wq.M_shard == wq.M // 4
    assert (wq.N_shard, wq.T_shard) == (wq.N, wq.T)
    wo = substrate.SITE_PLANS["attn.wo"]
    assert wo.shard.contraction == 4 and wo.shard.reduce_ops == 2
    assert wo.N_shard == wo.N // 4
    assert substrate.SITE_PLANS["mlp.wi_gate"].shard.cols == 4
    assert substrate.SITE_PLANS["unembed"].shard.cols == 4
    # FSDP axis shards the streamed rows too
    substrate.clear_plan_cache()
    cfg = _cfg("qwen2-0.5b", "arrayflex", (2, 2))
    jax.eval_shape(lambda p, b: lm.forward(cfg, p, b), params, toks)
    assert dict(substrate.DISPATCH_COUNTS) == base_counts
    wq = substrate.SITE_PLANS["attn.wq"]
    assert wq.shard.rows == 2 and wq.T_shard == wq.T // 2
    substrate.clear_plan_cache()


@needs8
def test_multidev_expert_parallel_and_fallback():
    """E % tp == 0 runs expert-parallel dispatch (the _MOE_EP condition);
    an indivisible TP degree falls back to replicated dispatch and still
    serves correct logits."""
    cfg4 = _cfg("qwen3-moe-30b-a3b", mesh=(1, 4))
    E = cfg4.moe.num_experts
    assert E == 4
    mesh4 = sharding.mesh_from_config(cfg4)
    assert sharding.expert_shard_ctx(E, mesh4) is not None
    mesh3 = make_host_mesh(1, 3, strict=True)
    assert sharding.expert_shard_ctx(E, mesh3) is None
    toks = jnp.asarray(_TOKS, jnp.int32)
    params = _params("qwen3-moe-30b-a3b")
    want, _, _ = lm.forward(_cfg("qwen3-moe-30b-a3b"), params,
                            {"tokens": toks})
    got, _, _ = lm.forward(_cfg("qwen3-moe-30b-a3b", mesh=(1, 3)), params,
                           {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-3)


@needs8
def test_multidev_engine_stream_identical():
    """The serving engine under --tp/--fsdp meshes produces bit-identical
    greedy token streams."""
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]

    def run(mesh, backend="xla"):
        cfg = _cfg("qwen2-0.5b", backend, mesh)
        eng = ServingEngine(cfg, _params("qwen2-0.5b"),
                            ServeConfig(max_batch=2, max_seq=32))
        if mesh:
            assert eng.mesh is not None
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    want = run(())
    assert run((2, 2)) == want
    assert run((1, 4), backend="arrayflex") == want


# ------------------------------------------- tier-1 subprocess coverage
def test_sharded_equivalence_subprocess():
    """On a single-device host, run the whole multidev suite once in an
    8-device subprocess so tier-1 always covers the acceptance matrix."""
    if len(jax.devices()) >= 8:
        pytest.skip("multi-device host runs test_multidev_* directly")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join("tests", "test_sharded_substrate.py"),
         "-k", "multidev"],
        capture_output=True, text=True, timeout=1500, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout[-4000:] + out.stderr[-2000:]
    assert "passed" in out.stdout
