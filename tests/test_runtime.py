"""Runtime fault-tolerance layer: heartbeats, checkpoint-restart, elastic.

Promised by ``runtime/fault.py``'s module docstring since the seed: drives
dead-host / straggler scenarios through :class:`HeartbeatMonitor` with an
injected clock, the :class:`FaultToleranceManager` restart loop through
failures injected at every phase of the checkpoint cadence, and property
tests over :func:`elastic.largest_mesh_shape` (hypothesis, or the stub in
``tests/_hypothesis_stub.py`` when absent).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import CheckpointManager
from repro.runtime import FaultToleranceManager, HeartbeatMonitor
from repro.runtime import elastic
from repro.runtime.fault import RECOVERABLE


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------- monitor
def test_monitor_dead_hosts_by_timeout():
    clk = FakeClock()
    mon = HeartbeatMonitor(3, dead_after_s=10.0, clock=clk)
    # nobody has beaten yet: everyone is dead
    assert mon.dead_hosts() == [0, 1, 2]
    for h in range(3):
        mon.beat(h, step=5, step_time_s=1.0)
    assert mon.dead_hosts() == []
    clk.t = 11.0
    assert mon.dead_hosts() == [0, 1, 2]
    mon.beat(1, step=6, step_time_s=1.0)
    assert mon.dead_hosts() == [0, 2]


def test_monitor_stragglers_need_quorum():
    clk = FakeClock()
    mon = HeartbeatMonitor(8, straggler_factor=2.0, clock=clk)
    mon.beat(0, 1, 10.0)
    mon.beat(1, 1, 1.0)
    # fewer than max(2, n//2)=4 beats: no straggler verdicts yet
    assert mon.stragglers() == []
    mon.beat(2, 1, 1.0)
    mon.beat(3, 1, 1.1)
    assert mon.stragglers() == [0]      # 10s >> 2 x median(~1s)


def test_monitor_single_host_never_straggles():
    mon = HeartbeatMonitor(1, clock=FakeClock())
    mon.beat(0, 1, 100.0)
    # a fleet of one has no median to be slower than
    assert mon.stragglers() == []


# ------------------------------------------------- checkpoint-restart loop
class CountingSource:
    """batch_at(step) -> the step index; the training invariant below is
    state == sum of consumed batches, so lost/duplicated batches show up
    as a wrong final sum."""

    def batch_at(self, step):
        return step


def _mk_ftm(tmp_path, ckpt_every=3, **kw):
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=3)
    mon = HeartbeatMonitor(1, clock=FakeClock())
    return FaultToleranceManager(mgr, mon, ckpt_every=ckpt_every, **kw)


def _step_fn(state, batch):
    return {"acc": state["acc"] + np.float64(batch)}


@pytest.mark.parametrize("fail_step", list(range(4, 10)))
def test_restart_resumes_exact_state(tmp_path, fail_step):
    """Inject one RuntimeError at every phase of the ckpt_every=3 cadence
    (right after a save, mid-interval, right before one): the loop must
    reach n_steps with state == sum(range(n)) — no lost or replayed
    batch escapes the sum."""
    ft = _mk_ftm(tmp_path)
    fired = []

    def inject(step):
        if step == fail_step and not fired:
            fired.append(step)
            raise RuntimeError("simulated node failure")

    state, steps, restarts = ft.run({"acc": np.float64(0)}, _step_fn,
                                    CountingSource(), 10,
                                    inject_failure=inject)
    assert steps == 10 and restarts == 1
    assert state["acc"] == sum(range(10))


def test_failure_before_first_checkpoint_raises_by_default(tmp_path):
    """A crash with no durable checkpoint is a cold restart; the default
    contract is to surface it, not silently replay from step 0."""
    ft = _mk_ftm(tmp_path)

    def inject(step):
        if step == 1:
            raise RuntimeError("early crash")

    with pytest.raises(RuntimeError, match="early crash"):
        ft.run({"acc": np.float64(0)}, _step_fn, CountingSource(), 10,
               inject_failure=inject)
    assert ft.restarts == 1 and ft.cold_restarts == 0


def test_cold_restart_opt_in_replays_from_zero(tmp_path):
    ft = _mk_ftm(tmp_path)
    fired = []

    def inject(step):
        if step == 1 and not fired:
            fired.append(step)
            raise RuntimeError("early crash")

    state, steps, restarts = ft.run({"acc": np.float64(0)}, _step_fn,
                                    CountingSource(), 10,
                                    inject_failure=inject,
                                    cold_restart="restart")
    assert steps == 10 and restarts == 1 and ft.cold_restarts == 1
    assert state["acc"] == sum(range(10))


def test_cold_restart_rejects_unknown_mode(tmp_path):
    ft = _mk_ftm(tmp_path)
    with pytest.raises(ValueError, match="cold_restart"):
        ft.run({"acc": np.float64(0)}, _step_fn, CountingSource(), 2,
               cold_restart="retry")


def test_unrecoverable_exception_propagates(tmp_path):
    """Programming errors are not node failures: a TypeError must escape
    the restart loop immediately, not burn max_restarts retries."""
    assert RuntimeError in RECOVERABLE and OSError in RECOVERABLE
    ft = _mk_ftm(tmp_path)

    def inject(step):
        if step == 4:
            raise TypeError("bug, not a fault")

    with pytest.raises(TypeError):
        ft.run({"acc": np.float64(0)}, _step_fn, CountingSource(), 10,
               inject_failure=inject)
    assert ft.restarts == 0


def test_custom_recoverable_tuple(tmp_path):
    ft = _mk_ftm(tmp_path)
    fired = []

    def inject(step):
        if step == 4 and not fired:
            fired.append(step)
            raise KeyError("flaky storage layer")

    state, steps, restarts = ft.run({"acc": np.float64(0)}, _step_fn,
                                    CountingSource(), 10,
                                    inject_failure=inject,
                                    recoverable=(KeyError,))
    assert steps == 10 and restarts == 1
    assert state["acc"] == sum(range(10))


def test_max_restarts_exceeded_reraises(tmp_path):
    ft = _mk_ftm(tmp_path, max_restarts=2)

    def inject(step):
        if step == 4:
            raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError, match="permanent failure"):
        ft.run({"acc": np.float64(0)}, _step_fn, CountingSource(), 10,
               inject_failure=inject)
    assert ft.restarts == 3        # 2 recoveries + the re-raising attempt


def test_beats_carry_host_index(tmp_path):
    ft = _mk_ftm(tmp_path, host_index=2)
    ft.monitor.n_hosts = 3
    ft.run({"acc": np.float64(0)}, _step_fn, CountingSource(), 4)
    assert 2 in ft.monitor.beats
    assert 0 not in ft.monitor.beats
    assert ft.monitor.beats[2].step == 3


def test_resume_across_manager_instances(tmp_path):
    """A fresh FTM over the same directory resumes from the durable step
    (process-death recovery, not just in-process restart)."""
    ft1 = _mk_ftm(tmp_path)

    def inject(step):
        if step == 7:
            raise OSError("process killed")

    with pytest.raises(OSError):
        # max_restarts=0 via a fresh manager: make the first failure fatal
        ft1.max_restarts = 0
        ft1.run({"acc": np.float64(0)}, _step_fn, CountingSource(), 10,
                inject_failure=inject)
    ft2 = _mk_ftm(tmp_path)
    state, steps, restarts = ft2.run({"acc": np.float64(0)}, _step_fn,
                                     CountingSource(), 10)
    assert steps == 10 and restarts == 0
    assert state["acc"] == sum(range(10))


# ------------------------------------------------------------ elastic
@settings(max_examples=60)
@given(n=st.integers(min_value=1, max_value=256),
       m=st.integers(min_value=1, max_value=64))
def test_largest_mesh_shape_properties(n, m):
    data, model = elastic.largest_mesh_shape(n, m)
    assert data * model == n                      # every device placed
    assert 1 <= model <= min(m, n)                # never exceeds the ask
    # maximality: no larger valid TP degree <= m divides n
    assert all(n % k for k in range(model + 1, min(m, n) + 1))


@settings(max_examples=30)
@given(n=st.integers(min_value=1, max_value=64))
def test_largest_mesh_shape_tp1_is_pure_data(n):
    assert elastic.largest_mesh_shape(n, 1) == (n, 1)


def test_replan_mesh_smoke():
    mesh, state = elastic.replan_mesh(model_parallel=1)
    assert state.mesh_shape[0] * state.mesh_shape[1] == state.n_devices
    assert mesh.axis_names == ("data", "model")
