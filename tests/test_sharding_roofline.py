"""Sharding rules, HLO analyzer, roofline model, multi-device pipeline."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.models import api
from repro.roofline import hlo as hlo_lib
from repro.roofline import model as roof


def _mesh11():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_param_pspecs_cover_all_leaves():
    mesh = _mesh11()
    for arch in ("mixtral-8x22b", "jamba-1.5-large-398b", "whisper-base"):
        cfg = ARCHS[arch]
        specs = api.param_pspecs(cfg, mesh)
        params = api.abstract_params(cfg)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            assert isinstance(spec, P)
            assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


def test_moe_weights_shard_over_experts_or_ff():
    mesh = _mesh11()
    cfg = ARCHS["mixtral-8x22b"]
    specs = api.param_pspecs(cfg, mesh)
    wi = specs["blocks"][0]["moe"]["wi_gate"]
    # stacked leading dim unsharded; one of E/d/ff dims carries an axis
    assert wi[0] is None
    assert any(a is not None for a in tuple(wi)[1:])


def test_embed_table_sharded():
    mesh = _mesh11()
    specs = api.param_pspecs(ARCHS["qwen2-0.5b"], mesh)
    assert tuple(specs["embed"]["table"]) == ("model", "data")


# ------------------------------------------------------------- HLO parse
_HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %dot = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(f)/while/dot_general"}
  %ar = f32[64,64]{1,0} all-reduce(%dot), replica_groups=[4,2]<=[8], to_apply=%add, metadata={op_name="jit(f)/while/psum"}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%ip, %ar)
}

%cond (p2: (s32[], f32[64,64])) -> pred[] {
  %p2 = (s32[], f32[64,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%z, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_trip_count_multiplication():
    res = hlo_lib.analyze(_HLO_SAMPLE)
    # 10 iterations x 2*64^3 flops
    assert res["flops_per_device"] == pytest.approx(10 * 2 * 64 ** 3)
    # all-reduce moves 2*(n-1)/n * bytes, n=2, x10 trips
    expect = 10 * 2 * (1 / 2) * 64 * 64 * 4
    assert res["collective_bytes_per_device"]["all-reduce"] == \
        pytest.approx(expect)
    assert not res["unknown_trip_count"]
    assert res["top_flops"][0][0].startswith("while/dot_general")


_HLO_FUSION = """
HloModule test2

%fused_dus (p0: f32[128,64], p1: f32[1,64], p2: s32[]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %p1 = f32[1,64]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  ROOT %dus = f32[128,64]{1,0} dynamic-update-slice(%p0, %p1, %p2, %p2)
}

ENTRY %main (a: f32[128,64], u: f32[1,64], i: s32[]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %u = f32[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[128,64]{1,0} fusion(%a, %u, %i), kind=kLoop, calls=%fused_dus
}
"""


def test_hlo_analyzer_dus_fusion_slice_aware():
    """A DUS-rooted fusion touches only its update, not the big buffer."""
    res = hlo_lib.analyze(_HLO_FUSION)
    # 2 * update bytes (1*64*4), NOT operand+result (2*128*64*4)
    assert res["hbm_bytes_per_device"] == pytest.approx(2 * 64 * 4)
    assert res["flops_per_device"] == 0


def test_roofline_terms():
    hl = {"flops_per_device": roof.PEAK_FLOPS_BF16,
          "hbm_bytes_per_device": roof.HBM_BW / 2,
          "collective_total_per_device": 0.0,
          "collective_bytes_per_device": {}}
    t = roof.terms_from_analysis(hl)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.dominant == "compute"
    assert t.roofline_fraction == pytest.approx(1.0)


def test_model_flops_moe_active():
    mf = roof.model_flops(ARCHS["mixtral-8x22b"],
                          __import__("repro.configs", fromlist=["SHAPES"])
                          .SHAPES["train_4k"])
    assert mf["n_active_params"] < 0.4 * mf["n_params"]


# ------------------------------------------------------- pipeline (8 dev)
_PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    import sys
    sys.path.insert(0, "src")
    from repro.parallel.pipeline import make_pipelined

    mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("pod",))
    d, mb, M = 8, 4, 6
    rng = np.random.RandomState(0)
    stage_w = jnp.asarray(rng.randn(4, d, d) * 0.3, jnp.float32)
    x = jnp.asarray(rng.randn(M, mb, d), jnp.float32)

    def stage(w, h):
        return jnp.tanh(h @ w)

    piped = jax.jit(make_pipelined(stage, mesh, stage_param_spec=P("pod"),
                                   x_spec=P()))
    got = piped(stage_w, x)
    want = x
    for i in range(4):
        want = jnp.tanh(want @ stage_w[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPE_OK")
""")


def test_gpipe_matches_sequential_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _PIPE_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "PIPE_OK" in out.stdout, out.stderr[-2000:]
