"""Batched chunked prefill == seed per-token prefill (greedy, bit-exact).

The engine's batched prefill path must be a pure performance refactor:
identical greedy token streams for mixed-length prompts (including slot
reuse after EOS and prompts spanning several chunks), with O(P/chunk)
prefill dispatches instead of P.

The paged K/V path extends the same contract: block-table paged
attention (with or without radix prefix reuse) must emit bit-identical
greedy streams to the dense path, while admission becomes page-budget
bounded and shared prefixes stop being recomputed.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels import substrate
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


PROMPTS = [
    [5, 6, 7],
    [11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21],   # spans several chunks
    [21],                                            # no prefill at all
    [31, 32, 33, 34, 35],
    [41, 42, 43, 44, 45, 46, 47, 48],
]


def _run(cfg, params, mode, prompts, *, eos=-1, chunk=0, max_batch=2,
         max_new=5):
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=max_batch, max_seq=64,
                                       eos_id=eos, prefill_mode=mode,
                                       prefill_chunk=chunk))
    reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], engine


def test_batched_prefill_matches_token_prefill(model):
    cfg, params = model
    token_out, _ = _run(cfg, params, "token", PROMPTS)
    for chunk in (4, 16, 0):            # 0 -> planner-chosen
        batched_out, _ = _run(cfg, params, "batched", PROMPTS, chunk=chunk)
        assert batched_out == token_out, f"chunk={chunk}"


def test_batched_prefill_matches_after_eos_slot_reuse(model):
    """EOS mid-stream frees a slot for the queue; streams must still match."""
    cfg, params = model
    first, _ = _run(cfg, params, "token", PROMPTS)
    # pick a token that actually occurs so EOS fires and truncates streams
    eos = first[0][1]
    token_out, _ = _run(cfg, params, "token", PROMPTS, eos=eos)
    batched_out, _ = _run(cfg, params, "batched", PROMPTS, eos=eos, chunk=4)
    assert batched_out == token_out
    assert any(len(t) < 5 for t in token_out), "EOS never fired"


def test_prefill_dispatch_count_is_chunked(model):
    """A P-token prompt must cost ceil(P/chunk) prefill dispatches, not P
    full-batch decode steps (and exactly P prefill tokens either way)."""
    cfg, params = model
    chunk = 4
    _, tok_eng = _run(cfg, params, "token", PROMPTS, max_batch=len(PROMPTS))
    _, bat_eng = _run(cfg, params, "batched", PROMPTS, chunk=chunk,
                      max_batch=len(PROMPTS))
    n_prefill = sum(len(p) - 1 for p in PROMPTS)
    assert tok_eng.stats["prefill_dispatches"] == n_prefill
    assert bat_eng.stats["prefill_tokens"] == n_prefill
    # all slots prefill concurrently: dispatches bounded by the longest
    # prompt's chunk count, far below the token path's P dispatches
    worst = max(math.ceil((len(p) - 1) / chunk) for p in PROMPTS)
    assert bat_eng.stats["prefill_dispatches"] <= worst
    assert bat_eng.stats["prefill_dispatches"] < n_prefill


def test_prefill_writes_only_target_rows(model):
    """Batched prefill must not pollute co-resident slots' KV caches."""
    cfg, params = model
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=3, max_seq=64,
                                       prefill_mode="batched"))
    engine.submit(Request(prompt=[7, 8, 9, 10, 11], max_new_tokens=1))
    engine._admit()
    engine._prefill_tick()
    for layer in engine.cache:
        for key in ("k", "v"):
            rows = np.asarray(layer[key])[:, 1:]     # slots 1, 2: untouched
            assert not np.any(rows), "prefill wrote a non-target row"


def test_submit_rejects_bad_prompts(model):
    cfg, params = model
    engine = ServingEngine(cfg, params, ServeConfig(max_batch=1, max_seq=16))
    with pytest.raises(ValueError):
        engine.submit(Request(prompt=[]))
    with pytest.raises(ValueError):
        engine.submit(Request(prompt=list(range(2, 42))))   # 40 > max_seq
    engine.submit(Request(prompt=list(range(2, 18)), max_new_tokens=1))
    engine.run_to_completion()


def test_single_token_prompt_skips_prefill(model):
    cfg, params = model
    out, engine = _run(cfg, params, "batched", [[9]], max_batch=1)
    assert engine.stats["prefill_dispatches"] == 0
    assert len(out[0]) == 5


# ---------------------------------------------------------------------------
# paged K/V path


def _run_paged(cfg, params, prompts, *, kv_pages, page_size=0,
               prefix_cache=False, max_batch=2, max_new=5, eos=-1):
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=max_batch, max_seq=64,
                                       eos_id=eos, prefill_mode="batched",
                                       kv_pages=kv_pages,
                                       page_size=page_size,
                                       prefix_cache=prefix_cache))
    reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], engine


def test_paged_matches_dense_streams(model):
    """The tentpole contract: paged attention (block-table gather over the
    global page pool) is a pure memory-layout refactor — greedy streams
    are bit-identical to the dense (max_batch, max_seq) cache, for every
    page geometry and with the prefix cache on or off."""
    cfg, params = model
    dense_out, _ = _run(cfg, params, "batched", PROMPTS)
    for page, prefix in ((16, False), (16, True), (8, True), (0, False)):
        paged_out, engine = _run_paged(cfg, params, PROMPTS, kv_pages=40,
                                       page_size=page, prefix_cache=prefix)
        assert paged_out == dense_out, \
            f"page_size={page} prefix_cache={prefix}"
        # every sequence released its reservations; only tree-owned
        # published prefix pages (refcount 1) may remain resident
        held = engine.radix.n_pages() if engine.radix else 0
        assert engine.pool.n_used == held


def test_paged_matches_dense_with_eos(model):
    cfg, params = model
    first, _ = _run(cfg, params, "batched", PROMPTS)
    eos = first[0][1]
    dense_out, _ = _run(cfg, params, "batched", PROMPTS, eos=eos)
    paged_out, _ = _run_paged(cfg, params, PROMPTS, kv_pages=40,
                              page_size=8, prefix_cache=True, eos=eos)
    assert paged_out == dense_out
    assert any(len(t) < 5 for t in paged_out), "EOS never fired"


def _staggered_shared_prefix_run(cfg, params, *, prefix_cache):
    """One request completes prefill first (publishing its prompt pages
    when the cache is on), then followers sharing its 32-token system
    prompt arrive — the reuse-sensitive schedule."""
    system = list(range(3, 35))                       # 32 = 4 pages of 8
    prompts = [system + [40 + i, 41 + i] for i in range(4)]
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=2, max_seq=64,
                                       prefill_mode="batched",
                                       prefill_chunk=8, kv_pages=60,
                                       page_size=8,
                                       prefix_cache=prefix_cache))
    reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
            for i, p in enumerate(prompts)]
    engine.submit(reqs[0])
    while not reqs[0].out_tokens:                     # prefix now published
        engine.step()
    for r in reqs[1:]:
        engine.submit(r)
    engine.run_to_completion()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], engine


def test_prefix_reuse_cuts_prefill_gemm_dispatches(model):
    """Followers sharing a published system prompt skip its pages: whole
    prefill chunks disappear, so substrate-counted GEMM launches drop —
    with streams unchanged (shared pages are bit-identical to recomputed
    ones)."""
    cfg, params = model
    cold_out, cold = _staggered_shared_prefix_run(cfg, params,
                                                  prefix_cache=False)
    warm_out, warm = _staggered_shared_prefix_run(cfg, params,
                                                  prefix_cache=True)
    assert warm_out == cold_out
    assert warm.stats["prefix_hit_tokens"] > 0
    assert warm.stats["prefill_tokens"] < cold.stats["prefill_tokens"]
    assert (warm.stats["prefill_gemm_dispatches"]
            < cold.stats["prefill_gemm_dispatches"])


def test_attention_plan_cache_settles_after_first_decode(model):
    """Serving steady state plans nothing: every attention_plan lookup
    after the first decode tick hits the planner's cache (the geometry
    is fixed per engine, so a steady-state miss would mean the plan key
    is unstable)."""
    cfg, params = model
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=2, max_seq=64,
                                       prefill_mode="batched", kv_pages=40,
                                       page_size=16, prefix_cache=True))
    for r in [Request(prompt=p, max_new_tokens=6, rid=i)
              for i, p in enumerate(PROMPTS)]:
        engine.submit(r)
    while engine.stats["decode_dispatches"] < 1:
        engine.step()
    misses0 = substrate.plan_cache_info().attention_plan["misses"]
    engine.run_to_completion()
    info = substrate.plan_cache_info().attention_plan
    assert info["misses"] == misses0, \
        f"attention_plan missed in steady state: {info}"


def test_int8_engine_serves_prequantized_without_in_trace_requantize(model):
    """The quantizing backend serves from the pre-quantized tree: zero
    in-trace quantize_weight stagings (the AF008 hoist), with streams
    bitwise equal to the in-trace-quantizing reference decode loop."""
    cfg, params = model
    cfg8 = dataclasses.replace(cfg, gemm_backend="arrayflex_int8")
    prompts = PROMPTS[:3]
    traced0 = substrate.QUANT_CACHE_STATS["traced"]
    paged_out, engine = _run_paged(cfg8, params, prompts, kv_pages=40,
                                   page_size=16, max_new=4)
    assert substrate.QUANT_CACHE_STATS["traced"] == traced0, \
        "engine staged quantize_weight inside a compiled step"
    quant_leaves = jax.tree_util.tree_leaves(
        engine.params,
        is_leaf=lambda x: isinstance(x, substrate.QuantizedTensor))
    assert any(isinstance(leaf, substrate.QuantizedTensor)
               for leaf in quant_leaves), "tree was not pre-quantized"
    # reference: raw-tree decode loop, quantization staged in-trace
    step = jax.jit(lambda p, c, t, q: lm.decode_step(cfg8, p, c, t, q))
    for rid, prompt in enumerate(prompts):
        cache = lm.init_cache(cfg8, 1, 64)
        out = []
        for i, t in enumerate(prompt[:-1]):
            _, cache = step(params, cache,
                            jnp.asarray([t], jnp.int32), jnp.int32(i))
        tok = prompt[-1]
        for i in range(4):
            logits, cache = step(params, cache,
                                 jnp.asarray([tok], jnp.int32),
                                 jnp.int32(len(prompt) - 1 + i))
            tok = int(jnp.argmax(logits[0]))
            out.append(tok)
        assert out == paged_out[rid], f"req {rid} diverged"
