"""Batched chunked prefill == seed per-token prefill (greedy, bit-exact).

The engine's batched prefill path must be a pure performance refactor:
identical greedy token streams for mixed-length prompts (including slot
reuse after EOS and prompts spanning several chunks), with O(P/chunk)
prefill dispatches instead of P.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


PROMPTS = [
    [5, 6, 7],
    [11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21],   # spans several chunks
    [21],                                            # no prefill at all
    [31, 32, 33, 34, 35],
    [41, 42, 43, 44, 45, 46, 47, 48],
]


def _run(cfg, params, mode, prompts, *, eos=-1, chunk=0, max_batch=2,
         max_new=5):
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=max_batch, max_seq=64,
                                       eos_id=eos, prefill_mode=mode,
                                       prefill_chunk=chunk))
    reqs = [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], engine


def test_batched_prefill_matches_token_prefill(model):
    cfg, params = model
    token_out, _ = _run(cfg, params, "token", PROMPTS)
    for chunk in (4, 16, 0):            # 0 -> planner-chosen
        batched_out, _ = _run(cfg, params, "batched", PROMPTS, chunk=chunk)
        assert batched_out == token_out, f"chunk={chunk}"


def test_batched_prefill_matches_after_eos_slot_reuse(model):
    """EOS mid-stream frees a slot for the queue; streams must still match."""
    cfg, params = model
    first, _ = _run(cfg, params, "token", PROMPTS)
    # pick a token that actually occurs so EOS fires and truncates streams
    eos = first[0][1]
    token_out, _ = _run(cfg, params, "token", PROMPTS, eos=eos)
    batched_out, _ = _run(cfg, params, "batched", PROMPTS, eos=eos, chunk=4)
    assert batched_out == token_out
    assert any(len(t) < 5 for t in token_out), "EOS never fired"


def test_prefill_dispatch_count_is_chunked(model):
    """A P-token prompt must cost ceil(P/chunk) prefill dispatches, not P
    full-batch decode steps (and exactly P prefill tokens either way)."""
    cfg, params = model
    chunk = 4
    _, tok_eng = _run(cfg, params, "token", PROMPTS, max_batch=len(PROMPTS))
    _, bat_eng = _run(cfg, params, "batched", PROMPTS, chunk=chunk,
                      max_batch=len(PROMPTS))
    n_prefill = sum(len(p) - 1 for p in PROMPTS)
    assert tok_eng.stats["prefill_dispatches"] == n_prefill
    assert bat_eng.stats["prefill_tokens"] == n_prefill
    # all slots prefill concurrently: dispatches bounded by the longest
    # prompt's chunk count, far below the token path's P dispatches
    worst = max(math.ceil((len(p) - 1) / chunk) for p in PROMPTS)
    assert bat_eng.stats["prefill_dispatches"] <= worst
    assert bat_eng.stats["prefill_dispatches"] < n_prefill


def test_prefill_writes_only_target_rows(model):
    """Batched prefill must not pollute co-resident slots' KV caches."""
    cfg, params = model
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=3, max_seq=64,
                                       prefill_mode="batched"))
    engine.submit(Request(prompt=[7, 8, 9, 10, 11], max_new_tokens=1))
    engine._admit()
    engine._prefill_tick()
    for layer in engine.cache:
        for key in ("k", "v"):
            rows = np.asarray(layer[key])[:, 1:]     # slots 1, 2: untouched
            assert not np.any(rows), "prefill wrote a non-target row"


def test_submit_rejects_bad_prompts(model):
    cfg, params = model
    engine = ServingEngine(cfg, params, ServeConfig(max_batch=1, max_seq=16))
    with pytest.raises(ValueError):
        engine.submit(Request(prompt=[]))
    with pytest.raises(ValueError):
        engine.submit(Request(prompt=list(range(2, 42))))   # 40 > max_seq
    engine.submit(Request(prompt=list(range(2, 18)), max_new_tokens=1))
    engine.run_to_completion()


def test_single_token_prompt_skips_prefill(model):
    cfg, params = model
    out, engine = _run(cfg, params, "batched", [[9]], max_batch=1)
    assert engine.stats["prefill_dispatches"] == 0
    assert len(out[0]) == 5
