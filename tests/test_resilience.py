"""Resilient serving: seeded chaos, typed outcomes, graceful degradation.

The acceptance contract of the resilience layer, test by test:

* under every injected fault, every request terminates with exactly one
  typed :class:`~repro.serving.errors.Outcome` — no hang, no silent
  garbage in the stream;
* chaos is replayable: an injection decision is a pure function of
  (seed, point, draw index), independent of interleaving;
* a request preempted mid-decode (page-pool pressure) and recomputed on
  re-admission emits a stream bit-identical to an un-preempted run;
* an engine killed mid-stream and restored from its snapshot continues
  bit-identically;
* with chaos off, the hardened engine's streams are bit-identical to the
  unhardened baseline (resilience is free in the fault-free path).
"""
import dataclasses

import jax
import pytest

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.runtime import chaos
from repro.runtime.chaos import ChaosConfig, ChaosEngine
from repro.serving import (AdmissionError, EngineCrash, Outcome,
                           ServeConfig, ServingEngine)
from repro.serving.engine import Request
from repro.serving.paged import PagePool, RadixCache


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


PROMPTS = [
    [5, 6, 7],
    [11, 12, 13, 14, 15],
    [21],
]


def _mk(model, pr=PROMPTS, max_new=4, **sc_kw):
    cfg, params = model
    sc_kw.setdefault("max_batch", 2)
    sc_kw.setdefault("max_seq", 64)
    sc_kw.setdefault("prefill_mode", "batched")
    sc_kw.setdefault("prefill_chunk", 4)
    clock = sc_kw.pop("clock", None)
    kw = {"clock": clock} if clock is not None else {}
    eng = ServingEngine(cfg, params, ServeConfig(**sc_kw), **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=max_new, rid=i)
            for i, p in enumerate(pr)]
    for r in reqs:
        eng.submit(r)
    return eng, reqs


def _run(model, **sc_kw):
    eng, reqs = _mk(model, **sc_kw)
    eng.run_to_completion()
    return eng, reqs


@pytest.fixture(scope="module")
def baseline(model):
    _, reqs = _run(model)
    return [r.out_tokens for r in reqs]


# ---------------------------------------------------------- chaos engine
def test_chaos_decision_is_pure_function_of_seed_point_draw():
    a, b = ChaosEngine(ChaosConfig(seed=7, gemm_fault=0.5)), None
    seq_a = [a.fire("substrate.dispatch") for _ in range(64)]
    # interleave other points between draws: decisions must not move
    b = ChaosEngine(ChaosConfig(seed=7, gemm_fault=0.5))
    seq_b = []
    for _ in range(64):
        b.fire("engine.sample")
        b.fire("pool.alloc")
        seq_b.append(b.fire("substrate.dispatch"))
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)
    # a different seed gives a different (but deterministic) sequence
    c = ChaosEngine(ChaosConfig(seed=8, gemm_fault=0.5))
    assert [c.fire("substrate.dispatch") for _ in range(64)] != seq_a


def test_chaos_at_trigger_fires_exactly_once():
    e = ChaosEngine(ChaosConfig(nan_logits_at=2))
    hits = [e.fire("engine.sample") for _ in range(6)]
    assert hits == [False, False, True, False, False, False]
    assert e.chaos_log == [("engine.sample", 2, "")]


def test_chaos_state_snapshot_roundtrip():
    e = ChaosEngine(ChaosConfig(seed=3, gemm_fault=0.5))
    pre = [e.fire("substrate.dispatch") for _ in range(10)]
    snap = e.state_snapshot()
    tail = [e.fire("substrate.dispatch") for _ in range(10)]
    e2 = ChaosEngine(ChaosEngine.config_from_snapshot(snap))
    e2.load_state(snap)
    assert [e2.fire("substrate.dispatch") for _ in range(10)] == tail
    assert pre  # silence unused warning; pre-draws exercised the counter


def test_parse_spec_roundtrip_and_errors():
    c = chaos.parse_spec("seed=3, gemm=0.05, nan_at=2, crash=0.01")
    assert c == ChaosConfig(seed=3, gemm_fault=0.05, nan_logits_at=2,
                            crash=0.01)
    assert c.without_crash().crash == 0.0
    with pytest.raises(ValueError, match="unknown chaos spec key"):
        chaos.parse_spec("bogus=1")
    with pytest.raises(ValueError, match="key=value"):
        chaos.parse_spec("seed")


def test_ambient_fire_is_noop_outside_scope():
    assert chaos.active() is None
    assert chaos.fire("engine.tick") is False
    eng = ChaosEngine(ChaosConfig(crash_at=0))
    with chaos.scope(eng):
        assert chaos.active() is eng
        assert chaos.fire("engine.tick") is True
    assert chaos.active() is None


# ----------------------------------------------------- admission control
def test_bounded_queue_rejects_overload_typed(model):
    eng, _ = _mk(model, pr=[], max_queue=2)
    eng.submit(Request(prompt=[1, 2], max_new_tokens=2, rid=0))
    eng.submit(Request(prompt=[3, 4], max_new_tokens=2, rid=1))
    r = Request(prompt=[5, 6], max_new_tokens=2, rid=2)
    with pytest.raises(AdmissionError) as ei:
        eng.submit(r)
    assert ei.value.outcome == Outcome.REJECTED_OVERLOAD
    assert r.done and r.outcome == Outcome.REJECTED_OVERLOAD.value
    assert eng.stats["outcome_rejected_overload"] == 1
    # back-compat: AdmissionError still is a ValueError
    assert isinstance(ei.value, ValueError)


def test_invalid_prompt_fails_typed(model):
    eng, _ = _mk(model, pr=[])
    r = Request(prompt=[], max_new_tokens=2, rid=0)
    with pytest.raises(ValueError):
        eng.submit(r)
    assert r.done and r.outcome == Outcome.FAILED.value


# ---------------------------------------------------------- deadlines
def test_total_deadline_expires_typed(model):
    t = [0.0]
    eng, reqs = _mk(model, deadline_ms=5.0, max_new=50,
                    clock=lambda: t[0])
    eng.step()
    t[0] = 0.001                     # 1ms < 5ms: still running
    eng.step()
    assert not all(r.done for r in reqs)
    t[0] = 10.0                      # 10s >> 5ms
    eng.step()
    assert all(r.done and r.outcome == Outcome.DEADLINE_EXPIRED.value
               for r in reqs)
    assert eng.stats["outcome_deadline_expired"] == len(reqs)


def test_ttft_deadline_only_pre_first_token(model):
    t = [0.0]
    eng, reqs = _mk(model, ttft_deadline_ms=1000.0, max_new=3,
                    clock=lambda: t[0])
    eng.run_to_completion()
    # every request got its first token instantly (fake clock never moved)
    assert all(r.outcome == Outcome.OK.value for r in reqs)


# ------------------------------------------------ NaN/Inf logit handling
def test_transient_nan_retried_stream_identical(model, baseline):
    eng, reqs = _run(model, chaos=ChaosConfig(nan_logits_at=0))
    assert [r.out_tokens for r in reqs] == baseline
    assert eng.stats["sample_retries"] == 1
    assert all(r.outcome == Outcome.OK.value for r in reqs)


def test_persistent_nan_fails_typed_no_hang(model):
    eng, reqs = _run(model, chaos=ChaosConfig(nan_logits=1.0),
                     max_retries=1)
    assert all(r.done and r.outcome == Outcome.FAILED.value for r in reqs)
    assert all("non-finite" in r.error for r in reqs)
    assert eng.stats["outcome_failed"] == len(reqs)


# --------------------------------------------------- GEMM launch faults
def test_transient_gemm_fault_retried_stream_identical(model, baseline):
    eng, reqs = _run(model, chaos=ChaosConfig(gemm_fault_at=0))
    assert [r.out_tokens for r in reqs] == baseline
    assert eng.stats["kernel_fault_retries"] >= 1
    assert all(r.outcome == Outcome.OK.value for r in reqs)


def test_persistent_gemm_fault_fails_typed_no_hang(model):
    eng, reqs = _run(model, chaos=ChaosConfig(gemm_fault=1.0))
    assert all(r.done and r.outcome == Outcome.FAILED.value for r in reqs)
    assert eng.stats["outcome_failed"] == len(reqs)


# --------------------------------------------- page exhaustion + watchdog
def test_page_exhaustion_chaos_terminates_all_typed(model):
    eng, reqs = _run(model, kv_pages=24, page_size=8,
                     chaos=ChaosConfig(page_exhaust=1.0),
                     watchdog_ticks=4)
    assert all(r.done and r.outcome is not None for r in reqs)
    # nothing can admit, so the watchdog must have broken the stall
    assert eng.stats["watchdog_fired"] >= 1


def test_zero_chaos_probabilities_fire_nothing(model, baseline):
    eng, reqs = _run(model, chaos=ChaosConfig(seed=123))
    assert [r.out_tokens for r in reqs] == baseline
    assert eng._chaos.chaos_log == []


# ----------------------------------------------------------- preemption
def test_preemption_streams_bit_identical(model):
    _, ample = _run(model, max_new=8, kv_pages=40, page_size=8,
                    preempt_policy="youngest", prefix_cache=True)
    eng, tight = _run(model, max_new=8, kv_pages=5, page_size=8,
                      preempt_policy="youngest", prefix_cache=True)
    assert eng.stats["preemptions"] >= 1
    assert ([r.out_tokens for r in tight]
            == [r.out_tokens for r in ample])
    preempted = [r for r in tight if r.preemptions]
    assert preempted
    assert all(r.outcome == Outcome.PREEMPTED_RETRIED.value
               for r in preempted)
    assert all(r.outcome == Outcome.OK.value
               for r in tight if not r.preemptions)


def test_preemption_matches_dense_streams(model, baseline):
    eng, reqs = _run(model, kv_pages=5, page_size=8,
                     preempt_policy="youngest", prefix_cache=True)
    assert [r.out_tokens for r in reqs] == baseline


def test_policy_none_small_pool_rejected_at_construction(model):
    cfg, params = model
    with pytest.raises(ValueError, match="preempt_policy"):
        ServingEngine(cfg, params,
                      ServeConfig(max_batch=2, max_seq=64, kv_pages=5,
                                  page_size=8))


def test_unknown_preempt_policy_rejected(model):
    cfg, params = model
    with pytest.raises(ValueError, match="preempt_policy"):
        ServingEngine(cfg, params,
                      ServeConfig(max_batch=2, max_seq=64,
                                  preempt_policy="oldest"))


# ------------------------------------------------------- crash recovery
def _finish_after_restore(model, eng, reqs, max_restarts=3):
    cfg, params = model
    restarts = 0
    while True:
        try:
            eng.run_to_completion()
            break
        except EngineCrash:
            restarts += 1
            assert restarts <= max_restarts, "crash recovery livelocked"
            snap = eng.latest_snapshot()
            assert snap is not None
            eng = ServingEngine.restore(cfg, params, eng.sc, snap)
    final = {r.rid: r for r in reqs}
    for r in eng.restored_requests:
        final[r.rid] = r
    return eng, [final[r.rid] for r in reqs], restarts


@pytest.mark.parametrize("crash_at", [0, 2, 4])
def test_crash_restore_bit_identical(model, baseline, crash_at):
    eng, reqs = _mk(model, chaos=ChaosConfig(crash_at=crash_at),
                    snapshot_every_ticks=1)
    eng, reqs, restarts = _finish_after_restore(model, eng, reqs)
    assert restarts == 1
    assert [r.out_tokens for r in reqs] == baseline
    assert all(r.outcome == Outcome.OK.value for r in reqs)


def test_crash_restore_paged_with_prefix_cache(model):
    shared = [7, 8, 9, 10, 11, 12, 13, 14]
    pr = [shared + [20 + i] for i in range(3)]
    _, clean = _run(model, pr=pr, max_new=6, kv_pages=40, page_size=8,
                    prefix_cache=True)
    eng, reqs = _mk(model, pr=pr, max_new=6, kv_pages=40, page_size=8,
                    prefix_cache=True, chaos=ChaosConfig(crash_at=3),
                    snapshot_every_ticks=1)
    eng, reqs, restarts = _finish_after_restore(model, eng, reqs)
    assert restarts == 1
    assert ([r.out_tokens for r in reqs]
            == [r.out_tokens for r in clean])


def test_restore_strips_crash_trigger_by_default(model):
    eng, reqs = _mk(model, chaos=ChaosConfig(crash_at=1),
                    snapshot_every_ticks=1)
    with pytest.raises(EngineCrash):
        eng.run_to_completion()
    cfg, params = model
    e2 = ServingEngine.restore(cfg, params, eng.sc, eng.latest_snapshot())
    assert e2.sc.chaos.crash_at == -1
    # the chaos draw counters carried over: replay continues, not restarts
    assert e2._chaos.chaos_draws["engine.tick"] >= 1


def test_snapshot_without_crash_chaos_is_inert(model, baseline):
    eng, reqs = _run(model, snapshot_every_ticks=2)
    assert [r.out_tokens for r in reqs] == baseline
    assert eng.stats["snapshots_taken"] >= 1


# --------------------------------------------- pool/radix snapshot bits
def test_radix_snapshot_roundtrip_preserves_matches():
    pool = PagePool(16, 4)
    rad = RadixCache(4)
    toks = list(range(12))
    pages = pool.alloc(3)
    rad.insert(toks, pages, pool)
    rad2 = RadixCache.from_snapshot(rad.to_snapshot())
    assert rad2.match(toks) == rad.match(toks)
    assert rad2.n_pages() == rad.n_pages()
    assert rad2.n_nodes() == rad.n_nodes()
    # eviction on the restored tree releases the same pages
    pool2 = PagePool(16, 4)
    pool2.free_pages[:] = list(pool.free_pages)
    pool2.refcounts[:] = list(pool.refcounts)
    for pg in pages:
        pool.decref(pg)
        pool2.decref(pg)          # drop producer refs; tree ref remains
    assert rad.evict(3, pool) == rad2.evict(3, pool2) == 3
    assert pool.free_pages == pool2.free_pages


# ------------------------------------------------- outcome bookkeeping
def test_every_request_counted_exactly_once(model):
    eng, reqs = _run(model, chaos=ChaosConfig(seed=5, nan_logits=0.3,
                                              gemm_fault=0.1),
                     max_retries=1)
    assert all(r.done and r.outcome is not None for r in reqs)
    counted = sum(v for k, v in eng.stats.items()
                  if k.startswith("outcome_"))
    assert counted == len(reqs)


def test_hardened_defaults_keep_pr7_config_shape(model):
    """Default ServeConfig must not enable any resilience feature: the
    fault-free fast path is the PR7 engine bit-for-bit."""
    sc = ServeConfig(max_batch=2, max_seq=64)
    assert sc.max_queue == 0 and sc.deadline_ms == 0.0
    assert sc.preempt_policy == "none" and sc.chaos is None
    assert sc.snapshot_every_ticks == 0
    assert dataclasses.fields(sc)  # it stayed a dataclass
