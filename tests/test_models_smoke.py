"""Per-arch reduced-config smoke tests: one train step + one decode step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import api, lm
from repro.optim import OptConfig, adamw_init

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones(
            (B, cfg.n_image_tokens, cfg.d_frontend), jnp.bfloat16)
    if cfg.family == "audio":
        DL = max(S // 8, 16)
        batch = {"frames": jnp.ones((B, S, cfg.d_frontend), jnp.bfloat16),
                 "tokens": jnp.ones((B, DL), jnp.int32),
                 "labels": jnp.ones((B, DL), jnp.int32)}
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_train_step(arch):
    cfg = reduced(ARCHS[arch])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    oc = OptConfig(total_steps=10)
    opt = adamw_init(params, oc)
    step = jax.jit(api.make_train_step(cfg, oc))
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(o2["step"]) == 1
    # params actually changed (bit-level: first-step updates are ~lr/warmup)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_decode_step(arch):
    cfg = reduced(ARCHS[arch])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = lm.init_cache(cfg, B, 16)
    step = jax.jit(api.make_serve_step(cfg))
    logits, cache = step(params, cache, jnp.ones((B,), jnp.int32),
                         jnp.int32(0))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.float32(logits)).all()


def test_microbatched_train_matches_loss_scale():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=4, S=32)
    oc = OptConfig(total_steps=10)
    opt = adamw_init(params, oc)
    m1 = jax.jit(api.make_train_step(cfg, oc, 1))(params, opt, batch)[2]
    opt = adamw_init(params, oc)
    m2 = jax.jit(api.make_train_step(cfg, oc, 2))(params, opt, batch)[2]
    # microbatched mean loss approximates the full-batch loss
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05


def test_decode_matches_prefill_logits():
    """Teacher-forced decode reproduces the forward logits (dense arch)."""
    cfg = reduced(ARCHS["qwen2-0.5b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jnp.asarray(
        np.random.RandomState(0).randint(2, cfg.vocab_size, (B, S)))
    logits_full, _, _ = lm.forward(cfg, params, {"tokens": toks})
    cache = lm.init_cache(cfg, B, S + 1)
    outs = []
    for t in range(S):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t],
                                   jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.float32(dec), np.float32(logits_full),
                               rtol=3e-2, atol=3e-2)


def test_param_counts_match_published_sizes():
    expected = {
        "jamba-1.5-large-398b": (380e9, 420e9),
        "mixtral-8x22b": (130e9, 150e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "llama3-8b": (7.5e9, 9e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }
    for name, (lo, hi) in expected.items():
        n = ARCHS[name].param_count()
        assert lo < n < hi, (name, n)
