"""Fused epilogues, expert-batched dispatch, dispatch counts, plan-cache
hygiene and the Pallas interpret override.

Equivalence contract: for every backend, ``substrate.gemm`` with an
epilogue computes the same function as the unfused xla composition
(``act(x@w [+b]) [* (x@w2 [+b2])]``) to fp32-accumulation tolerance,
across ragged / prime / empty shapes.  The expert-batched kernel matches
the einsum and ``moe_apply`` stays equal to ``moe_apply_reference``.
Dispatch counts prove the fusion/batching is structural: one launch per
MoE expert-GEMM site, one launch for the dense swiglu pair.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import planner, timing
from repro.kernels import ops, substrate
from repro.kernels.arrayflex_gemm import arrayflex_expert_gemm
from repro.kernels.runtime import resolve_interpret
from repro.models import lm
from repro.nn import moe as moe_lib


def _unfused(x, w, w2, b, b2, kind):
    y = x @ w
    if b is not None:
        y = y + b
    if kind == "silu":
        y = jax.nn.silu(y)
    elif kind == "gelu":
        y = jax.nn.gelu(y)
    elif kind == "swiglu":
        u = x @ w2
        if b2 is not None:
            u = u + b2
        y = jax.nn.silu(y) * u
    return y


# ----------------------------------------------------------- fused epilogues
@pytest.mark.parametrize("backend", ["xla", "arrayflex", "ref"])
@pytest.mark.parametrize("kind,use_bias,use_bias2", [
    ("none", True, False),          # plain fused bias
    ("silu", False, False),
    ("gelu", True, False),
    ("swiglu", False, False),
    ("swiglu", True, True),
])
@pytest.mark.parametrize("shape", [
    (7, 33, 40),        # small ragged everything
    (130, 257, 384),    # prime-ish K beyond the SA tile, ragged M
    (128, 128, 128),    # exact tiling
])
def test_epilogue_matches_unfused(backend, kind, use_bias, use_bias2,
                                  shape):
    T, K, N = shape
    rng = np.random.RandomState(sum(shape) + len(kind))
    x = jnp.asarray(rng.randn(2, T, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    w2 = jnp.asarray(rng.randn(K, N), jnp.float32) if kind == "swiglu" \
        else None
    b = jnp.asarray(rng.randn(N), jnp.float32) if use_bias else None
    b2 = jnp.asarray(rng.randn(N), jnp.float32) if use_bias2 else None
    got = substrate.gemm(x, w, backend=backend, epilogue=kind, w2=w2,
                         bias=b, bias2=b2)
    want = _unfused(x, w, w2, b, b2, kind)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-3)


def test_epilogue_empty_shapes():
    """K=0 applies the epilogue to the zero accumulator (NOT plain zeros);
    empty rows/cols return empty results of the right shape."""
    b = jnp.asarray([1.0, -2.0, 3.0], jnp.float32)
    for backend in ("xla", "arrayflex", "ref"):
        got = substrate.gemm(jnp.zeros((4, 0), jnp.float32),
                             jnp.zeros((0, 3), jnp.float32),
                             backend=backend, epilogue="silu", bias=b)
        want = jnp.broadcast_to(jax.nn.silu(b), (4, 3))
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-5, atol=1e-6)
        empty = substrate.gemm(jnp.zeros((0, 5), jnp.float32),
                               jnp.zeros((5, 3), jnp.float32),
                               backend=backend, epilogue="gelu", bias=b)
        assert empty.shape == (0, 3)


def test_epilogue_validation():
    x, w = jnp.ones((2, 4)), jnp.ones((4, 8))
    with pytest.raises(ValueError):
        substrate.gemm(x, w, epilogue="swiglu")          # missing w2
    with pytest.raises(ValueError):
        substrate.gemm(x, w, epilogue="silu", w2=w)      # w2 without swiglu
    with pytest.raises(ValueError):
        substrate.gemm(x, w, bias2=jnp.ones((8,)))       # bias2 without w2
    with pytest.raises(ValueError):
        substrate.gemm(x, w, epilogue="tanh")            # unknown kind


def test_epilogue_priced_into_plan():
    """Eq.(5')/(6'): the fused epilogue adds to the per-step period, the
    dual contraction doubles the streamed cycles, and the plan cache keys
    on the epilogue spec."""
    substrate.clear_plan_cache()
    plain = substrate.plan_gemm(512, 256, 64, "arrayflex")
    ep = substrate.Epilogue(kind="swiglu", bias=True)
    fused = substrate.plan_gemm(512, 256, 64, "arrayflex", ep)
    assert fused is not plain
    assert fused.t_pred_ps > 2 * plain.t_pred_ps          # 2 contractions + e
    # the conventional comparator carries the SAME epilogue datapath, so
    # saving isolates the pipelining technique
    assert fused.t_conventional_ps > 2 * plain.t_conventional_ps
    assert ep.ops == 3 and ep.contractions == 2           # silu+gate+bias
    # the epilogue term is k-independent while cycles fall with k, so the
    # argmin can only move toward deeper collapse
    assert fused.k >= plain.k
    # timing-level sanity: period grows by exactly ops * d_epilogue_ps
    tp = timing.DEFAULT_TIMING
    assert tp.clock_period_ps(2, 3) == pytest.approx(
        tp.clock_period_ps(2) + 3 * tp.d_epilogue_ps)
    assert tp.clock_ghz(2, 3) == pytest.approx(
        1000.0 / tp.clock_period_ps(2, 3))


def test_analytic_and_executed_swiglu_plans_agree():
    """planner.model_gemms marks the wi pair with epilogue_ops=3 (silu +
    gate + the fused ln2 norm-scale prologue), so the analytic table and
    the executed fused substrate plan pick the same k and the two
    per-entry times sum to the dual-contraction prediction."""
    g = planner.GEMM("mlp.wi_gate", 512, 256, 64, epilogue_ops=3)
    lp = planner.plan_gemm(g, 128, 128)
    sp = substrate.plan_gemm(512, 256, 64, "arrayflex",
                             substrate.Epilogue(kind="swiglu",
                                                norm_scale=True))
    assert sp.epilogue.ops == 3
    assert lp.k == sp.k
    assert 2 * lp.t_abs_ps == pytest.approx(sp.t_pred_ps)
    assert lp.clock_ghz == pytest.approx(
        timing.DEFAULT_TIMING.clock_ghz(lp.k, 3))
    wi = [x for x in planner.model_gemms(reduced(ARCHS["qwen2-0.5b"]),
                                         ShapeConfig("t", 8, 2, "train"))
          if x.name.startswith("mlp.wi")]
    assert wi and all(x.epilogue_ops == 3 for x in wi)


# ------------------------------------------------- expert-batched kernel
@pytest.mark.parametrize("E,T,K,N", [
    (3, 5, 16, 24),      # small ragged
    (4, 130, 257, 40),   # rows/contraction beyond the SA tile, prime K
    (2, 128, 64, 128),   # exact tiling
])
def test_expert_batched_kernel_matches_einsum(E, T, K, N):
    rng = np.random.RandomState(E + T + K + N)
    x = jnp.asarray(rng.randn(E, T, K), jnp.float32)
    w = jnp.asarray(rng.randn(E, K, N), jnp.float32)
    want = jnp.einsum("etk,ekn->etn", x, w)
    for k in (1, 2, 4):
        got = ops.arrayflex_expert_matmul(x, w, k_collapse=k)
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-4, atol=1e-3)


def test_expert_batched_kernel_empty():
    out = arrayflex_expert_gemm(jnp.zeros((0, 4, 8), jnp.float32),
                                jnp.zeros((0, 8, 16), jnp.float32))
    assert out.shape == (0, 4, 16)
    out = ops.arrayflex_expert_matmul(jnp.zeros((2, 4, 0), jnp.float32),
                                      jnp.zeros((2, 0, 16), jnp.float32))
    assert not np.any(np.asarray(out)) and out.shape == (2, 4, 16)


def test_moe_apply_matches_reference_under_arrayflex():
    """The batched expert kernel inside moe_apply agrees with the dense
    every-expert oracle when capacity is ample."""
    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], compute_dtype="float32",
                  param_dtype="float32")
    m = cfg.moe
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_init(key, cfg.d_model, m.expert_d_ff or cfg.d_ff,
                         m.num_experts, num_shared=m.num_shared_experts,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    want = moe_lib.moe_apply_reference(p, x, top_k=m.top_k)
    for backend in ("xla", "arrayflex"):
        got, _ = moe_lib.moe_apply(p, x, top_k=m.top_k,
                                   capacity_factor=8.0,
                                   compute_dtype=jnp.float32,
                                   backend=backend)
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-4, atol=1e-3)


# --------------------------------------------------------- dispatch counts
def test_one_launch_per_moe_expert_site():
    """Acceptance: per MoE layer the expert GEMMs dispatch 3 launches
    (one per site), not 3E — and the dense swiglu pair is ONE launch."""
    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], compute_dtype="float32",
                  param_dtype="float32", gemm_backend="arrayflex")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    substrate.clear_plan_cache()
    jax.eval_shape(lambda p, b: lm.forward(cfg, p, b), params,
                   {"tokens": jnp.ones((2, 8), jnp.int32)})
    counts = dict(substrate.DISPATCH_COUNTS)
    # one traced super-block layer (scan): exactly one launch per site
    assert counts["moe.wi_gate"] == 1
    assert counts["moe.wi_up"] == 1
    assert counts["moe.wo"] == 1
    # E > 1 proves batching is doing work, not a degenerate expert count
    assert cfg.moe.num_experts > 1
    # attention QK/PV dispatch through the substrate now
    assert counts.get("attn.qk", 0) >= 1
    assert counts.get("attn.pv", 0) >= 1
    # dense model: the swiglu pair is ONE fused dual-GEMM launch, recorded
    # under both component site labels
    cfg_d = reduced(ARCHS["qwen2-0.5b"], compute_dtype="float32",
                    param_dtype="float32", gemm_backend="arrayflex")
    params_d = lm.init_params(cfg_d, jax.random.PRNGKey(0))
    substrate.clear_plan_cache()
    jax.eval_shape(lambda p, b: lm.forward(cfg_d, p, b), params_d,
                   {"tokens": jnp.ones((2, 8), jnp.int32)})
    counts_d = dict(substrate.DISPATCH_COUNTS)
    assert counts_d["mlp.wi_gate+mlp.wi_up"] == 1
    assert "mlp.wi_gate" not in counts_d      # no separate unfused launches
    assert {"mlp.wi_gate", "mlp.wi_up"} <= set(substrate.SITE_PLANS)
    plan = substrate.SITE_PLANS["mlp.wi_gate"]
    assert plan.epilogue.kind == "swiglu" and plan.epilogue.contractions == 2


def test_expert_site_plans_consistent_across_backends():
    """Satellite: every backend records ONE plan per expert shape with the
    xla convention T = G*C (the unrolled path used to log expert 0 only,
    with a per-expert T=C)."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 3, 5, 16), jnp.float32)   # (G,E,C,K)
    w = jnp.asarray(rng.randn(3, 16, 24), jnp.float32)
    for backend in ("xla", "arrayflex", "ref"):
        substrate.clear_plan_cache()
        substrate.expert_gemm(x, w, site="moe.wi_gate", backend=backend)
        plan = substrate.SITE_PLANS["moe.wi_gate"]
        assert (plan.M, plan.N, plan.T) == (24, 16, 2 * 5)
        assert plan.backend == backend
        assert substrate.DISPATCH_COUNTS["moe.wi_gate"] == 1


def test_backend_overrides_honored_on_batched_paths():
    """Re-registering a built-in backend must win on batched_gemm and
    expert_gemm exactly as it does on gemm, and the unrolled custom path
    must count one launch per batch element."""
    rng = np.random.RandomState(0)
    xb = jnp.asarray(rng.randn(3, 4, 8), jnp.float32)
    wb = jnp.asarray(rng.randn(3, 8, 6), jnp.float32)
    xe = jnp.asarray(rng.randn(2, 3, 4, 8), jnp.float32)
    we = jnp.asarray(rng.randn(3, 8, 6), jnp.float32)
    calls = []

    def spy(x2, w, plan, call):
        calls.append(x2.shape)
        return x2 @ w

    orig = substrate._BACKENDS["xla"]
    substrate.register_backend("xla", spy)
    try:
        substrate.clear_plan_cache()
        got = substrate.batched_gemm(xb, wb, site="attn.qk", backend="xla")
        np.testing.assert_allclose(np.float32(got),
                                   np.float32(jnp.matmul(xb, wb)),
                                   rtol=1e-5, atol=1e-5)
        assert len(calls) == 3                      # unrolled per batch elem
        assert substrate.DISPATCH_COUNTS["attn.qk"] == 3   # honest count
        calls.clear()
        got = substrate.expert_gemm(xe, we, site="moe.wo", backend="xla")
        want = jnp.einsum("gecd,edf->gecf", xe, we)
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-5, atol=1e-5)
        assert len(calls) == 3                      # one per expert
        assert substrate.DISPATCH_COUNTS["moe.wo"] == 3
    finally:
        substrate.register_backend("xla", orig)
        substrate.clear_plan_cache()


# ------------------------------------------------------- plan-cache hygiene
def test_clear_plan_cache_clears_every_memo():
    """Satellite: clear_plan_cache must reset ops.plan_collapse and
    planner.attention_plan too, or stale picks leak across timing-param
    changes."""
    substrate.plan_gemm(512, 256, 64, "arrayflex")
    ops.plan_collapse(384, 192, 48)
    planner.attention_plan(4096, 32768)
    assert substrate.plan_cache_info().currsize > 0
    assert ops.plan_collapse.cache_info().currsize > 0
    assert planner.attention_plan.cache_info().currsize > 0
    substrate.SITE_PLANS["x"] = substrate.plan_gemm(8, 8, 8, "xla")
    substrate.DISPATCH_COUNTS["x"] = 3
    substrate.clear_plan_cache()
    assert substrate.plan_cache_info().currsize == 0
    assert ops.plan_collapse.cache_info().currsize == 0
    assert planner.attention_plan.cache_info().currsize == 0
    assert not substrate.SITE_PLANS and not substrate.DISPATCH_COUNTS


# --------------------------------------------------------- interpret plumbing
def test_resolve_interpret_chain(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    # explicit argument wins
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # default: interpret everywhere but on real TPU backends
    assert resolve_interpret(None) is (jax.default_backend() != "tpu")
    # env var overrides the default
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "false")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert resolve_interpret(None) is True
    # ...but never the explicit argument
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(True) is True


def test_config_interpret_reaches_kernels(monkeypatch):
    """ModelConfig.pallas_interpret threads through substrate/ops down to
    pallas_call (observable: interpret=False on CPU fails to lower)."""
    import dataclasses
    cfg = reduced(ARCHS["qwen2-0.5b"], compute_dtype="float32",
                  param_dtype="float32", gemm_backend="arrayflex")
    assert cfg.pallas_interpret is None       # default: resolve chain
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((1, 4), jnp.int32)
    lm.forward(cfg, params, {"tokens": toks})  # interpret on CPU: runs
    cfg_hw = dataclasses.replace(cfg, pallas_interpret=False)
    with pytest.raises(Exception):
        # compiled Mosaic lowering is unavailable on CPU — proof the flag
        # reached the kernel (interpret=True would have succeeded)
        jax.block_until_ready(
            lm.forward(cfg_hw, params, {"tokens": toks})[0])
