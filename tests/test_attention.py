"""nn.attention: chunked==dense, GQA, windows, decode-vs-prefill parity."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.nn import attention as att


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), dtype)


@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("gqa", [(8, 8), (8, 2), (4, 1)])
def test_chunked_equals_dense(gqa, window):
    H, KV = gqa
    B, S, D = 2, 128, 32
    q = _rand((B, S, H, D), 1)
    k = _rand((B, S, KV, D), 2)
    v = _rand((B, S, KV, D), 3)
    dense = att.dense_attention(q, k, v, causal=True, window=window)
    chunk = att.chunked_attention(q, k, v, causal=True, window=window,
                                  kv_chunk=32)
    np.testing.assert_allclose(np.float32(chunk), np.float32(dense),
                               rtol=2e-4, atol=2e-4)


def test_noncausal_chunked():
    B, S, T, H, KV, D = 1, 64, 96, 4, 4, 16
    q, k, v = _rand((B, S, H, D), 1), _rand((B, T, KV, D), 2), \
        _rand((B, T, KV, D), 3)
    dense = att.dense_attention(q, k, v, causal=False)
    chunk = att.chunked_attention(q, k, v, causal=False, kv_chunk=32)
    np.testing.assert_allclose(np.float32(chunk), np.float32(dense),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_dense_row():
    """decode_attention at position p == row p of full dense attention."""
    B, S, H, KV, D = 2, 32, 4, 2, 16
    q_full = _rand((B, S, H, D), 1)
    k = _rand((B, S, KV, D), 2)
    v = _rand((B, S, KV, D), 3)
    full = att.dense_attention(q_full, k, v, causal=True)
    for pos in (0, 7, 31):
        out = att.decode_attention(q_full[:, pos:pos + 1], k, v,
                                   jnp.int32(pos))
        np.testing.assert_allclose(np.float32(out[:, 0]),
                                   np.float32(full[:, pos]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_ring_buffer_window():
    """SWA ring cache gives the same result as a windowed dense row."""
    B, S, H, KV, D, W = 1, 64, 2, 2, 16, 16
    q_full = _rand((B, S, H, D), 5)
    k = _rand((B, S, KV, D), 6)
    v = _rand((B, S, KV, D), 7)
    full = att.dense_attention(q_full, k, v, causal=True, window=W)
    pos = 40
    # build the ring cache: slot i holds position p where p % W == i
    ring_k = np.zeros((B, W, KV, D), np.float32)
    ring_v = np.zeros((B, W, KV, D), np.float32)
    for p in range(pos - W + 1, pos + 1):
        ring_k[:, p % W] = np.asarray(k[:, p])
        ring_v[:, p % W] = np.asarray(v[:, p])
    out = att.decode_attention(q_full[:, pos:pos + 1], jnp.asarray(ring_k),
                               jnp.asarray(ring_v), jnp.int32(pos), window=W)
    np.testing.assert_allclose(np.float32(out[:, 0]), np.float32(full[:, pos]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("T,kv_chunk", [
    (97, 32),     # prime-ish T: formerly degenerated to chunk=1
    (130, 64),    # one ragged tail chunk
    (96, 128),    # chunk larger than T (clamped)
    (101, 101),   # exact after clamp
])
def test_chunked_ragged_kv_matches_dense(T, kv_chunk):
    """Ragged KV lengths run at the planned chunk via zero-pad + mask
    instead of a largest-divisor search (T=4097-style degeneration)."""
    B, S, H, KV, D = 1, 64, 4, 2, 16
    q = _rand((B, S, H, D), 1)
    k = _rand((B, T, KV, D), 2)
    v = _rand((B, T, KV, D), 3)
    for causal in (True, False):
        dense = att.dense_attention(q, k, v, causal=causal)
        chunk = att.chunked_attention(q, k, v, causal=causal,
                                      kv_chunk=kv_chunk)
        np.testing.assert_allclose(np.float32(chunk), np.float32(dense),
                                   rtol=2e-4, atol=2e-4)
