import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # real dependency (declared in pyproject [test] extra) wins when present
    import hypothesis  # noqa: F401
except ImportError:  # hermetic env: install the deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install()
