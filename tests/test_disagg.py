"""Disaggregated prefill/decode serving (serving.disagg).

The acceptance matrix drives greedy streams through the colocated
:class:`ServingEngine` and the :class:`DisaggServingEngine` and requires
them bit-identical — dense and MoE families, xla / arrayflex /
arrayflex_w8a8 backends, 2+2 pods with and without the pp=2 layer
pipeline, dense and paged K/V, batched and token prefill.  On top of the
matrix: per-role plan pricing (prefill deepens ``best_k``, decode
shallows it — ``sharding.pp_transfer_terms``), the pod->pod K/V handoff
as a priced + chaos-faultable transfer, decode-pod-loss recovery through
the recompute-on-re-admission path, snapshot/restore with the prefill
cache, construction validations, and the AF002 stage-boundary audit leg
(``analysis.jaxpr_audit.audit_pipeline``).

The pp=2 cells and the pipeline audit need a 4-device host
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``).  On a
single-device host they skip in-process and run once through the
subprocess wrapper, so tier-1 always exercises them; the CI ``disagg``
job runs them directly.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import pytest

from repro import configs
from repro.analysis import jaxpr_audit
from repro.configs import base
from repro.core import planner
from repro.kernels import substrate
from repro.models import lm
from repro.parallel import sharding
from repro.runtime.chaos import ChaosConfig
from repro.serving import (DisaggServeConfig, DisaggServingEngine,
                           EngineCrash, Request, ServeConfig, ServingEngine)
from repro.serving.disagg import PREFILL_STEP_OVERHEAD
from repro.serving.engine import PREFILL_CHUNK_CHOICES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

BACKENDS = ("xla", "arrayflex", "arrayflex_w8a8")


def _cfg(arch="llama3-8b", backend="xla"):
    return base.reduced(configs.ARCHS[arch], gemm_backend=backend)


_PARAMS = {}


def _params(arch="llama3-8b", backend="xla"):
    # params are backend-independent (quantizing engines pre-quantize
    # internally), so cache per arch
    if arch not in _PARAMS:
        _PARAMS[arch] = lm.init_params(_cfg(arch), jax.random.PRNGKey(0))
    return _PARAMS[arch]


def _reqs():
    return [Request(prompt=[5, 7, 11, 13, 17, 19, 23], max_new_tokens=6,
                    rid=1),
            Request(prompt=[2, 3], max_new_tokens=5, rid=2),
            Request(prompt=[31], max_new_tokens=4, rid=3),
            Request(prompt=list(range(40, 60)), max_new_tokens=6, rid=4)]


def _run(engine_cls, sc, arch="llama3-8b", backend="xla", reqs=None):
    eng = engine_cls(_cfg(arch, backend), _params(arch, backend), sc)
    rs = _reqs() if reqs is None else reqs
    for r in rs:
        eng.submit(r)
    eng.run_to_completion()
    return {r.rid: (r.outcome, tuple(r.out_tokens)) for r in rs}, eng


_KW = dict(max_batch=4, max_seq=64, seed=0)


# ------------------------------------------------- equivalence matrix
@pytest.mark.parametrize("backend", BACKENDS)
def test_dense_disagg_stream_identical(backend):
    """2+2 pods, pp=1, dense K/V: bit-identical greedy streams per
    backend (W8A8 keeps the colocated chunk — tile geometry is part of
    its numerics — so within-backend equality is exact there too)."""
    colo, ce = _run(ServingEngine, ServeConfig(**_KW), backend=backend)
    dis, de = _run(DisaggServingEngine,
                   DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2),
                   backend=backend)
    assert dis == colo
    assert all(o == "ok" for o, _ in dis.values())
    if substrate.backend_act_quantizes(backend):
        assert de.prefill_chunk == ce.prefill_chunk
    assert de.stats["kv_transfer_bytes"] > 0
    assert set(de.ttft_virtual) == set(dis)


@pytest.mark.parametrize("backend", BACKENDS)
def test_moe_disagg_stream_identical(backend):
    """MoE family (token prefill — the batched path doesn't route
    experts), 2+2 pods, pp=1."""
    arch = "qwen3-moe-30b-a3b"
    colo, _ = _run(ServingEngine, ServeConfig(**_KW, prefill_mode="token"),
                   arch=arch, backend=backend)
    dis, _ = _run(DisaggServingEngine,
                  DisaggServeConfig(**_KW, prefill_mode="token",
                                    prefill_pods=2, decode_pods=2),
                  arch=arch, backend=backend)
    assert dis == colo


def test_paged_disagg_stream_identical():
    """Paged K/V: the handoff moves exactly the live pages the block
    table names, and streams stay bit-identical."""
    colo, _ = _run(ServingEngine, ServeConfig(**_KW))
    dis, eng = _run(DisaggServingEngine,
                    DisaggServeConfig(**_KW, kv_pages=40, page_size=16,
                                      prefill_pods=2, decode_pods=2))
    assert dis == colo
    assert eng.stats["kv_transfer_pages"] > 0
    assert eng.stats["kv_transfer_bytes"] > 0


@needs4
@pytest.mark.parametrize("backend", BACKENDS)
def test_multidev_pp2_stream_identical(backend):
    """pp=2 GPipe stages over each role's pod window (4 devices): the
    stage-boundary transfer re-prices plans per role but never moves
    values — streams stay bit-identical to the colocated engine."""
    colo, _ = _run(ServingEngine, ServeConfig(**_KW), backend=backend)
    dis, eng = _run(DisaggServingEngine,
                    DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2,
                                      pp_stages=2),
                    backend=backend)
    assert dis == colo
    assert eng.pp == 2


# ------------------------------------------------ launch accounting
def test_disagg_dispatch_accounting():
    substrate.DISPATCH_COUNTS.clear()
    dis, eng = _run(DisaggServingEngine,
                    DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2),
                    backend="arrayflex")
    assert all(o == "ok" for o, _ in dis.values())
    assert "attn.wq" in substrate.DISPATCH_COUNTS
    assert sum(substrate.DISPATCH_COUNTS.values()) > 0
    assert eng.stats["prefill_dispatches"] > 0
    assert eng.stats["decode_dispatches"] > 0
    # both role clocks advanced, and the virtual TTFT is bounded by the
    # colocated sum (it excludes the other role's interleaved work)
    assert eng.stats["prefill_time_s"] > 0
    assert eng.stats["decode_time_s"] > 0
    wall = {r: t for r, t in eng.ttft_virtual.items()}
    assert all(t > 0 for t in wall.values())


# ------------------------------------------------- per-role pricing
def test_role_pricing_k_shift():
    """The pinned boundary site (attn.wq of the reduced-8b geometry,
    M=K=896, one epilogue op, pp=2): prefill's boundary ops keep or
    deepen ``best_k``, decode's serialized ingress shallows it."""
    ep = substrate.Epilogue(kind="none", bias=True)
    assert ep.ops == 1

    def k(role, T):
        t_ops, t_cyc = sharding.pp_transfer_terms(role, 2, T, 896)
        sig = substrate.ShardSig(transfer_ops=t_ops,
                                 transfer_cycles=t_cyc)
        return substrate.plan_gemm(896, 896, T, backend="arrayflex",
                                   epilogue=ep, shard=sig).k

    assert (k("", 128), k("prefill", 128), k("decode", 128)) == (4, 4, 2)
    assert (k("", 2048), k("prefill", 2048), k("decode", 2048)) == (2, 2, 1)
    for T in (128, 2048):
        assert k("prefill", T) > k("decode", T)


def test_pp_transfer_terms():
    assert sharding.pp_transfer_terms("", 2, 8, 896) == (0, 0)
    assert sharding.pp_transfer_terms("prefill", 1, 8, 896) == (0, 0)
    assert sharding.pp_transfer_terms("prefill", 2, 8, 896) == (1, 0)
    assert sharding.pp_transfer_terms("prefill", 8, 8, 896) == (3, 0)
    ops_, cyc = sharding.pp_transfer_terms("decode", 2, 4, 896)
    assert ops_ == 0 and cyc == -(-(4 * 896) // substrate.ops.SA_C)
    with pytest.raises(ValueError, match="pp_role"):
        sharding.pp_transfer_terms("training", 2, 8, 896)


def test_pricing_scope_targets_boundary_site():
    """Inside use_pp_pricing only PP_BOUNDARY_SITE gets the pricing-only
    ShardCtx (mesh=None — the GPipe shard_map owns the 'pod' axis, the
    per-stage GEMM must not nest another)."""
    with sharding.use_pp_pricing("prefill", 2):
        ctx = sharding.gemm_shard_ctx(sharding.PP_BOUNDARY_SITE,
                                      8, 896, 896)
        assert ctx is not None and ctx.mesh is None
        assert ctx.transfer_ops == 1 and ctx.transfer_cycles == 0
        assert sharding.gemm_shard_ctx("mlp.wo", 8, 896, 896) is None
    with sharding.use_pp_pricing("decode", 2):
        ctx = sharding.gemm_shard_ctx(sharding.PP_BOUNDARY_SITE,
                                      4, 896, 896)
        assert ctx.transfer_cycles > 0 and ctx.transfer_ops == 0
    with sharding.use_pp_pricing("", 2):        # inert without a role
        assert sharding.gemm_shard_ctx(sharding.PP_BOUNDARY_SITE,
                                       8, 896, 896) is None


def test_prefill_chunk_repick():
    """The prefill role re-picks its chunk under PREFILL_STEP_OVERHEAD;
    an explicit serve_cfg.prefill_chunk still wins."""
    S = _KW["max_seq"]
    want = min(S, max(1, planner.attention_plan(
        S, S, choices=PREFILL_CHUNK_CHOICES,
        step_overhead=PREFILL_STEP_OVERHEAD)))
    eng = DisaggServingEngine(
        _cfg(), _params(),
        DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2))
    assert eng.prefill_chunk == want
    pinned = DisaggServingEngine(
        _cfg(), _params(),
        DisaggServeConfig(**_KW, prefill_chunk=8,
                          prefill_pods=2, decode_pods=2))
    assert pinned.prefill_chunk == 8


# --------------------------------------------------------- validations
def test_construction_validations():
    cfg, p = _cfg(), _params()
    with pytest.raises(TypeError, match="DisaggServeConfig"):
        DisaggServingEngine(cfg, p, ServeConfig(**_KW))
    with pytest.raises(ValueError, match="at least one pod"):
        DisaggServingEngine(cfg, p, DisaggServeConfig(**_KW,
                                                      prefill_pods=0))
    with pytest.raises(ValueError, match="prefix_cache"):
        DisaggServingEngine(cfg, p, DisaggServeConfig(
            **_KW, prefill_pods=2, decode_pods=2,
            kv_pages=40, page_size=16, prefix_cache=True))
    with pytest.raises(ValueError, match="dense K/V"):
        DisaggServingEngine(cfg, p, DisaggServeConfig(
            **_KW, prefill_pods=2, decode_pods=2, pp_stages=2,
            kv_pages=40, page_size=16))
    with pytest.raises(ValueError, match="prefill_pods == decode_pods"):
        DisaggServingEngine(cfg, p, DisaggServeConfig(
            **_KW, prefill_pods=1, decode_pods=2, pp_stages=2))


# --------------------------------------------------------------- chaos
def _streams(res):
    return {rid: toks for rid, (_, toks) in res.items()}


def test_chaos_transfer_retry_recovers():
    base_res, _ = _run(DisaggServingEngine,
                       DisaggServeConfig(**_KW, prefill_pods=2,
                                         decode_pods=2))
    res, eng = _run(DisaggServingEngine,
                    DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2,
                                      max_retries=2,
                                      chaos=ChaosConfig(kv_transfer_at=0)))
    assert res == base_res
    assert eng.stats["transfer_retries"] == 1
    assert all(o == "ok" for o, _ in res.values())


def test_chaos_transfer_persistent_fails_typed():
    rs = _reqs()
    eng = DisaggServingEngine(
        _cfg(), _params(),
        DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2,
                          max_retries=0,
                          chaos=ChaosConfig(kv_transfer=1.0)))
    for r in rs:
        eng.submit(r)
    eng.run_to_completion()
    bad = [r for r in rs if r.outcome == "failed"]
    assert bad
    assert all("TransferFault" in (r.error or "") for r in bad)


@pytest.mark.parametrize("paged", (False, True))
def test_chaos_decode_pod_loss_recovers(paged):
    """A decode pod dies mid-stream: every decode-resident request
    re-admits through the recompute path (prefilled again, handed off
    again) and finishes PREEMPTED_RETRIED with bit-identical tokens."""
    kv = dict(kv_pages=40, page_size=16) if paged else {}
    base_res, _ = _run(DisaggServingEngine,
                       DisaggServeConfig(**_KW, prefill_pods=2,
                                         decode_pods=2, **kv))
    res, eng = _run(DisaggServingEngine,
                    DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2,
                                      chaos=ChaosConfig(pod_lost_at=4),
                                      **kv))
    assert eng.stats["pod_losses"] == 1
    assert _streams(res) == _streams(base_res)
    assert any(o == "preempted_retried" for o, _ in res.values())


def test_snapshot_restore_with_pcache():
    """An injected crash mid-serve restores from the snapshot (which
    carries the prefill-role cache) and finishes bit-identically."""
    base_res, _ = _run(DisaggServingEngine,
                       DisaggServeConfig(**_KW, prefill_pods=2,
                                         decode_pods=2))
    sc = DisaggServeConfig(**_KW, prefill_pods=2, decode_pods=2,
                           snapshot_every_ticks=1,
                           chaos=ChaosConfig(crash_at=5))
    eng = DisaggServingEngine(_cfg(), _params(), sc)
    for r in _reqs():
        eng.submit(r)
    with pytest.raises(EngineCrash):
        eng.run_to_completion()
    snap = eng.latest_snapshot()
    assert snap is not None and "pcache" in snap
    eng2 = DisaggServingEngine.restore(_cfg(), _params(), sc, snap)
    eng2.run_to_completion()
    got = {r.rid: tuple(r.out_tokens) for r in eng2.restored_requests}
    want = _streams(base_res)
    for rid, toks in got.items():
        assert toks == want[rid], (rid, toks, want[rid])


# ------------------------------------------------- AF002 pipeline audit
@needs4
def test_multidev_audit_pipeline_roles_clean():
    cfg = _cfg()
    for role, off in (("prefill", 0), ("decode", 2)):
        rcfg = dataclasses.replace(cfg, pp_role=role, pp_stages=2,
                                   mesh_shape=(2, 1, 1), pod_offset=off)
        assert jaxpr_audit.audit_pipeline(rcfg) == []


@needs4
def test_multidev_audit_unscoped_pipeline_flags_af002():
    """The seeded violation: a pipelined step traced WITHOUT a role
    pricing scope stages its collective_permute with no site plan
    pricing the transfer."""
    bad = dataclasses.replace(_cfg(), pp_role="", pp_stages=2,
                              mesh_shape=(2, 1, 1))
    findings = jaxpr_audit.audit_pipeline(bad)
    af002 = [f for f in findings if f.code == "AF002"
             and "collective_permute" in f.message]
    assert af002, findings
    assert "use_pp_pricing" in af002[0].message


# ---------------------------------------------------- serve CLI + tier-1
def test_disagg_subprocess():
    """On a small host, run the 4-device cells once in a subprocess so
    tier-1 always covers the pp=2 matrix and the pipeline audit."""
    if len(jax.devices()) >= 4:
        pytest.skip("multi-device host runs test_multidev_* directly")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join("tests", "test_disagg.py"),
         "-k", "multidev"],
        capture_output=True, text=True, timeout=1500, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout[-4000:] + out.stderr[-2000:]
    assert "passed" in out.stdout


def test_serve_cli_disagg():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--requests", "3",
         "--max-new", "4", "--prefill-pods", "1", "--decode-pods", "1"],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
    assert "disagg: 1 prefill + 1 decode pod(s)" in out.stdout
    assert "virtual TTFT" in out.stdout
