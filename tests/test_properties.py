"""Hypothesis property tests on framework invariants."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.nn import layers
from repro.core import planner


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), shift=st.integers(0, 64))
def test_rope_relative_position_invariance(seed, shift):
    """RoPE inner products depend only on relative position: shifting both
    q and k positions by the same offset preserves q·k."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, 1, 1, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 1, 32), jnp.float32)
    pos_q = jnp.asarray([[5]])
    pos_k = jnp.asarray([[2]])
    dot0 = float(jnp.sum(layers.apply_rope(q, pos_q, 1e4)
                         * layers.apply_rope(k, pos_k, 1e4)))
    dot1 = float(jnp.sum(layers.apply_rope(q, pos_q + shift, 1e4)
                         * layers.apply_rope(k, pos_k + shift, 1e4)))
    assert abs(dot0 - dot1) < 1e-3 * (1.0 + abs(dot0))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_rope_preserves_norm(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, 3, 4, 16), jnp.float32)
    y = layers.apply_rope(x, jnp.arange(3)[None, :], 1e4)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 32))
def test_softmax_xent_matches_manual(seed, n):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(4, n), jnp.float32)
    labels = jnp.asarray(rng.randint(0, n, 4), jnp.int32)
    got = float(layers.softmax_xent(logits, labels))
    p = np.exp(np.asarray(logits, np.float64))
    p /= p.sum(-1, keepdims=True)
    want = -np.mean(np.log(p[np.arange(4), np.asarray(labels)]))
    assert abs(got - want) < 1e-4 * (1 + abs(want))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_rmsnorm_scale_invariance(seed):
    """RMSNorm output is invariant to positive rescaling of its input."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, 8), jnp.float32) + 0.1
    p = layers.rmsnorm_init(8)
    a = layers.rmsnorm(p, x)
    b = layers.rmsnorm(p, x * 7.5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(M=st.integers(16, 2048), N=st.integers(16, 8192),
       T=st.integers(1, 4096))
def test_planner_never_beats_exhaustive(M, N, T):
    """plan_gemm's absolute time equals the exhaustive minimum (Eq. 6)."""
    from repro.core import timing
    g = planner.GEMM("g", M, N, T)
    p = planner.plan_gemm(g, 128, 128)
    best = min(timing.t_abs_ps(M, N, T, 128, 128, k)
               for k in (1, 2, 4))
    assert p.t_abs_ps == best


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.sampled_from([1, 2, 4]))
def test_gemm_kernel_collapse_property(seed, k):
    """arrayflex_gemm == oracle for random shapes at every collapse."""
    from repro.kernels import ref
    from repro.kernels.arrayflex_gemm import arrayflex_gemm
    rng = np.random.RandomState(seed)
    M = 64 * rng.randint(1, 3)
    K = 64 * k * rng.randint(1, 4)
    N = 64 * rng.randint(1, 3)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    got = arrayflex_gemm(x, w, bk=64, k_collapse=k)
    np.testing.assert_allclose(np.float32(got), np.float32(ref.gemm_ref(x, w)),
                               rtol=1e-3, atol=1e-3)
