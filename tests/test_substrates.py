"""Data pipeline, checkpointing, optimizer, compression, fault tolerance."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM, make_pipeline
from repro.optim import OptConfig, adamw_init, adamw_update, lr_schedule
from repro.parallel import compression
from repro.runtime import FaultToleranceManager, HeartbeatMonitor
from repro.runtime.elastic import largest_mesh_shape


# ------------------------------------------------------------------ data
def test_data_determinism_and_host_sharding():
    dc = DataConfig(seq_len=16, global_batch=8, vocab_size=100, seed=7)
    full = SyntheticLM(dc).batch_at(3)
    h0 = SyntheticLM(dc, 0, 2).batch_at(3)
    h1 = SyntheticLM(dc, 1, 2).batch_at(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])
    np.testing.assert_array_equal(SyntheticLM(dc).batch_at(3)["tokens"],
                                  full["tokens"])
    assert (full["tokens"] >= 2).all() and (full["tokens"] < 100).all()
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


def test_prefetcher_resumes_at_step():
    dc = DataConfig(seq_len=8, global_batch=2, vocab_size=50)
    pipe = make_pipeline(dc, start_step=5)
    step, batch = next(pipe)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"],
                                  SyntheticLM(dc).batch_at(5)["tokens"])
    pipe.close()


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(10000, dtype=np.int32).tofile(path)
    dc = DataConfig(seq_len=16, global_batch=4, path=path)
    from repro.data import MemmapCorpus
    b = MemmapCorpus(dc).batch_at(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "blocks": ({"w": jnp.ones((2, 2), jnp.bfloat16)},),
            "step": jnp.int32(7)}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest_step() == 30
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["blocks"][0]["w"].dtype == jnp.bfloat16


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.ones((4,))}
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5


# ------------------------------------------------------------------ optim
def test_adamw_converges_on_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                    weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_master_weights_precision():
    cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=1000,
                    weight_decay=0.0, master_weights=True)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    for _ in range(10):
        params, state, _ = adamw_update(params, g, state, cfg)
    # bf16-only updates of 1e-6 would be lost; master accumulates them
    assert float(state["master"]["w"][0]) < 1.0


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, jnp.int32(0))) < 0.2
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0,
                                                                   abs=0.02)
    assert float(lr_schedule(cfg, jnp.int32(100))) < 0.01


# ------------------------------------------------------------ compression
def test_compression_error_feedback():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(64, 64), jnp.float32)}
    err = compression.init_error(g)
    q, s, err2 = compression.compress(g, err)
    assert q["w"].dtype == jnp.int8
    deq = compression.decompress(q, s)
    rel = float(jnp.linalg.norm(deq["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.02
    # residual carries exactly the quantization error
    np.testing.assert_allclose(
        np.asarray(err2["w"], np.float32),
        np.asarray(g["w"] - deq["w"], np.float32), atol=1e-2)


# ------------------------------------------------------------ fault tol.
def test_fault_manager_restarts_from_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mon = HeartbeatMonitor(1)
    ft = FaultToleranceManager(mgr, mon, ckpt_every=5)

    class Src:
        def batch_at(self, step):
            return step

    crashed = {"done": False}

    def inject(step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    state = {"acc": jnp.float32(0.0)}

    def step_fn(st, batch):
        return {"acc": st["acc"] + 1.0}

    state, steps, restarts = ft.run(state, step_fn, Src(), 20,
                                    inject_failure=inject)
    assert restarts == 1 and steps == 20
    # after restart from step 10, total increments = 10 + (20-10)
    assert float(state["acc"]) == 20.0


def test_straggler_detection():
    mon = HeartbeatMonitor(4, straggler_factor=2.0)
    for h, t in [(0, 1.0), (1, 1.1), (2, 0.9), (3, 5.0)]:
        mon.beat(h, 1, t)
    assert mon.stragglers() == [3]
    assert mon.dead_hosts() == []


def test_elastic_mesh_shapes():
    assert largest_mesh_shape(512, 16) == (32, 16)
    assert largest_mesh_shape(384, 16) == (24, 16)
    assert largest_mesh_shape(100, 16) == (10, 10)
