"""MoE dispatch vs dense oracle; SSD vs naive recurrence; decode parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SSMConfig
from repro.nn import mamba as mamba_lib
from repro.nn import moe as moe_lib


def test_moe_grouped_matches_reference():
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_init(key, 32, 64, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    yref = moe_lib.moe_apply_reference(p, x, top_k=2)
    for groups in (0, 1, 2):
        y, aux = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=8.0,
                                   groups=groups,
                                   compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)
        assert float(aux) >= 0


def test_moe_capacity_drops_are_partial():
    """With tiny capacity outputs shrink but stay finite (token dropping)."""
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_init(key, 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y_full, _ = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=8.0,
                                  compute_dtype=jnp.float32)
    y_tiny, _ = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=0.25,
                                  compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(y_tiny)).all()
    assert float(jnp.mean(jnp.abs(y_tiny))) < float(jnp.mean(jnp.abs(y_full)))


def test_moe_shared_experts():
    key = jax.random.PRNGKey(2)
    p = moe_lib.moe_init(key, 16, 32, 4, num_shared=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 16))
    y, _ = moe_lib.moe_apply(p, x, top_k=2, compute_dtype=jnp.float32)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()


def _ssd_naive(x, dt, A, B_, C_):
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    hg = H // G
    x, dt, B_, C_, A = map(np.float64, (x, dt, B_, C_, A))
    st = np.zeros((Bsz, G, hg, P, N))
    ys = np.zeros((Bsz, S, H, P))
    for t in range(S):
        for g in range(G):
            for h in range(hg):
                a = np.exp(dt[:, t, g * hg + h] * A[g * hg + h])
                upd = np.einsum("bn,b,bp->bpn", B_[:, t, g],
                                dt[:, t, g * hg + h], x[:, t, g * hg + h])
                st[:, g, h] = st[:, g, h] * a[:, None, None] + upd
                ys[:, t, g * hg + h] = np.einsum("bn,bpn->bp", C_[:, t, g],
                                                 st[:, g, h])
    return ys, st


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunked_vs_naive(chunk):
    rng = np.random.RandomState(0)
    B, S, H, P, G, N = 2, 64, 4, 8, 2, 16
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(rng.rand(B, S, H) * 0.5, jnp.float32)
    A = jnp.asarray(-rng.rand(H) - 0.2, jnp.float32)
    B_ = jnp.asarray(rng.randn(B, S, G, N), jnp.float32)
    C_ = jnp.asarray(rng.randn(B, S, G, N), jnp.float32)
    y, st = mamba_lib.ssd_chunked(x, dt, A, B_, C_, chunk)
    yn, stn = _ssd_naive(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.float64(y), yn, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.float64(st), stn, rtol=1e-3, atol=1e-4)


def test_mamba_decode_matches_forward():
    """Token-by-token decode reproduces the full-sequence forward."""
    ssm = SSMConfig(d_state=16, head_dim=8, expand=2, chunk_size=8)
    d = 16
    key = jax.random.PRNGKey(0)
    p = mamba_lib.mamba_init(key, d, ssm, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d))
    y_full, state, conv = mamba_lib.mamba_forward(p, x, ssm, jnp.float32)

    B = 2
    d_in = ssm.expand * d
    G, N = ssm.n_groups, ssm.d_state
    hg = (d_in // ssm.head_dim) // G
    st = jnp.zeros((B, G, hg, ssm.head_dim, N), jnp.float32)
    cv = jnp.zeros((B, ssm.d_conv - 1, d_in + 2 * G * N), jnp.float32)
    outs = []
    for t in range(24):
        y, st, cv = mamba_lib.mamba_decode_step(p, x[:, t], st, cv, ssm,
                                                jnp.float32)
        outs.append(y)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state),
                               rtol=2e-3, atol=2e-3)
