"""Power/EDP calibration envelope + CNN GEMM-shape extraction anchors."""
import pytest

from repro.core import cnn_shapes, planner, power


def test_resnet34_paper_anchors():
    ls = cnn_shapes.resnet34_layers()
    assert ls[19].mnt == (256, 2304, 196)    # paper layer 20
    assert ls[27].mnt == (512, 2304, 49)     # paper layer 28
    assert len(ls) == 34                     # 33 convs + fc


def test_network_layer_counts():
    assert len(cnn_shapes.mobilenet_layers()) == 1 + 13 * 2 + 1
    assert len(cnn_shapes.convnext_layers()) == 1 + 3 + 18 * 3 + 1


def test_normal_mode_costs_more_than_conventional():
    # paper §IV-B: in normal (k=1) mode ArrayFlex consumes MORE power
    assert power.power_arrayflex(1) > power.power_conventional()


@pytest.mark.parametrize("R", [128, 256])
@pytest.mark.parametrize("net", ["resnet34", "mobilenet", "convnext"])
def test_full_run_savings_in_paper_envelope(net, R):
    gemms = [planner.GEMM(f"l{i}", *mnt)
             for i, mnt in enumerate(cnn_shapes.network_mnt(net))]
    res = planner.plan_network(gemms, R, R)
    # paper: latency 9-11% avg (we allow 5-16% per-net), power 13-23%
    # (we allow 10-30%), EDP 1.4-1.8x (we allow 1.25-2.0x)
    assert 0.05 < res["latency_saving"] < 0.16
    assert 0.08 < res["power_saving"] < 0.30
    assert 1.25 < res["edp_gain"] < 2.0


def test_aggregate_matches_paper_headline():
    """Across the three CNNs on 128x128: ~11% latency, 13-23% power."""
    all_savings = []
    all_power = []
    for net in ("resnet34", "mobilenet", "convnext"):
        gemms = [planner.GEMM(f"l{i}", *mnt)
                 for i, mnt in enumerate(cnn_shapes.network_mnt(net))]
        res = planner.plan_network(gemms, 128, 128)
        all_savings.append(res["latency_saving"])
        all_power.append(res["power_saving"])
    avg = sum(all_savings) / 3
    assert 0.07 < avg < 0.13          # paper: 11% average
    assert all(p > 0.08 for p in all_power)
