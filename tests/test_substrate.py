"""GEMM substrate: backend registry, plan cache, site labels, and
end-to-end backend equivalence on the reduced qwen2-0.5b model
(forward / decode_step / prefill_step logits + greedy serving streams)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.core import planner
from repro.kernels import ops, ref, substrate
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def _qwen(backend="xla"):
    """fp32 everywhere: cross-backend differences are pure accumulation
    order, so logits agree to fp32 tolerance and greedy ties cannot flip."""
    return reduced(ARCHS["qwen2-0.5b"], compute_dtype="float32",
                   param_dtype="float32", gemm_backend=backend)


# ------------------------------------------------------------------ registry
def test_backend_registry():
    assert {"xla", "arrayflex", "ref"} <= set(substrate.backends())
    with pytest.raises(ValueError):
        substrate.gemm(jnp.ones((2, 4)), jnp.ones((4, 4)), backend="nope")
    calls = []

    def mine(x2, w, plan, call):
        calls.append((plan, call))
        return x2 @ w

    substrate.register_backend("_test", mine)
    try:
        out = substrate.gemm(jnp.ones((2, 4)), jnp.ones((4, 8)),
                             backend="_test", interpret=False)
        assert out.shape == (2, 8) and len(calls) == 1
        plan, call = calls[0]
        assert plan.M == 8 and plan.N == 4 and plan.T == 2
        assert plan.epilogue == substrate.EPILOGUE_NONE
        assert call.out_dtype is None and call.interpret is False
    finally:
        substrate._BACKENDS.pop("_test")


@pytest.mark.parametrize("backend", ["xla", "arrayflex", "ref"])
@pytest.mark.parametrize("shape", [
    (7, 64, 32),        # small everything
    (300, 130, 200),    # ragged M/K/N beyond the SA tile
    (128, 256, 128),    # exact tiling
])
def test_gemm_backends_agree(backend, shape):
    T, K, N = shape
    rng = np.random.RandomState(sum(shape))
    x = jnp.asarray(rng.randn(2, T, K), jnp.float32)   # leading batch dim
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    got = substrate.gemm(x, w, backend=backend)
    want = ref.gemm_ref(x.reshape(-1, K), w).reshape(2, T, N)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)


def test_expert_gemm_backends_agree():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 3, 5, 16), jnp.float32)   # (G,E,C,K)
    w = jnp.asarray(rng.randn(3, 16, 24), jnp.float32)     # (E,K,N)
    want = jnp.einsum("gecd,edf->gecf", x, w)
    for backend in ("xla", "arrayflex", "ref"):
        got = substrate.expert_gemm(x, w, backend=backend)
        np.testing.assert_allclose(np.float32(got), np.float32(want),
                                   rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------- plan cache
def test_plan_cache_and_memoized_planners():
    """Satellite: Eq.(6) argmin runs once per shape, not per trace/call."""
    h0 = substrate.plan_cache_info().hits
    p1 = substrate.plan_gemm(512, 256, 64, "arrayflex")
    p2 = substrate.plan_gemm(512, 256, 64, "arrayflex")
    assert p1 is p2
    assert substrate.plan_cache_info().hits > h0
    assert p1.k == ops.plan_collapse(512, 256, 64)
    assert p1.t_pred_ps > 0 and p1.t_conventional_ps > 0
    # non-arrayflex backends plan k=1 (no collapse on the XLA path)
    assert substrate.plan_gemm(512, 256, 64, "xla").k == 1

    h0 = ops.plan_collapse.cache_info().hits
    ops.plan_collapse(384, 192, 48)
    ops.plan_collapse(384, 192, 48)
    assert ops.plan_collapse.cache_info().hits > h0

    h0 = planner.attention_plan.cache_info().hits
    planner.attention_plan(4096, 32768)
    planner.attention_plan(4096, 32768)
    assert planner.attention_plan.cache_info().hits > h0


def test_site_plans_align_with_model_gemms():
    """Site labels recorded during a model trace are the same names the
    analytic planner emits — the contract the bench joins the tables on."""
    substrate.SITE_PLANS.clear()
    cfg = _qwen("arrayflex")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    lm.forward(cfg, params, {"tokens": jnp.ones((2, 8), jnp.int32)})
    from repro.configs.base import ShapeConfig
    analytic = {g.name for g in planner.model_gemms(
        cfg, ShapeConfig("t", 8, 2, "train"))}
    executed = set(substrate.SITE_PLANS)
    # every executed projection GEMM carries its planner name (attention
    # score/PV products run inside the attention kernels, not the substrate)
    assert executed <= analytic | {"frontend.img", "frontend.audio"}
    assert {"attn.wq", "attn.wk", "attn.wv", "attn.wo",
            "mlp.wi_gate", "mlp.wi_up", "mlp.wo", "unembed"} <= executed
    assert all(p.backend == "arrayflex" and p.k >= 1
               for p in substrate.SITE_PLANS.values())


# ------------------------------------------------- model-level equivalence
def test_forward_logits_match_across_backends():
    cfg = _qwen()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.RandomState(0).randint(2, cfg.vocab_size, (2, 12)))
    want, _, _ = lm.forward(cfg, params, {"tokens": toks})
    got, _, _ = lm.forward(_qwen("arrayflex"), params, {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)


def test_decode_and_prefill_match_across_backends():
    cfg, cfg_af = _qwen(), _qwen("arrayflex")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.asarray([3, 5], jnp.int32)
    want, _ = lm.decode_step(cfg, params, lm.init_cache(cfg, 2, 16), tok,
                             jnp.int32(0))
    got, _ = lm.decode_step(cfg_af, params, lm.init_cache(cfg, 2, 16), tok,
                            jnp.int32(0))
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)

    toks = jnp.asarray(np.random.RandomState(1).randint(2, 512, (2, 8)),
                       jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)
    lens = jnp.asarray([8, 5], jnp.int32)
    want, wc = lm.prefill_step(cfg, params, lm.init_cache(cfg, 2, 16),
                               toks, pos, lens)
    got, gc = lm.prefill_step(cfg_af, params, lm.init_cache(cfg, 2, 16),
                              toks, pos, lens)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-5, atol=1e-4)
    for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(wc)):
        np.testing.assert_allclose(np.float32(a), np.float32(b),
                                   rtol=1e-5, atol=1e-4)


def test_greedy_streams_identical_across_backends():
    """Acceptance: the serving engine produces bit-identical greedy token
    streams whichever backend executes the GEMMs."""
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]

    def run(backend):
        cfg = _qwen(backend)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params,
                            ServeConfig(max_batch=2, max_seq=32))
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.out_tokens for r in reqs]

    assert run("xla") == run("arrayflex")


@pytest.mark.parametrize("arch", ["mamba2-370m", "qwen3-moe-30b-a3b"])
def test_other_families_match_across_backends(arch):
    """The substrate covers mamba projections and MoE expert GEMMs too."""
    cfg = reduced(ARCHS[arch], compute_dtype="float32",
                  param_dtype="float32")
    cfg_af = dataclasses.replace(cfg, gemm_backend="arrayflex")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((2, 32), jnp.int32)
    want, _, _ = lm.forward(cfg, params, {"tokens": toks})
    substrate.SITE_PLANS.clear()
    got, _, _ = lm.forward(cfg_af, params, {"tokens": toks})
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-3)
    # the family's GEMMs really dispatched through the arrayflex backend
    # (guards against a silently dropped backend= thread-through, which
    # would make the equivalence above trivially true)
    family_sites = ({"mamba.z", "mamba.xbc", "mamba.dt", "mamba.out"}
                    if ARCHS[arch].family == "ssm" else
                    {"moe.router", "moe.wi_gate", "moe.wi_up", "moe.wo"})
    assert family_sites <= set(substrate.SITE_PLANS)
    assert all(substrate.SITE_PLANS[s].backend == "arrayflex"
               for s in family_sites)
    # decode path too (mamba/MoE decode GEMMs must also dispatch)
    tok = jnp.asarray([3, 5], jnp.int32)
    want, _ = lm.decode_step(cfg, params, lm.init_cache(cfg, 2, 8), tok,
                             jnp.int32(0))
    got, _ = lm.decode_step(cfg_af, params, lm.init_cache(cfg, 2, 8), tok,
                            jnp.int32(0))
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=1e-4, atol=1e-3)
