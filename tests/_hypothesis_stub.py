"""Minimal, deterministic stand-in for ``hypothesis`` when it is absent.

The real dependency is declared in ``pyproject.toml`` (``.[test]``); hermetic
environments without it still need ``tests/test_core_timing.py``,
``test_properties.py`` and ``test_simulator.py`` to collect and run.  This
shim implements exactly the surface those modules use — ``given``,
``settings`` and the ``integers``/``sampled_from``/``floats``/``booleans``
strategies — by drawing ``max_examples`` pseudo-random examples from an RNG
seeded with the test name, so runs are reproducible.  No shrinking, no
database, no edge-case bias: a property stays a property, just with plain
random sampling.

``tests/conftest.py`` installs this into ``sys.modules`` only when the real
package cannot be imported, so installed environments are unaffected.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value=0, max_value=2 ** 31 - 1):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def just(value):
    return _Strategy(lambda rng: value)


class settings:
    """Decorator recording max_examples on the (possibly given-wrapped) fn."""

    def __init__(self, max_examples=100, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError("the hypothesis stub only supports keyword "
                        "strategies, e.g. @given(x=st.integers(0, 9))")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", None) or settings()
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(cfg.max_examples):
                drawn = {name: strat.example(rng)
                         for name, strat in kw_strategies.items()}
                fn(*args, **drawn, **kwargs)
        # pytest must not mistake strategy kwargs for fixtures: hide the
        # drawn parameters behind an empty signature (as hypothesis does).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


def install():
    """Register this shim as ``hypothesis`` + ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "floats", "booleans", "just"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
